"""Standing perf trajectory: the ``BENCH_*.json`` contract.

Every PR that touches the emulation fast path lands one ``BENCH_<pr>.json``
at the repo root (written by a ``benchmarks/fig_*`` script), so emulation
speed is a *tracked series* rather than a one-off claim — the paper's 5–17×
headline is only credible here if every change appends a comparable point.
Each artifact declares its kind via ``bench``; three kinds exist:

``bench: "emu_speed"`` (``benchmarks/fig_emu_speed.py``) — raw coordination
and end-to-end emulation throughput.  Schema (``schema_version`` 1)::

    {
      "bench": "emu_speed",
      "pr": 6,                       # trajectory x-axis
      "schema_version": 1,
      "mode": "full" | "quick" | "smoke",
      "host": {"python": "...", "platform": "...", "cpus": N},
      "coordination": [              # Timekeeper microbenchmark cells
        {"actors": 8, "coordination_mode": "batched" | "unbatched",
         "events": int, "wall_s": float,
         "events_per_s": float, "rounds_per_s": float,
         "virtual_per_wall": float,  # virtual seconds per wall second
         "rounds": int, "requests": int, "batched_requests": int,
         "merged_rounds": int, "coalesced_parks": int}, ...
      ],
      "wire": [                      # optional (PR 9+): transport-only
        {"transport": "tcp" | "shm", # cells — bare client processes, no
         "replicas": int,            # engine, isolating wire cost
         "events": int, "wall_s": float, "events_per_s": float}, ...
      ],
      "end_to_end": [                # full serving stack cells
        {"backend": "thread" | "process", "replicas": int,
         "transport": "tcp" | "shm",  # optional: process wire (PR 9+)
         "events": int, "wall_s": float, "virtual_s": float,
         "events_per_s": float, "rounds_per_s": float,
         "virtual_per_wall": float, "timekeeper": {...}}, ...
      ],
      "diurnal": {                   # optional headline cell (PR 9+): an
        "backend": "process",        # hour of virtual time on a streaming
        "transport": "shm",          # diurnal trace at high replica count
        "replicas": 100, "virtual_s": 3600.0, "wall_s": float,
        "events": int, "events_per_s": float, "virtual_per_wall": float,
        "sessions": int},
      "summary": {"batched_speedup_at_8": float,
                  "shm_speedup_at_8": float,       # optional: shm/tcp e2e
                  "shm_wire_speedup_at_8": float,  # optional: wire-only
                  "max_events_per_s": float,
                  "max_virtual_per_wall": float}
    }

Reading the numbers: ``events_per_s`` is emulated engine steps (end-to-end)
or coordinated jump targets (microbench) retired per wall second — raw
evaluation throughput.  ``virtual_per_wall`` is the emulation speedup (how
much faster than real time the timeline ran).  ``batched_speedup_at_8`` is
batched/unbatched coordination events/sec at 8 actors — the fast-path win.

``bench: "scale"`` (``benchmarks/fig_scale.py``) — the streaming path's
flat-memory session sweep::

    {
      "bench": "scale",
      "pr": 7, "schema_version": 1, "mode": ..., "host": {...},
      "cells": [
        {"backend": "thread" | "process", "sessions": int, "requests": int,
         "audit": "full" | "sampled" | "off", "qps": float,
         "wall_s": float, "virtual_s": float,
         "sessions_per_s": float, "requests_per_s": float,
         "virtual_per_wall": float, "peak_rss_mb": float}, ...
      ],
      "summary": {"max_sessions": int, "max_sessions_per_s": float,
                  "max_requests_per_s": float, "max_virtual_per_wall": float,
                  "rss_ratio_thread": float, "rss_ratio_process": float,
                  "rss_flat_within": float}
    }

``rss_ratio_<backend>`` is largest/smallest sampled-cell peak RSS across
the session sweep; validation *enforces* ``rss_ratio <= rss_flat_within``
— a committed artifact showing memory growth is a regression, not a data
point.  The comparability floor is >= 3 distinct sampled session counts on
the thread backend and >= 2 on process.

``bench: "fleet"`` (``benchmarks/fig_fleet.py``) — the fleet plane's
multiplexed-vs-partitioned consolidation claim::

    {
      "bench": "fleet",
      "pr": 10, "schema_version": 1, "mode": ..., "host": {...},
      "cells": [
        {"variant": "multiplexed" | "partitioned",
         "backend": str, "models": int, "tenants": int,
         "requests": int, "attainment": float, "fairness": float,
         "replica_seconds": float, "goodput_rps": float,
         "wall_s": float, "virtual_s": float}, ...
      ],
      "parity": {"backends": "thread,des", "max_err_steps": float,
                 "decisions_equal": bool, "completed_equal": bool},
      "summary": {"replica_seconds_saving": float,   # 1 - mux/part
                  "attainment_multiplexed": float,
                  "attainment_partitioned": float,
                  "min_fairness": float,
                  "saving_floor": float,             # gate: saving >= floor
                  "attainment_epsilon": float}       # gate: mux >= part-eps
    }

Validation *enforces* the headline the same way scale enforces flat
memory: ``replica_seconds_saving >= saving_floor`` and
``attainment_multiplexed >= attainment_partitioned - attainment_epsilon``
— a committed artifact where consolidation stopped paying is a
regression, not a data point.  The comparability floor is at least one
cell of each variant.

Stdlib only (CI validates artifacts with no repo imports)::

    python tools/bench_trajectory.py validate BENCH_6.json
    python tools/bench_trajectory.py show            # trajectory table
    python tools/bench_trajectory.py compare BENCH_6.json BENCH_9.json \\
        --gate 50          # fail if any shared cell regressed > 50%

``compare`` diffs two artifacts of the same kind cell by cell (cells are
keyed by what identifies them: (actors, mode) for coordination rows,
(transport, replicas) for wire rows, (backend, transport, replicas) for
end-to-end, (backend, sessions, audit) for scale, (variant, backend,
tenants) for fleet) on their primary throughput metric, prints per-cell
deltas, and
— with ``--gate`` — exits non-zero when any shared cell regressed by more
than the given percentage.  Cells present on only one side are listed but
never gate: a new transport axis or replica count is growth, not a
regression.
"""

from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path
from typing import List, Optional

SCHEMA_VERSION = 1
REPO_ROOT = Path(__file__).resolve().parent.parent

_COORD_REQUIRED = ("actors", "coordination_mode", "events", "wall_s",
                   "events_per_s", "rounds_per_s", "virtual_per_wall")
_E2E_REQUIRED = ("backend", "replicas", "events", "wall_s", "virtual_s",
                 "events_per_s", "rounds_per_s", "virtual_per_wall")
_SCALE_REQUIRED = ("backend", "sessions", "requests", "audit", "qps",
                   "wall_s", "virtual_s", "sessions_per_s", "requests_per_s",
                   "virtual_per_wall", "peak_rss_mb")
_FLEET_REQUIRED = ("variant", "backend", "models", "tenants", "requests",
                   "attainment", "fairness", "replica_seconds",
                   "goodput_rps", "wall_s", "virtual_s")


def _is_num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def validate(doc: dict, *, min_replica_counts: int = 3) -> List[str]:
    """Return every schema problem (empty list == valid artifact).

    Dispatches on ``doc["bench"]``; each kind enforces its own
    comparability floor beyond shape checks (see the module docstring).
    """
    if not isinstance(doc, dict):
        return [f"artifact must be a JSON object, got {type(doc).__name__}"]
    problems: List[str] = []
    if not isinstance(doc.get("pr"), int):
        problems.append("pr: missing or not an integer")
    if doc.get("schema_version") != SCHEMA_VERSION:
        problems.append(f"schema_version: expected {SCHEMA_VERSION}, "
                        f"got {doc.get('schema_version')!r}")
    kind = doc.get("bench")
    if kind == "emu_speed":
        problems += _validate_emu_speed(doc, min_replica_counts)
    elif kind == "scale":
        problems += _validate_scale(doc)
    elif kind == "fleet":
        problems += _validate_fleet(doc)
    else:
        problems.append(f"bench: expected 'emu_speed', 'scale', or "
                        f"'fleet', got {kind!r}")
    return problems


def _validate_emu_speed(doc: dict, min_replica_counts: int) -> List[str]:
    """Floor: >= ``min_replica_counts`` distinct replica counts on BOTH the
    thread and process backends, each cell carrying events/sec and
    virtual-s/wall-s."""
    problems: List[str] = []
    coord = doc.get("coordination")
    if not isinstance(coord, list) or not coord:
        problems.append("coordination: missing or empty")
        coord = []
    for i, row in enumerate(coord):
        for k in _COORD_REQUIRED:
            if k not in row:
                problems.append(f"coordination[{i}].{k}: missing")
            elif k not in ("coordination_mode",) and not _is_num(row[k]):
                problems.append(f"coordination[{i}].{k}: not a number")
        if row.get("coordination_mode") not in ("batched", "unbatched"):
            problems.append(f"coordination[{i}].coordination_mode: "
                            f"expected batched|unbatched")

    e2e = doc.get("end_to_end")
    if not isinstance(e2e, list) or not e2e:
        problems.append("end_to_end: missing or empty")
        e2e = []
    per_backend: dict = {"thread": set(), "process": set()}
    for i, row in enumerate(e2e):
        for k in _E2E_REQUIRED:
            if k not in row:
                problems.append(f"end_to_end[{i}].{k}: missing")
            elif k != "backend" and not _is_num(row[k]):
                problems.append(f"end_to_end[{i}].{k}: not a number")
        b = row.get("backend")
        if b not in per_backend:
            problems.append(f"end_to_end[{i}].backend: expected "
                            f"thread|process, got {b!r}")
        elif isinstance(row.get("replicas"), int):
            per_backend[b].add(row["replicas"])
        if "transport" in row and row["transport"] not in ("tcp", "shm"):
            problems.append(f"end_to_end[{i}].transport: expected tcp|shm, "
                            f"got {row['transport']!r}")
    for b, counts in per_backend.items():
        if len(counts) < min_replica_counts:
            problems.append(
                f"end_to_end: backend {b!r} covers {len(counts)} replica "
                f"counts ({sorted(counts)}), need >= {min_replica_counts}")

    wire = doc.get("wire")
    if wire is not None:
        if not isinstance(wire, list):
            problems.append("wire: not a list")
            wire = []
        for i, row in enumerate(wire):
            if row.get("transport") not in ("tcp", "shm"):
                problems.append(f"wire[{i}].transport: expected tcp|shm, "
                                f"got {row.get('transport')!r}")
            for k in ("replicas", "events", "wall_s", "events_per_s"):
                if not _is_num(row.get(k)):
                    problems.append(f"wire[{i}].{k}: missing or not a number")

    diurnal = doc.get("diurnal")
    if diurnal is not None:
        if not isinstance(diurnal, dict):
            problems.append("diurnal: not an object")
        else:
            for k in ("backend", "replicas", "virtual_s", "wall_s",
                      "events", "events_per_s", "virtual_per_wall"):
                if k not in diurnal:
                    problems.append(f"diurnal.{k}: missing")
                elif k != "backend" and not _is_num(diurnal[k]):
                    problems.append(f"diurnal.{k}: not a number")

    summary = doc.get("summary")
    if not isinstance(summary, dict):
        problems.append("summary: missing")
    else:
        for k in ("batched_speedup_at_8", "max_events_per_s",
                  "max_virtual_per_wall"):
            if not _is_num(summary.get(k)):
                problems.append(f"summary.{k}: missing or not a number")
        for k in ("shm_speedup_at_8", "shm_wire_speedup_at_8"):
            if k in summary and not _is_num(summary[k]):
                problems.append(f"summary.{k}: not a number")
    return problems


def _validate_scale(doc: dict) -> List[str]:
    """Floor: >= 3 distinct sampled session counts on thread, >= 2 on
    process, and the flat-memory gate ``rss_ratio <= rss_flat_within``."""
    problems: List[str] = []
    cells = doc.get("cells")
    if not isinstance(cells, list) or not cells:
        problems.append("cells: missing or empty")
        cells = []
    sampled: dict = {"thread": set(), "process": set()}
    for i, row in enumerate(cells):
        for k in _SCALE_REQUIRED:
            if k not in row:
                problems.append(f"cells[{i}].{k}: missing")
            elif k not in ("backend", "audit") and not _is_num(row[k]):
                problems.append(f"cells[{i}].{k}: not a number")
        if row.get("backend") not in ("thread", "process"):
            problems.append(f"cells[{i}].backend: expected thread|process, "
                            f"got {row.get('backend')!r}")
        if row.get("audit") not in ("full", "sampled", "off"):
            problems.append(f"cells[{i}].audit: expected full|sampled|off, "
                            f"got {row.get('audit')!r}")
        if (row.get("audit") == "sampled"
                and row.get("backend") in sampled
                and isinstance(row.get("sessions"), int)):
            sampled[row["backend"]].add(row["sessions"])
    for b, floor in (("thread", 3), ("process", 2)):
        if len(sampled[b]) < floor:
            problems.append(
                f"cells: backend {b!r} covers {len(sampled[b])} sampled "
                f"session counts ({sorted(sampled[b])}), need >= {floor}")

    summary = doc.get("summary")
    if not isinstance(summary, dict):
        problems.append("summary: missing")
        return problems
    for k in ("max_sessions", "max_sessions_per_s", "max_requests_per_s",
              "max_virtual_per_wall", "rss_ratio_thread",
              "rss_ratio_process", "rss_flat_within"):
        if not _is_num(summary.get(k)):
            problems.append(f"summary.{k}: missing or not a number")
    gate = summary.get("rss_flat_within")
    if _is_num(gate):
        for b in ("thread", "process"):
            ratio = summary.get(f"rss_ratio_{b}")
            if _is_num(ratio) and ratio > gate:
                problems.append(
                    f"summary.rss_ratio_{b}: {ratio} exceeds the "
                    f"flat-memory gate ({gate}) — streaming replay must "
                    f"not grow RSS with session count")
    return problems


def _validate_fleet(doc: dict) -> List[str]:
    """Floor: at least one multiplexed and one partitioned cell, plus the
    consolidation gates ``replica_seconds_saving >= saving_floor`` and
    ``attainment_multiplexed >= attainment_partitioned - epsilon``."""
    problems: List[str] = []
    cells = doc.get("cells")
    if not isinstance(cells, list) or not cells:
        problems.append("cells: missing or empty")
        cells = []
    variants: set = set()
    for i, row in enumerate(cells):
        for k in _FLEET_REQUIRED:
            if k not in row:
                problems.append(f"cells[{i}].{k}: missing")
            elif k not in ("variant", "backend") and not _is_num(row[k]):
                problems.append(f"cells[{i}].{k}: not a number")
        v = row.get("variant")
        if v not in ("multiplexed", "partitioned"):
            problems.append(f"cells[{i}].variant: expected "
                            f"multiplexed|partitioned, got {v!r}")
        else:
            variants.add(v)
        att = row.get("attainment")
        if _is_num(att) and not 0.0 <= att <= 1.0:
            problems.append(f"cells[{i}].attainment: {att} outside [0, 1]")
        fair = row.get("fairness")
        if _is_num(fair) and not 0.0 < fair <= 1.0:
            problems.append(f"cells[{i}].fairness: {fair} outside (0, 1]")
    for v in ("multiplexed", "partitioned"):
        if cells and v not in variants:
            problems.append(f"cells: no {v!r} cell — the consolidation "
                            f"claim needs both sides of the comparison")

    parity = doc.get("parity")
    if not isinstance(parity, dict):
        problems.append("parity: missing")
    else:
        if not _is_num(parity.get("max_err_steps")):
            problems.append("parity.max_err_steps: missing or not a number")
        elif parity["max_err_steps"] > 1.0:
            problems.append(f"parity.max_err_steps: "
                            f"{parity['max_err_steps']} exceeds the "
                            f"one-slow-step bar")
        for k in ("decisions_equal", "completed_equal"):
            if not isinstance(parity.get(k), bool):
                problems.append(f"parity.{k}: missing or not a bool")
            elif not parity[k]:
                problems.append(f"parity.{k}: false — fleet backends "
                                f"diverged")

    summary = doc.get("summary")
    if not isinstance(summary, dict):
        problems.append("summary: missing")
        return problems
    for k in ("replica_seconds_saving", "attainment_multiplexed",
              "attainment_partitioned", "min_fairness", "saving_floor",
              "attainment_epsilon"):
        if not _is_num(summary.get(k)):
            problems.append(f"summary.{k}: missing or not a number")
    saving = summary.get("replica_seconds_saving")
    floor = summary.get("saving_floor")
    if _is_num(saving) and _is_num(floor) and saving < floor:
        problems.append(
            f"summary.replica_seconds_saving: {saving} below the "
            f"consolidation gate ({floor}) — multiplexing must keep "
            f"beating static partitioning on replica-seconds")
    mux = summary.get("attainment_multiplexed")
    part = summary.get("attainment_partitioned")
    eps = summary.get("attainment_epsilon")
    if (_is_num(mux) and _is_num(part) and _is_num(eps)
            and mux < part - eps):
        problems.append(
            f"summary.attainment_multiplexed: {mux} fell more than "
            f"{eps} below partitioned ({part}) — consolidation is "
            f"paying for its savings with SLO misses")
    return problems


def write_bench(doc: dict, path: Path) -> Path:
    """Validate then write one trajectory point (refuses malformed docs —
    a broken artifact in the series is worse than a missing one)."""
    problems = validate(doc)
    if problems:
        raise ValueError("refusing to write malformed bench artifact:\n  "
                         + "\n  ".join(problems))
    path = Path(path)
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    return path


def load_trajectory(root: Path = REPO_ROOT) -> List[dict]:
    """All ``BENCH_<n>.json`` points at ``root``, ascending PR order."""
    out = []
    for path in Path(root).glob("BENCH_*.json"):
        m = re.fullmatch(r"BENCH_(\d+)\.json", path.name)
        if not m:
            continue
        try:
            doc = json.loads(path.read_text())
        except json.JSONDecodeError:
            continue
        doc.setdefault("pr", int(m.group(1)))
        out.append(doc)
    return sorted(out, key=lambda d: d.get("pr", 0))


def _cmd_validate(args) -> int:
    path = Path(args.path)
    if not path.exists():
        print(f"MISSING: {path}", file=sys.stderr)
        return 1
    try:
        doc = json.loads(path.read_text())
    except json.JSONDecodeError as e:
        print(f"MALFORMED JSON: {path}: {e}", file=sys.stderr)
        return 1
    problems = validate(doc, min_replica_counts=args.min_replica_counts)
    if problems:
        print(f"MALFORMED: {path}", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        return 1
    s = doc["summary"]
    head = f"ok: {path.name} pr={doc['pr']} mode={doc.get('mode', '?')}"
    if doc["bench"] == "scale":
        print(f"{head} max_sessions={s['max_sessions']} "
              f"max_sessions_per_s={s['max_sessions_per_s']:.0f} "
              f"rss_ratio_thread={s['rss_ratio_thread']} "
              f"rss_ratio_process={s['rss_ratio_process']} "
              f"(gate <= {s['rss_flat_within']})")
    elif doc["bench"] == "fleet":
        print(f"{head} "
              f"replica_seconds_saving={s['replica_seconds_saving']} "
              f"(gate >= {s['saving_floor']}) "
              f"attainment={s['attainment_multiplexed']} vs "
              f"partitioned={s['attainment_partitioned']} "
              f"min_fairness={s['min_fairness']}")
    else:
        print(f"{head} "
              f"batched_speedup_at_8={s['batched_speedup_at_8']}x "
              f"max_events_per_s={s['max_events_per_s']:.0f} "
              f"max_virtual_per_wall={s['max_virtual_per_wall']:.1f}")
    return 0


def _cmd_show(args) -> int:
    points = load_trajectory(Path(args.root))
    if not points:
        print(f"(no BENCH_*.json under {args.root})")
        return 0
    speed = [d for d in points if d.get("bench") == "emu_speed"]
    scale = [d for d in points if d.get("bench") == "scale"]
    fleet = [d for d in points if d.get("bench") == "fleet"]
    if speed:
        print(f"{'pr':>4}  {'mode':<6} {'batched@8':>10}  "
              f"{'max_events/s':>13}  {'max_virt/wall':>13}")
        for doc in speed:
            s = doc.get("summary", {})
            print(f"{doc.get('pr', '?'):>4}  {doc.get('mode', '?'):<6} "
                  f"{s.get('batched_speedup_at_8', float('nan')):>9.2f}x  "
                  f"{s.get('max_events_per_s', float('nan')):>13.0f}  "
                  f"{s.get('max_virtual_per_wall', float('nan')):>13.1f}")
    if scale:
        if speed:
            print()
        print(f"{'pr':>4}  {'mode':<6} {'max_sessions':>12}  "
              f"{'sessions/s':>10}  {'rss_thread':>10}  {'rss_proc':>9}")
        for doc in scale:
            s = doc.get("summary", {})
            print(f"{doc.get('pr', '?'):>4}  {doc.get('mode', '?'):<6} "
                  f"{s.get('max_sessions', float('nan')):>12}  "
                  f"{s.get('max_sessions_per_s', float('nan')):>10.0f}  "
                  f"{s.get('rss_ratio_thread', float('nan')):>9.2f}x  "
                  f"{s.get('rss_ratio_process', float('nan')):>8.2f}x")
    if fleet:
        if speed or scale:
            print()
        print(f"{'pr':>4}  {'mode':<6} {'rs_saving':>9}  "
              f"{'attain_mux':>10}  {'attain_part':>11}  {'fairness':>8}")
        for doc in fleet:
            s = doc.get("summary", {})
            print(f"{doc.get('pr', '?'):>4}  {doc.get('mode', '?'):<6} "
                  f"{s.get('replica_seconds_saving', float('nan')):>9.3f}  "
                  f"{s.get('attainment_multiplexed', float('nan')):>10.4f}  "
                  f"{s.get('attainment_partitioned', float('nan')):>11.4f}  "
                  f"{s.get('min_fairness', float('nan')):>8.4f}")
    return 0


def cells_of(doc: dict) -> dict:
    """Comparable cells of one artifact: ``{key_tuple: throughput}``.

    Keys carry everything that identifies a cell across artifacts —
    including the transport axis introduced in PR 9, so a tcp row never
    silently compares against an shm row.
    """
    kind = doc.get("bench")
    cells: dict = {}
    if kind == "emu_speed":
        for row in doc.get("coordination", []):
            cells[("coordination", row.get("actors"),
                   row.get("coordination_mode"))] = row.get("events_per_s")
        for row in doc.get("wire", []):
            cells[("wire", row.get("transport"),
                   row.get("replicas"))] = row.get("events_per_s")
        for row in doc.get("end_to_end", []):
            cells[("end_to_end", row.get("backend"),
                   row.get("transport", ""),
                   row.get("replicas"))] = row.get("events_per_s")
        d = doc.get("diurnal")
        if isinstance(d, dict):
            cells[("diurnal", d.get("backend"), d.get("transport", ""),
                   d.get("replicas"))] = d.get("events_per_s")
    elif kind == "scale":
        for row in doc.get("cells", []):
            cells[("scale", row.get("backend"), row.get("sessions"),
                   row.get("audit"))] = row.get("sessions_per_s")
    elif kind == "fleet":
        for row in doc.get("cells", []):
            cells[("fleet", row.get("variant"), row.get("backend"),
                   row.get("tenants"))] = row.get("goodput_rps")
    return cells


def _cmd_compare(args) -> int:
    docs = []
    for p in (args.old, args.new):
        path = Path(p)
        if not path.exists():
            print(f"MISSING: {path}", file=sys.stderr)
            return 1
        try:
            docs.append(json.loads(path.read_text()))
        except json.JSONDecodeError as e:
            print(f"MALFORMED JSON: {path}: {e}", file=sys.stderr)
            return 1
    old, new = docs
    if old.get("bench") != new.get("bench"):
        print(f"incomparable artifacts: bench {old.get('bench')!r} vs "
              f"{new.get('bench')!r}", file=sys.stderr)
        return 1
    if old.get("mode") != new.get("mode"):
        print(f"note: comparing mode={old.get('mode')!r} against "
              f"mode={new.get('mode')!r} — deltas reflect harness size, "
              f"not just code")
    oc, nc = cells_of(old), cells_of(new)
    shared = [k for k in oc if k in nc]
    regressions = []
    print(f"{args.old} (pr={old.get('pr')}) -> {args.new} "
          f"(pr={new.get('pr')}), {len(shared)} shared cells:")
    for k in sorted(shared, key=str):
        a, b = oc[k], nc[k]
        if not (_is_num(a) and _is_num(b)) or a <= 0:
            print(f"  {' '.join(map(str, k)):<44} (not comparable)")
            continue
        pct = (b - a) / a * 100.0
        print(f"  {' '.join(map(str, k)):<44} {a:>12.1f} -> {b:>12.1f}  "
              f"{pct:+7.1f}%")
        if args.gate is not None and pct < -args.gate:
            regressions.append((k, pct))
    for label, only in (("only in old", [k for k in oc if k not in nc]),
                        ("only in new", [k for k in nc if k not in oc])):
        for k in sorted(only, key=str):
            print(f"  {' '.join(map(str, k)):<44} ({label})")
    if regressions:
        print(f"GATE FAILED (> {args.gate}% regression):", file=sys.stderr)
        for k, pct in regressions:
            print(f"  - {' '.join(map(str, k))}: {pct:+.1f}%",
                  file=sys.stderr)
        return 1
    if args.gate is not None:
        print(f"gate ok: no shared cell regressed > {args.gate}%")
    return 0


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    sub = ap.add_subparsers(dest="cmd", required=True)
    p = sub.add_parser("validate", help="validate one BENCH_*.json artifact")
    p.add_argument("path")
    p.add_argument("--min-replica-counts", type=int, default=3)
    p.set_defaults(fn=_cmd_validate)
    p = sub.add_parser("show", help="print the whole trajectory")
    p.add_argument("--root", default=str(REPO_ROOT))
    p.set_defaults(fn=_cmd_show)
    p = sub.add_parser("compare",
                       help="diff two artifacts cell by cell (+ --gate)")
    p.add_argument("old")
    p.add_argument("new")
    p.add_argument("--gate", type=float, default=None, metavar="PCT",
                   help="fail if any shared cell's throughput regressed "
                        "by more than PCT percent")
    p.set_defaults(fn=_cmd_compare)
    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
