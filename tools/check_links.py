#!/usr/bin/env python
"""Fail on broken intra-repo markdown links (CI `docs` job).

Scans markdown files (default: docs/*.md + README.md) for
``[text](target)`` links, resolves each relative target against the file's
directory, and exits non-zero listing every target that does not exist.
External links (http/https/mailto) and pure in-page anchors (``#...``) are
skipped; a ``path#anchor`` target is checked for the path only.

    python tools/check_links.py            # default file set
    python tools/check_links.py README.md docs/*.md CHANGES.md
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def iter_links(md: Path):
    # blank out fenced code blocks (``` examples often contain pseudo-links)
    # while keeping their newlines, so reported line numbers stay true
    text = re.sub(r"```.*?```",
                  lambda m: "\n" * m.group(0).count("\n"),
                  md.read_text(), flags=re.DOTALL)
    for lineno, line in enumerate(text.splitlines(), 1):
        for m in LINK_RE.finditer(line):
            yield lineno, m.group(1)


def check_file(md: Path) -> list:
    broken = []
    for lineno, target in iter_links(md):
        if target.startswith(SKIP_PREFIXES):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        resolved = (md.parent / path).resolve()
        if not resolved.exists():
            broken.append((md, lineno, target))
    return broken


def main(argv) -> int:
    if argv:
        files = [Path(a) for a in argv]
    else:
        files = sorted((REPO_ROOT / "docs").glob("*.md"))
        files.append(REPO_ROOT / "README.md")
    missing_inputs = [f for f in files if not f.exists()]
    if missing_inputs:
        print(f"input files not found: {missing_inputs}", file=sys.stderr)
        return 2
    broken = []
    for f in files:
        broken.extend(check_file(f))
    for md, lineno, target in broken:
        print(f"{md.relative_to(REPO_ROOT)}:{lineno}: broken link -> {target}",
              file=sys.stderr)
    if broken:
        print(f"{len(broken)} broken intra-repo link(s)", file=sys.stderr)
        return 1
    print(f"checked {len(files)} file(s): all intra-repo links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
