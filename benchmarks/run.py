"""Benchmark harness entry point: one suite per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # full suite
    PYTHONPATH=src python -m benchmarks.run --quick    # reduced sizes
    PYTHONPATH=src python -m benchmarks.run --smoke    # tiny sizes (CI)
    PYTHONPATH=src python -m benchmarks.run --only fig3,roofline

``--smoke`` exists so CI can execute every figure script end-to-end (imports,
plumbing, derived-metric assertions) in a few minutes: request counts are
tiny, so the *numbers* are not publication-grade, but a figure script that
rots (API drift, broken assertion, stale import) fails the job instead of
failing the next person who needs the figure.

Prints a ``name,us_per_call,derived`` CSV summary at the end: ``us_per_call``
is the benchmark's own wall time in microseconds (what one evaluation of that
paper artifact costs on this container), ``derived`` the headline metric.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def _n(mode: str, full: int, quick: int, smoke: int) -> int:
    return {"full": full, "quick": quick, "smoke": smoke}[mode]


def _fig3(mode):
    from benchmarks import fig3_cpu_gpu_split as m
    rows = m.main(n=_n(mode, 100, 40, 12))
    return f"min_gpu_frac={min(r['gpu_frac'] for r in rows):.4f}"


def _fig6(mode):
    from benchmarks import fig6_accuracy as m
    rows = m.main(n=_n(mode, 30, 16, 6))
    worst = max(r["ttft_p50_err"] for r in rows)
    floor = max(r["real_noise_floor"] for r in rows)
    return f"worst_ttft_p50_err={worst:.4f},noise_floor={floor:.4f}"


def _fig7(mode):
    from benchmarks import fig7_speedup as m
    rows = m.main(n=_n(mode, 60, 30, 10))
    return (f"speedup={min(r['speedup_x'] for r in rows)}-"
            f"{max(r['speedup_x'] for r in rows)}x")


def _fig8(mode):
    from benchmarks import fig8_batch_duration as m
    rows = m.main(n=_n(mode, 50, 30, 10))
    return (f"max_speedup={max(r['speedup_x'] for r in rows)}x,"
            f"worst_err={max(r['ttft_p50_err'] for r in rows):.4f}")


def _fig9(mode):
    from benchmarks import fig9_arrival_rate as m
    rows = m.main(n=_n(mode, 40, 24, 10))
    return f"worst_ttft_p50_err={max(r['ttft_p50_err'] for r in rows):.4f}"


def _cluster(mode):
    from benchmarks import fig_cluster_scaling as m
    rows = m.main(n=_n(mode, 40, 24, 10))
    parity = rows[-1]
    best = max((r for r in rows[:-1]), key=lambda r: r.get("goodput_rps", 0))
    return (f"max_goodput_rps={best['goodput_rps']}@{best['replicas']}r/"
            f"{best['policy']},des_parity_err={parity['max_err_steps']}steps")


def _autoscale(mode):
    from benchmarks import fig_autoscale as m
    rows = m.main(n=_n(mode, 16, 10, 6))
    parity = rows[-1]
    # matched (cv2, slo) cell pair — same comparison fig_autoscale asserts on
    cv2, slo = m.BURSTINESS[-1], m.SLOS[-1]
    cell = {r["policy"]: r for r in rows[:-1]
            if r["cv2"] == cv2 and r["slo_ttft_s"] == slo}
    save = 1 - (cell["ttft_slo"]["replica_seconds"]
                / cell["fixed"]["replica_seconds"])
    return (f"ttft_slo_saves={save:.0%}_replica_seconds@cv2={cv2},"
            f"elastic_parity_err={parity['max_err_steps']}steps")


def _hetero(mode):
    from benchmarks import fig_hetero as m
    rows = m.main(n=_n(mode, 16, 10, 6))
    parity = rows[-1]
    fixed = next(r for r in rows if r.get("variant") == "fixed_6xh100")
    auto = next(r for r in rows if r.get("variant") == "tier_aware")
    save = 1 - auto["cost_dollars"] / fixed["cost_dollars"]
    return (f"tier_aware_saves={save:.0%}_dollars@equal_attainment,"
            f"hetero_parity_err={parity['max_err_steps']}steps")


def _distributed(mode):
    from benchmarks import fig_distributed as m
    rows = m.main(n=_n(mode, 40, 24, 10))
    parities = [r for r in rows if "max_err_steps" in r]
    proc = max((r for r in rows if r.get("backend") == "process"),
               key=lambda r: r["speedup_x"])
    return (f"process_speedup={proc['speedup_x']}x@{proc['replicas']}r,"
            f"parity_err={max(p['max_err_steps'] for p in parities)}steps,"
            f"decisions_equal={all(p['decisions_equal'] for p in parities)}")


def _chaos(mode):
    from benchmarks import fig_chaos as m
    rows = m.main(n=_n(mode, 40, 24, 10))
    worst = min(r["attainment"] for r in rows)
    return (f"worst_attainment={worst:.4f},"
            f"faults_equal={all(r['faults_equal'] for r in rows)},"
            f"max_parity_err={max(r['max_err_steps'] for r in rows)}steps")


def _emu_speed(mode):
    from benchmarks import fig_emu_speed as m
    m.main(n=_n(mode, 24, 12, 6),
           coord_steps=_n(mode, 400, 200, 120), mode=mode)
    import json
    doc = json.loads((m.REPO_ROOT / f"BENCH_{m.PR_NUMBER}.json").read_text())
    s = doc["summary"]
    return (f"batched_speedup_at_8={s['batched_speedup_at_8']}x,"
            f"max_events_per_s={s['max_events_per_s']:.0f},"
            f"max_virtual_per_wall={s['max_virtual_per_wall']}")


def _scale(mode):
    from benchmarks import fig_scale as m
    m.main(mode=mode)
    import json
    doc = json.loads((m.REPO_ROOT / f"BENCH_{m.PR_NUMBER}.json").read_text())
    s = doc["summary"]
    return (f"max_sessions={s['max_sessions']},"
            f"max_sessions_per_s={s['max_sessions_per_s']:.0f},"
            f"rss_ratio_thread={s['rss_ratio_thread']}x")


def _fleet(mode):
    from benchmarks import fig_fleet as m
    m.main(n=_n(mode, 64, 24, 12), mode=mode)
    import json
    doc = json.loads((m.REPO_ROOT / f"BENCH_{m.PR_NUMBER}.json").read_text())
    s = doc["summary"]
    return (f"replica_seconds_saving={s['replica_seconds_saving']:.0%},"
            f"attainment_mux={s['attainment_multiplexed']},"
            f"min_fairness={s['min_fairness']},"
            f"parity_err={doc['parity']['max_err_steps']}steps")


def _table1(mode):
    from benchmarks import table1_features as m
    rows = m.main()
    return f"features_ok={sum(1 for r in rows if r['supported'])}/{len(rows)}"


def _roofline(mode):
    from benchmarks import roofline as m
    rows = m.rows()
    if not rows:
        return "no_dryrun_artifacts"
    from benchmarks.common import emit, print_table
    print_table(rows)
    emit("roofline", rows)
    bounds = {}
    for r in rows:
        bounds[r["bound"]] = bounds.get(r["bound"], 0) + 1
    return f"cells={len(rows)}," + ",".join(
        f"{k}={v}" for k, v in sorted(bounds.items()))


SUITES = [
    ("fig3_cpu_gpu_split", _fig3),
    ("fig6_accuracy", _fig6),
    ("fig7_speedup", _fig7),
    ("fig8_batch_duration", _fig8),
    ("fig9_arrival_rate", _fig9),
    ("fig_cluster_scaling", _cluster),
    ("fig_autoscale", _autoscale),
    ("fig_hetero", _hetero),
    ("fig_distributed", _distributed),
    ("fig_chaos", _chaos),
    ("fig_emu_speed", _emu_speed),
    ("fig_scale", _scale),
    ("fig_fleet", _fleet),
    ("table1_features", _table1),
    ("roofline", _roofline),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny request counts: CI rot-check, not results")
    ap.add_argument("--only", default="",
                    help="comma-separated suite substrings")
    args = ap.parse_args()
    mode = "smoke" if args.smoke else ("quick" if args.quick else "full")
    only = [s for s in args.only.split(",") if s]

    results = []
    failed = []
    for name, fn in SUITES:
        if only and not any(o in name for o in only):
            continue
        print(f"\n=== {name} " + "=" * max(0, 60 - len(name)))
        t0 = time.time()
        try:
            derived = fn(mode)
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failed.append(name)
            derived = f"FAILED:{type(e).__name__}"
        us = (time.time() - t0) * 1e6
        results.append((name, us, derived))

    print("\nname,us_per_call,derived")
    for name, us, derived in results:
        print(f"{name},{us:.0f},{derived}")
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
