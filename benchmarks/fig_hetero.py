"""Heterogeneous pools — tier mix × routing policy × QPS: attainment vs cost.

The sweep the heterogeneous cluster layer exists for: mixed hardware pools
(H100 + L4 tiers with per-tier predictors, KV capacities, and
$/replica-second from ``repro.core.hardware``) under tier-aware routing
(``least_outstanding_tokens`` drain-time-normalized, ``cost_normalized_load``
pricing each placement) — all on one deterministic virtual timeline
(ManualWallSource), so every cell reproduces from its seed.

Three blocks:

1. **Static mix sweep** — tier-mix × routing policy × QPS on fixed pools;
   per cell: TTFT percentiles, SLO attainment, replica-seconds, and the
   dollar cost of the run.  The interesting read: at moderate load a
   half-L4 pool holds attainment at a fraction of the all-H100 pool's cost.
2. **Cost-aware autoscaling headline** — a peak-provisioned homogeneous
   4×H100 baseline vs a 2×H100 floor whose TTFT-SLO autoscaler *selects
   tiers*: each scale-up provisions the cheapest tier whose projected
   service TTFT fits the SLO (here: L4).  Asserted: the tier-aware policy
   matches the baseline's attainment (±2%) at no more dollar cost.
3. **Mixed-pool parity** — an H100+L4 pool scaling up mid-run under the
   tier-selecting autoscaler (scripted SchedulePolicy, default
   cheapest-tier selection), emulator vs DES sharing the same router /
   tier-spec / predictor objects; per-request latencies must agree within
   one (slow-tier) predictor step — the §2.3 semantic-gap argument
   extended to heterogeneous pools.
"""

from __future__ import annotations

import copy
import dataclasses

from benchmarks.common import emit, print_table
from repro.cluster import (Autoscaler, AutoscalerConfig, SchedulePolicy,
                           build_cluster, make_autoscaler_policy, make_router,
                           make_tier_specs)
from repro.configs import get_config
from repro.core.clock import ManualWallSource
from repro.core.predictor import StaticPredictor
from repro.des.simulator import DESConfig, DiscreteEventSimulator
from repro.serving.benchmark import BenchmarkRunner
from repro.serving.scheduler import EngineConfig
from repro.workload import SessionConfig, SessionWorkload, WorkloadConfig, synthesize

MAX_NUM_SEQS = 8
MAX_BATCHED_TOKENS = 512

# Per-tier step durations for the StaticPredictor instances: the H100 tier
# steps 2.5× faster than the L4 tier (roughly their bf16 roofline ratio for
# a small dense model), while costing ~6.9× more per hour — which is exactly
# the trade the cost-aware policies arbitrage.
BATCH_S = {"h100": 8e-3, "l4": 20e-3}
SLO_TTFT_S = 0.5

MIXES = {
    "4xh100": ["h100"] * 4,
    "2h100+2l4": ["h100", "h100", "l4", "l4"],
    "4xl4": ["l4"] * 4,
}
POLICIES = ["least_outstanding_tokens", "cost_normalized_load"]
QPS = [4.0, 10.0]


def _engine_cfg(prefix_caching: bool = True) -> EngineConfig:
    return EngineConfig(policy="vllm", max_num_seqs=MAX_NUM_SEQS,
                        max_batched_tokens=MAX_BATCHED_TOKENS, block_size=16,
                        num_blocks=16384,
                        enable_prefix_caching=prefix_caching)


def _tier_predictors():
    return {t: StaticPredictor(s) for t, s in BATCH_S.items()}


def _specs(ecfg):
    return make_tier_specs(get_config("llama3_8b"), ecfg,
                           list(BATCH_S), tier_predictors=_tier_predictors())


def _build(tiers, policy, ecfg=None):
    ecfg = ecfg or _engine_cfg()
    return build_cluster(get_config("llama3_8b"), ecfg, len(tiers),
                         policy=policy, tiers=list(tiers),
                         tier_predictors=_tier_predictors(),
                         tier_specs=_specs(ecfg), wall=ManualWallSource())


# =========================================================================
# 1. static tier-mix sweep
# =========================================================================

def measure_mix(mix: str, policy: str, qps: float, n: int) -> dict:
    reqs = synthesize(WorkloadConfig(
        num_requests=n, qps=qps, prompt_len_mean=180, output_len_mean=40,
        seed=13))
    cluster = _build(MIXES[mix], policy)
    try:
        res = BenchmarkRunner(cluster, reqs,
                              transport=cluster.transport).run(timeout=3600)
    finally:
        cluster.shutdown()
    return {
        "mix": mix,
        "policy": policy,
        "qps": qps,
        "requests": res.num_requests,
        "ttft_p50_ms": round(res.ttft.p50 * 1e3, 1),
        "ttft_p99_ms": round(res.ttft.p99 * 1e3, 1),
        "slo_attainment": round(res.slo_attainment(slo_ttft_s=SLO_TTFT_S), 4),
        "replica_seconds": round(res.replica_seconds, 2),
        "cost_dollars": round(res.cost_dollars, 6),
        "wall_s": round(res.wall_seconds, 2),
    }


# =========================================================================
# 2. cost-aware autoscaling: tier-selecting scale-up vs fixed 6xH100
# =========================================================================

# The autoscaler's *internal* scaling trigger is much tighter than the
# reported SLO: it reacts to 0.15 s TTFTs (burst queueing building up) and
# provisions the cheapest tier whose projected service TTFT fits inside that
# trigger — L4's 40 ms does — long before the reported 0.5 s SLO is at risk.
# That headroom is what lets the elastic pool match the peak-provisioned
# baseline's attainment while renting cheap burst capacity.
SCALE_TRIGGER_TTFT_S = 0.15
FIXED_REPLICAS = 6          # homogeneous-H100 peak provisioning
FLOOR_REPLICAS = 2          # tier-aware variant's always-on H100 floor


def _sessions(n: int) -> SessionWorkload:
    """Bursty chat sessions (gamma cv²=8): the traffic shape where renting
    cheap burst capacity beats paying for peak H100s."""
    return SessionWorkload(SessionConfig(
        num_sessions=n, qps=20.0, arrival="gamma",
        arrival_kwargs={"cv2": 8.0}, turns_mean=3.0, max_turns=5,
        think_time_mean=0.5, prompt_len_mean=200.0, followup_len_mean=60.0,
        output_len_mean=20.0, max_output_len=64, seed=13))


def measure_autoscale(variant: str, n: int) -> dict:
    # small per-replica slot counts so session bursts genuinely queue on the
    # elastic variant's floor (that queueing is the scaling signal)
    ecfg = dataclasses.replace(_engine_cfg(), max_num_seqs=2)
    fixed = variant == "fixed_6xh100"
    tiers = ["h100"] * (FIXED_REPLICAS if fixed else FLOOR_REPLICAS)
    cluster = _build(tiers, "least_outstanding_tokens", ecfg)
    autoscaler = None
    if not fixed:
        asc_cfg = AutoscalerConfig(
            interval_s=0.1, min_replicas=FLOOR_REPLICAS,
            max_replicas=FIXED_REPLICAS,
            provision_delay_s=0.5,
            tiers=("h100", "l4"),
            # cheaper chips are easier to get — and the delay is paid in
            # virtual time on both emulator and DES identically
            provision_delay_by_tier={"l4": 0.3, "h100": 0.5})
        autoscaler = Autoscaler(
            cluster,
            make_autoscaler_policy("ttft_slo",
                                   slo_ttft_s=SCALE_TRIGGER_TTFT_S,
                                   target_attainment=0.98, window_s=1.0),
            asc_cfg)
    try:
        res = BenchmarkRunner(cluster, _sessions(n),
                              transport=cluster.transport,
                              autoscaler=autoscaler).run(timeout=3600)
        tiers_added = [t for _, t in autoscaler.scaleups] if autoscaler else []
    finally:
        cluster.shutdown()
    return {
        "variant": variant,
        "sessions": res.num_sessions,
        "requests": res.num_requests,
        "ttft_p99_ms": round(res.ttft.p99 * 1e3, 1),
        "slo_attainment": round(res.slo_attainment(slo_ttft_s=SLO_TTFT_S), 4),
        "replica_seconds": round(res.replica_seconds, 2),
        "cost_dollars": round(res.cost_dollars, 6),
        "tiers_added": ",".join(t or "?" for t in tiers_added) or "-",
        "wall_s": round(res.wall_seconds, 2),
    }


# =========================================================================
# 3. mixed-pool emulator-vs-DES parity under tier-selecting scale-up
# =========================================================================

PARITY_EVENTS = [(0.3, +1)]
PARITY_TIERS = ["h100", "l4"]


def des_parity(n: int) -> dict:
    """H100+L4 pool, scripted tier-selecting scale-up mid-run (the default
    selection rule provisions the cheapest candidate: L4), emulator vs DES
    with the same router/spec/predictor objects (fresh instances per run —
    routers and policies are stateful)."""
    ecfg = _engine_cfg(prefix_caching=False)
    specs = _specs(ecfg)
    asc_cfg = AutoscalerConfig(interval_s=0.1, provision_delay_s=0.5,
                               min_replicas=2, max_replicas=3,
                               tiers=("h100", "l4"),
                               provision_delay_by_tier={"l4": 0.3})
    # arrival-bound regime: the parity question is whether heterogeneity
    # (per-tier step times + tier-selecting provisioning) introduces
    # divergence, not whether deep-overload batching cascades do
    reqs = synthesize(WorkloadConfig(
        num_requests=n, qps=4.0, prompt_len_mean=180, output_len_mean=40,
        seed=13))
    reqs_des = copy.deepcopy(reqs)

    cluster = _build(PARITY_TIERS, "round_robin", ecfg)
    asc = Autoscaler(cluster, SchedulePolicy(PARITY_EVENTS), asc_cfg)
    try:
        BenchmarkRunner(cluster, reqs, transport=cluster.transport,
                        autoscaler=asc).run(timeout=3600)
        emu_latency = {r.request_id: r.e2e_latency()
                       for r in cluster.finished}
        emu_tiers = list(cluster.replica_tiers)
    finally:
        cluster.shutdown()

    des = DiscreteEventSimulator(
        StaticPredictor(BATCH_S["h100"]),
        DESConfig(max_num_seqs=MAX_NUM_SEQS,
                  max_batched_tokens=MAX_BATCHED_TOKENS, step_overhead_s=0.0),
        num_replicas=2, router=make_router("round_robin", 2),
        autoscaler_policy=SchedulePolicy(PARITY_EVENTS),
        autoscaler_cfg=asc_cfg,
        replica_tiers=PARITY_TIERS, tier_predictors=_tier_predictors(),
        tier_specs=specs)
    sims = des.run(reqs_des)

    slow_step = max(BATCH_S.values())
    errs = [abs(emu_latency[orig.request_id]
                - (sim.finish_time - sim.arrival_time))
            for orig, sim in zip(reqs_des, sims)]
    return {
        "policy": "schedule(+1@0.3, tier-select)",
        "emu_completed": len(emu_latency),
        "des_completed": sum(1 for s in sims if s.finish_time is not None),
        "emu_tiers": ",".join(t or "?" for t in emu_tiers),
        "des_tiers": ",".join(r.tier or "?" for r in des.replicas),
        "max_err_steps": round(max(errs) / slow_step, 3),
        "mean_err_steps": round(sum(errs) / len(errs) / slow_step, 3),
    }


# =========================================================================

def rows(n: int = 16) -> list:
    return [measure_mix(m, p, q, n)
            for m in MIXES for p in POLICIES for q in QPS]


def main(n: int = 16) -> list:
    out = rows(n)
    print_table(out)

    # sized independently of the mix sweep: the scaling story needs enough
    # sessions for bursts to queue on the elastic floor
    n_scale = max(10, n)
    scale = [measure_autoscale("fixed_6xh100", n_scale),
             measure_autoscale("tier_aware", n_scale)]
    print_table(scale)
    parity = des_parity(max(8, n))
    print_table([parity])
    emit("fig_hetero", out + scale + [parity])

    # ---- parity: heterogeneity must not open an emulator/DES gap --------
    assert parity["emu_completed"] == parity["des_completed"], \
        "mixed-pool emulator/DES completed-request counts diverge"
    assert parity["emu_tiers"] == parity["des_tiers"], \
        (f"tier-selecting scale-up diverged: emu={parity['emu_tiers']} "
         f"des={parity['des_tiers']}")
    assert parity["max_err_steps"] <= 1.0, \
        f"mixed-pool emulator/DES diverges by {parity['max_err_steps']} steps"

    # ---- headline: cost-aware tier selection beats peak H100s -----------
    fixed, auto = scale
    assert auto["slo_attainment"] >= fixed["slo_attainment"] - 0.02, \
        (f"tier-aware attainment {auto['slo_attainment']} fell below "
         f"fixed-{FIXED_REPLICAS}xH100 {fixed['slo_attainment']}")
    assert auto["cost_dollars"] <= fixed["cost_dollars"], \
        (f"tier-aware cost ${auto['cost_dollars']} exceeds "
         f"fixed-{FIXED_REPLICAS}xH100 ${fixed['cost_dollars']}")
    added = [t for t in auto["tiers_added"].split(",") if t and t != "-"]
    assert added, "tier-aware autoscaler never scaled up (no burst pressure)"
    assert all(t == "l4" for t in added), \
        (f"tier selection should pick the cheap feasible tier, got {added}")
    saving = 1 - auto["cost_dollars"] / fixed["cost_dollars"]
    print(f"hetero: tier-aware autoscaling matches "
          f"fixed-{FIXED_REPLICAS}xH100 attainment "
          f"({auto['slo_attainment']:.1%} vs {fixed['slo_attainment']:.1%}) "
          f"at {saving:.0%} lower $-cost (scale-ups: {auto['tiers_added']}); "
          f"mixed-pool emu/DES parity max_err="
          f"{parity['max_err_steps']} steps")
    return out + scale + [parity]


if __name__ == "__main__":
    main()
