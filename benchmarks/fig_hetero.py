"""Heterogeneous pools — tier mix × routing policy × QPS: attainment vs cost.

The sweep the heterogeneous cluster layer exists for: mixed hardware pools
(H100 + L4 tiers with per-tier predictors, KV capacities, and
$/replica-second from ``repro.core.hardware``) under tier-aware routing —
every cell a :class:`~repro.scenario.Scenario` derived from the
``hetero_mix`` preset, the grid a :class:`~repro.scenario.Sweep` over
tier-mix × policy × QPS, all executed by :func:`repro.scenario.run` on the
deterministic thread backend.

Three blocks:

1. **Static mix sweep** — tier-mix × routing policy × QPS on fixed pools;
   per cell: TTFT percentiles, SLO attainment, replica-seconds, and the
   dollar cost of the run.  The interesting read: at moderate load a
   half-L4 pool holds attainment at a fraction of the all-H100 pool's cost.
2. **Cost-aware autoscaling headline** — a peak-provisioned homogeneous
   6×H100 baseline vs a 2×H100 floor whose TTFT-SLO autoscaler *selects
   tiers*: each scale-up provisions the cheapest tier whose projected
   service TTFT fits the SLO (here: L4).  Asserted: the tier-aware policy
   matches the baseline's attainment (±2%) at no more dollar cost.
3. **Mixed-pool parity** — an H100+L4 pool scaling up mid-run under the
   tier-selecting autoscaler (scripted schedule, default cheapest-tier
   selection), emulator vs DES through one :func:`repro.scenario.compare`
   call; per-request latencies must agree within one (slow-tier) predictor
   step — the §2.3 semantic-gap argument extended to heterogeneous pools.
"""

from __future__ import annotations

import dataclasses

from benchmarks.common import emit, print_table
from repro.scenario import (AutoscaleSpec, Sweep, compare, get_preset, run,
                            scenario_with)

# Per-tier step durations (see the hetero_mix preset): the H100 tier steps
# 2.5× faster than the L4 tier (roughly their bf16 roofline ratio for a
# small dense model), while costing ~6.9× more per hour — which is exactly
# the trade the cost-aware policies arbitrage.
BATCH_S = {"h100": 8e-3, "l4": 20e-3}
SLO_TTFT_S = 0.5

MIXES = {
    "4xh100": ["h100"] * 4,
    "2h100+2l4": ["h100", "h100", "l4", "l4"],
    "4xl4": ["l4"] * 4,
}
POLICIES = ["least_outstanding_tokens", "cost_normalized_load"]
QPS = [4.0, 10.0]


def _base(n: int):
    return scenario_with(get_preset("hetero_mix"),
                         **{"workload.num_requests": n,
                            "slo.ttft_s": SLO_TTFT_S})


def grid(n: int):
    """The static-mix cells: one Sweep over tiers × policy × QPS."""
    return Sweep(_base(n), {
        "pool.tiers": [MIXES[m] for m in MIXES],
        "routing.policy": POLICIES,
        "workload.qps": QPS,
    }).expand()


_MIX_NAME = {tuple(v): k for k, v in MIXES.items()}


def measure_mix(scenario) -> dict:
    res = run(scenario, backend="thread", timeout=3600)
    return {
        "mix": _MIX_NAME[tuple(scenario.pool.tiers)],
        "policy": scenario.routing.policy,
        "qps": scenario.workload.qps,
        "requests": res.num_requests,
        "ttft_p50_ms": round(res.ttft.p50 * 1e3, 1),
        "ttft_p99_ms": round(res.ttft.p99 * 1e3, 1),
        "slo_attainment": round(res.slo_attainment(), 4),
        "replica_seconds": round(res.replica_seconds, 2),
        "cost_dollars": round(res.cost_dollars, 6),
        "wall_s": round(res.wall_seconds, 2),
    }


# =========================================================================
# 2. cost-aware autoscaling: tier-selecting scale-up vs fixed 6xH100
# =========================================================================

# The autoscaler's *internal* scaling trigger is much tighter than the
# reported SLO: it reacts to 0.15 s TTFTs (burst queueing building up) and
# provisions the cheapest tier whose projected service TTFT fits inside that
# trigger — L4's 40 ms does — long before the reported 0.5 s SLO is at risk.
# That headroom is what lets the elastic pool match the peak-provisioned
# baseline's attainment while renting cheap burst capacity.
SCALE_TRIGGER_TTFT_S = 0.15
FIXED_REPLICAS = 6          # homogeneous-H100 peak provisioning
FLOOR_REPLICAS = 2          # tier-aware variant's always-on H100 floor


def autoscale_scenario(variant: str, n: int):
    """Bursty chat sessions (gamma cv²=8) — the traffic shape where renting
    cheap burst capacity beats paying for peak H100s.  Small per-replica
    slot counts so session bursts genuinely queue on the elastic variant's
    floor (that queueing is the scaling signal)."""
    fixed = variant == "fixed_6xh100"
    replicas = FIXED_REPLICAS if fixed else FLOOR_REPLICAS
    s = scenario_with(
        get_preset("hetero_mix"),
        name=f"hetero_autoscale[{variant}]",
        **{"workload.kind": "sessions",
           "workload.qps": 20.0,
           "workload.arrival": "gamma",
           "workload.arrival_kwargs": {"cv2": 8.0},
           "workload.num_sessions": n,
           "workload.turns_mean": 3.0, "workload.max_turns": 5,
           "workload.think_time_mean": 0.5,
           "workload.prompt_len_mean": 200.0,
           "workload.followup_len_mean": 60.0,
           "workload.output_len_mean": 20.0,
           "workload.max_output_len": 64,
           "pool.max_num_seqs": 2,
           "pool.replicas": replicas,
           "pool.tiers": ["h100"],
           "routing.policy": "least_outstanding_tokens"})
    if fixed:
        return s
    return dataclasses.replace(s, autoscale=AutoscaleSpec(
        policy="ttft_slo",
        kwargs={"slo_ttft_s": SCALE_TRIGGER_TTFT_S,
                "target_attainment": 0.98, "window_s": 1.0},
        interval_s=0.1, min_replicas=FLOOR_REPLICAS,
        max_replicas=FIXED_REPLICAS,
        provision_delay_s=0.5,
        tiers=("h100", "l4"),
        # cheaper chips are easier to get — and the delay is paid in
        # virtual time on both emulator and DES identically
        provision_delay_by_tier={"l4": 0.3, "h100": 0.5}))


def measure_autoscale(variant: str, n: int) -> dict:
    res = run(autoscale_scenario(variant, n), backend="thread", timeout=3600)
    return {
        "variant": variant,
        "sessions": res.num_sessions,
        "requests": res.num_requests,
        "ttft_p99_ms": round(res.ttft.p99 * 1e3, 1),
        "slo_attainment": round(res.slo_attainment(), 4),
        "replica_seconds": round(res.replica_seconds, 2),
        "cost_dollars": round(res.cost_dollars, 6),
        "tiers_added": ",".join(t or "?" for t in res.tiers_added) or "-",
        "wall_s": round(res.wall_seconds, 2),
    }


# =========================================================================
# 3. mixed-pool emulator-vs-DES parity under tier-selecting scale-up
# =========================================================================

PARITY_EVENTS = ((0.3, 1),)
PARITY_TIERS = ("h100", "l4")


def des_parity(n: int) -> dict:
    """H100+L4 pool, scripted tier-selecting scale-up mid-run (the default
    selection rule provisions the cheapest candidate: L4), emulator vs DES
    through one ``compare`` call — same scenario, fresh router/spec/policy
    objects per backend by construction.

    Arrival-bound regime: the parity question is whether heterogeneity
    (per-tier step times + tier-selecting provisioning) introduces
    divergence, not whether deep-overload batching cascades do."""
    scenario = scenario_with(
        _base(n), name="hetero_parity",
        **{"workload.qps": 4.0,
           "pool.replicas": 2,
           "pool.tiers": list(PARITY_TIERS),
           "pool.enable_prefix_caching": False,
           "routing.policy": "round_robin",
           "autoscale": {
               "policy": "schedule",
               "schedule": [list(e) for e in PARITY_EVENTS],
               "interval_s": 0.1, "provision_delay_s": 0.5,
               "min_replicas": 2, "max_replicas": 3,
               "tiers": ["h100", "l4"],
               "provision_delay_by_tier": {"l4": 0.3}}})
    cres = compare(scenario, backends=("thread", "des"), timeout=3600)
    emu, des = cres.results["thread"], cres.results["des"]
    return {
        "policy": "schedule(+1@0.3, tier-select)",
        "emu_completed": emu.num_requests,
        "des_completed": des.num_requests,
        "emu_tiers": ",".join(t or "?" for t in emu.replica_tiers),
        "des_tiers": ",".join(t or "?" for t in des.replica_tiers),
        "max_err_steps": round(cres.max_err_steps, 3),
    }


# =========================================================================

def rows(n: int = 16) -> list:
    return [measure_mix(s) for s in grid(n)]


def main(n: int = 16) -> list:
    out = rows(n)
    print_table(out)

    # sized independently of the mix sweep: the scaling story needs enough
    # sessions for bursts to queue on the elastic floor
    n_scale = max(10, n)
    scale = [measure_autoscale("fixed_6xh100", n_scale),
             measure_autoscale("tier_aware", n_scale)]
    print_table(scale)
    parity = des_parity(max(8, n))
    print_table([parity])
    emit("fig_hetero", out + scale + [parity])

    # ---- parity: heterogeneity must not open an emulator/DES gap --------
    assert parity["emu_completed"] == parity["des_completed"], \
        "mixed-pool emulator/DES completed-request counts diverge"
    assert parity["emu_tiers"] == parity["des_tiers"], \
        (f"tier-selecting scale-up diverged: emu={parity['emu_tiers']} "
         f"des={parity['des_tiers']}")
    assert parity["max_err_steps"] <= 1.0, \
        f"mixed-pool emulator/DES diverges by {parity['max_err_steps']} steps"

    # ---- headline: cost-aware tier selection beats peak H100s -----------
    fixed, auto = scale
    assert auto["slo_attainment"] >= fixed["slo_attainment"] - 0.02, \
        (f"tier-aware attainment {auto['slo_attainment']} fell below "
         f"fixed-{FIXED_REPLICAS}xH100 {fixed['slo_attainment']}")
    assert auto["cost_dollars"] <= fixed["cost_dollars"], \
        (f"tier-aware cost ${auto['cost_dollars']} exceeds "
         f"fixed-{FIXED_REPLICAS}xH100 ${fixed['cost_dollars']}")
    added = [t for t in auto["tiers_added"].split(",") if t and t != "-"]
    assert added, "tier-aware autoscaler never scaled up (no burst pressure)"
    assert all(t == "l4" for t in added), \
        (f"tier selection should pick the cheap feasible tier, got {added}")
    saving = 1 - auto["cost_dollars"] / fixed["cost_dollars"]
    print(f"hetero: tier-aware autoscaling matches "
          f"fixed-{FIXED_REPLICAS}xH100 attainment "
          f"({auto['slo_attainment']:.1%} vs {fixed['slo_attainment']:.1%}) "
          f"at {saving:.0%} lower $-cost (scale-ups: {auto['tiers_added']}); "
          f"mixed-pool emu/DES parity max_err="
          f"{parity['max_err_steps']} steps")
    return out + scale + [parity]


if __name__ == "__main__":
    main()
