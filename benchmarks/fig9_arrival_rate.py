"""Figs. 9-10 — accuracy & speedup vs request arrival rate (0.5-8 QPS).

The paper fixes batch time at 20 ms (excluding predictor error) and sweeps
Poisson arrival rates; Revati holds <5% TTFT error across the board while
speedup shrinks slightly at high load (more CPU work per virtual second).

Derived: ttft_p50_err and speedup_x per QPS.
"""

from __future__ import annotations

from benchmarks.common import emit, print_table, sharegpt_workload, run_stack
from repro.configs import get_config
from repro.core.predictor import StaticPredictor
from repro.serving.benchmark import compare_distributions
from repro.serving.scheduler import EngineConfig

QPS_SWEEP = [0.5, 1.0, 2.0, 4.0, 8.0]
BATCH_S = 20e-3                      # fixed, per the paper's setup


def measure(qps: float, n: int = 40) -> dict:
    cfg = get_config("llama3_8b")
    ecfg = EngineConfig(policy="vllm", max_num_seqs=64,
                        max_batched_tokens=512, block_size=16,
                        num_blocks=32768, chip="h200-sxm")
    pred = StaticPredictor(BATCH_S)
    reqs = lambda: sharegpt_workload(n=n, qps=qps, seed=3,
                                     prompt_len_mean=180, output_len_mean=40)
    res_sleep = run_stack(cfg, ecfg, "sleep", reqs(), predictor=pred,
                          timeout=3600)
    res_emu = run_stack(cfg, ecfg, "emulate", reqs(), predictor=pred,
                        use_worker_group=False)
    ttft = compare_distributions(res_sleep.ttft, res_emu.ttft)
    return {
        "qps": qps,
        "ttft_p50_err": round(ttft["median_rel_err"], 4),
        "ttft_p99_err": round(ttft["p99_rel_err"], 4),
        "sleep_wall_s": round(res_sleep.wall_seconds, 2),
        "emu_wall_s": round(res_emu.wall_seconds, 2),
        "speedup_x": round(res_sleep.wall_seconds
                           / max(res_emu.wall_seconds, 1e-9), 1),
    }


def rows(n: int = 40) -> list:
    return [measure(q, n) for q in QPS_SWEEP]


def main(n: int = 40) -> list:
    out = rows(n)
    print_table(out)
    emit("fig9_arrival_rate", out)
    print("fig9/10: <5% TTFT error across rates; speedup dips slightly at "
          "high QPS (more CPU work per virtual second) — paper §6.3")
    return out


if __name__ == "__main__":
    main()
