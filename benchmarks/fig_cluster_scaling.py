"""Cluster scaling — replicas × routing policy × arrival rate, GPU-free.

The sweep the multi-replica layer exists for: a data-parallel deployment
grid evaluated entirely under time-warp emulation.  For each cell we report
cluster-level TTFT/TPOT percentiles, completed-request goodput, and the
emulation speedup; a parity column cross-checks the 2-replica emulator
against the 2-replica DES baseline sharing the *same* Router policy
(completed counts must match; per-request virtual finish latencies must
agree within the predictor's step granularity — the §2.3 semantic-gap
argument extended to cluster scale).

Derived: max per-request emulator/DES divergence (in predictor steps) and
the goodput scaling from 1 -> max replicas.
"""

from __future__ import annotations

import copy

from benchmarks.common import emit, print_table, sharegpt_workload
from repro.cluster import build_cluster, make_router
from repro.configs import get_config
from repro.core.clock import ManualWallSource
from repro.core.predictor import StaticPredictor
from repro.des.simulator import DESConfig, DiscreteEventSimulator
from repro.serving.benchmark import BenchmarkRunner
from repro.serving.scheduler import EngineConfig

REPLICAS = [1, 2, 4]
POLICIES = ["round_robin", "prefix_affinity"]
# One replica completes ~max_num_seqs/(output_len steps) ≈ 9.7 rps at 20 ms
# steps: the low rate is arrival-bound (parity regime), the high rate
# overloads a single replica ~2.5x so replica scaling shows up in TTFT tail
# and SLO goodput.
QPS = [4.0, 24.0]
BATCH_S = 20e-3
SLO_TTFT_S = 1.0

MAX_NUM_SEQS = 8
MAX_BATCHED_TOKENS = 512


def _engine_cfg(prefix_caching: bool = True) -> EngineConfig:
    return EngineConfig(policy="vllm", max_num_seqs=MAX_NUM_SEQS,
                        max_batched_tokens=MAX_BATCHED_TOKENS, block_size=16,
                        num_blocks=16384, chip="h200-sxm",
                        enable_prefix_caching=prefix_caching)


def _workload(n, qps, policy):
    # prefix_affinity cells use a shared system prompt so affinity has
    # something to exploit; round_robin cells use fully distinct prompts.
    shared = 64 if policy == "prefix_affinity" else 0
    return sharegpt_workload(n=n, qps=qps, seed=13, prompt_len_mean=180,
                             output_len_mean=40, shared_prefix_len=shared)


def measure(num_replicas: int, policy: str, qps: float, n: int) -> dict:
    model_cfg = get_config("llama3_8b")
    cluster = build_cluster(model_cfg, _engine_cfg(), num_replicas,
                            policy=policy, predictor=StaticPredictor(BATCH_S))
    try:
        res = BenchmarkRunner(cluster, _workload(n, qps, policy),
                              transport=cluster.transport).run(timeout=3600)
    finally:
        cluster.shutdown()
    return {
        "replicas": num_replicas,
        "policy": policy,
        "qps": qps,
        "ttft_p50_ms": round(res.ttft.p50 * 1e3, 1),
        "ttft_p99_ms": round(res.ttft.p99 * 1e3, 1),
        "tpot_p50_ms": round(res.tpot.p50 * 1e3, 2),
        "goodput_rps": round(res.goodput_rps(slo_ttft_s=SLO_TTFT_S), 3),
        "completed_rps": round(res.request_rate_completed, 3),
        "virtual_s": round(res.makespan_virtual, 1),
        "wall_s": round(res.wall_seconds, 2),
        "speedup_x": round(res.speedup, 1),
    }


def des_parity(n: int, qps: float = 4.0) -> dict:
    """2-replica emulator vs 2-replica DES, same router policy + predictor.

    A ManualWallSource pins the emulator timeline to pure jump arithmetic so
    the comparison isolates engine semantics (no wall-rate CPU absorption).
    """
    model_cfg = get_config("llama3_8b")
    reqs = _workload(n, qps, "round_robin")
    reqs_des = copy.deepcopy(reqs)

    cluster = build_cluster(model_cfg, _engine_cfg(prefix_caching=False), 2,
                            policy="round_robin",
                            predictor=StaticPredictor(BATCH_S),
                            wall=ManualWallSource())
    try:
        res = BenchmarkRunner(cluster, reqs,
                              transport=cluster.transport).run(timeout=3600)
        emu_latency = {r.request_id: r.e2e_latency()
                       for r in cluster.finished}
    finally:
        cluster.shutdown()

    sims = DiscreteEventSimulator(
        StaticPredictor(BATCH_S),
        DESConfig(max_num_seqs=MAX_NUM_SEQS,
                  max_batched_tokens=MAX_BATCHED_TOKENS,
                  step_overhead_s=0.0),
        num_replicas=2, router=make_router("round_robin", 2)).run(reqs_des)

    des_done = sum(1 for s in sims if s.finish_time is not None)
    errs = [abs(emu_latency[orig.request_id]
                - (sim.finish_time - sim.arrival_time))
            for orig, sim in zip(reqs_des, sims)]
    return {
        "replicas": 2,
        "policy": "round_robin",
        "qps": qps,
        "emu_completed": len(emu_latency),
        "des_completed": des_done,
        "max_err_steps": round(max(errs) / BATCH_S, 3),
        "mean_err_steps": round(sum(errs) / len(errs) / BATCH_S, 3),
    }


def rows(n: int = 40) -> list:
    out = [measure(r, p, q, n)
           for r in REPLICAS for p in POLICIES for q in QPS]
    return out


def main(n: int = 40) -> list:
    out = rows(n)
    print_table(out)
    parity = des_parity(n)
    print_table([parity])
    emit("fig_cluster_scaling", out + [parity])
    assert parity["emu_completed"] == parity["des_completed"], \
        "emulator/DES completed-request counts diverge"
    assert parity["max_err_steps"] <= 1.0, \
        f"emulator/DES finish times diverge by {parity['max_err_steps']} steps"
    lo = [r for r in out if r["policy"] == "round_robin" and r["qps"] == QPS[-1]]
    g1 = next(r["completed_rps"] for r in lo if r["replicas"] == 1)
    gN = next(r["completed_rps"] for r in lo if r["replicas"] == max(REPLICAS))
    print(f"cluster scaling: completed-rps x{gN / max(g1, 1e-9):.2f} from "
          f"1 -> {max(REPLICAS)} replicas at {QPS[-1]} QPS; "
          f"emulator/DES parity max_err={parity['max_err_steps']} steps")
    return out + [parity]


if __name__ == "__main__":
    main()
