"""Cluster scaling — replicas × routing policy × arrival rate, GPU-free.

The sweep the multi-replica layer exists for: a data-parallel deployment
grid evaluated entirely under time-warp emulation.  The grid is **data**:
one base :class:`~repro.scenario.Scenario` (the ``cluster_scaling`` preset)
expanded through a :class:`~repro.scenario.Sweep` over replicas × policy ×
QPS, every cell executed by the one :func:`repro.scenario.run` entry point.
For each cell we report cluster-level TTFT/TPOT percentiles,
completed-request goodput, and the emulation speedup; the parity block is a
:func:`repro.scenario.compare` of the same 2-replica scenario on the
thread emulator vs the DES baseline (completed counts must match;
per-request latencies must agree within the predictor's step granularity —
the §2.3 semantic-gap argument extended to cluster scale).

Derived: max emulator/DES divergence (in predictor steps) and the goodput
scaling from 1 -> max replicas.
"""

from __future__ import annotations

from benchmarks.common import emit, print_table
from repro.scenario import Sweep, compare, get_preset, run, scenario_with

REPLICAS = [1, 2, 4]
POLICIES = ["round_robin", "prefix_affinity"]
# One replica completes ~max_num_seqs/(output_len steps) ≈ 9.7 rps at 20 ms
# steps: the low rate is arrival-bound (parity regime), the high rate
# overloads a single replica ~2.5x so replica scaling shows up in TTFT tail
# and SLO goodput.
QPS = [4.0, 24.0]
SLO_TTFT_S = 1.0


def _base(n: int):
    return scenario_with(get_preset("cluster_scaling"),
                         **{"workload.num_requests": n,
                            "slo.ttft_s": SLO_TTFT_S})


def grid(n: int):
    """The figure's cells as scenarios: a Sweep grid, plus the one
    policy-coupled tweak (prefix_affinity cells share a system prompt so
    affinity has something to exploit)."""
    cells = Sweep(_base(n), {
        "pool.replicas": REPLICAS,
        "routing.policy": POLICIES,
        "workload.qps": QPS,
    }).expand()
    return [
        scenario_with(s, **{"workload.shared_prefix_len": 64})
        if s.routing.policy == "prefix_affinity" else s
        for s in cells
    ]


def measure(scenario) -> dict:
    res = run(scenario, backend="thread", timeout=3600)
    return {
        "replicas": scenario.pool.replicas,
        "policy": scenario.routing.policy,
        "qps": scenario.workload.qps,
        "ttft_p50_ms": round(res.ttft.p50 * 1e3, 1),
        "ttft_p99_ms": round(res.ttft.p99 * 1e3, 1),
        "tpot_p50_ms": round(res.tpot.p50 * 1e3, 2),
        "goodput_rps": round(res.goodput_rps(), 3),
        "completed_rps": round(res.request_rate_completed, 3),
        "virtual_s": round(res.makespan_virtual, 1),
        "wall_s": round(res.wall_seconds, 2),
        "speedup_x": round(res.speedup, 1),
    }


def des_parity(n: int, qps: float = 4.0) -> dict:
    """2-replica emulator vs 2-replica DES through ``compare``: same
    scenario JSON, same router/predictor arithmetic by construction (the
    thread backend runs on a ManualWallSource, so the comparison isolates
    engine semantics — no wall-rate CPU absorption)."""
    scenario = scenario_with(
        _base(n), name="cluster_scaling_parity",
        **{"workload.qps": qps, "pool.replicas": 2,
           "pool.enable_prefix_caching": False,
           "routing.policy": "round_robin"})
    cres = compare(scenario, backends=("thread", "des"), timeout=3600)
    return {
        "replicas": 2,
        "policy": "round_robin",
        "qps": qps,
        "emu_completed": cres.results["thread"].num_requests,
        "des_completed": cres.results["des"].num_requests,
        "decisions_equal": cres.decisions_equal,
        "max_err_steps": round(cres.max_err_steps, 3),
        "ttft_err_steps": round(cres.max_ttft_err_s / cres.slow_step_s, 3),
    }


def rows(n: int = 40) -> list:
    return [measure(s) for s in grid(n)]


def main(n: int = 40) -> list:
    out = rows(n)
    print_table(out)
    parity = des_parity(n)
    print_table([parity])
    emit("fig_cluster_scaling", out + [parity])
    assert parity["emu_completed"] == parity["des_completed"], \
        "emulator/DES completed-request counts diverge"
    assert parity["max_err_steps"] <= 1.0, \
        f"emulator/DES finish times diverge by {parity['max_err_steps']} steps"
    lo = [r for r in out if r["policy"] == "round_robin" and r["qps"] == QPS[-1]]
    g1 = next(r["completed_rps"] for r in lo if r["replicas"] == 1)
    gN = next(r["completed_rps"] for r in lo if r["replicas"] == max(REPLICAS))
    print(f"cluster scaling: completed-rps x{gN / max(g1, 1e-9):.2f} from "
          f"1 -> {max(REPLICAS)} replicas at {QPS[-1]} QPS; "
          f"emulator/DES parity max_err={parity['max_err_steps']} steps")
    return out + [parity]


if __name__ == "__main__":
    main()
