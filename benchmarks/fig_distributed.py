"""Distributed time-warp emulation — replica count × transport backend.

The experiment the process-mode runtime exists for: the *same* cluster
deployment evaluated with replicas as in-process threads
(``backend="thread"``, the PR-1 runtime) and as OS processes wired to the
Timekeeper over framed TCP (``backend="process"``, the paper's §5
deployment shape).  For each cell we report cluster-level TTFT/TPOT
percentiles, virtual makespan, wall time, and the emulation speedup
(virtual seconds per wall second) — the speedup column is the headline:
coordinating real processes over sockets still runs the timeline orders of
magnitude faster than wall-clock sleeping would.

Parity is the acceptance bar (the repo's analogue of the paper's
distributed-causality claim): a same-seed workload driven through both
backends must produce **identical routing decisions** and per-request
TTFT / TPOT within **one slow-step** (the deliberately coarse predictor
step, so bounded wall-rate absorption — socket round trips run at wall
rate under Eq. 1 — cannot masquerade as a semantic difference; a single
admission-boundary slip costs strictly less than one step by
construction).
A second parity cell runs a closed-loop session workload with the
autoscaler enabled (scripted scale-up + drain over a warm process pool):
per-turn placements and latencies must again agree, proving the
cross-process completion-listener path and wire-level add/drain preserve
the closed-loop causality invariant.
"""

from __future__ import annotations

import argparse

from benchmarks.common import emit, print_table, sharegpt_workload
from repro.cluster import (Autoscaler, AutoscalerConfig, SchedulePolicy,
                           build_cluster)
from repro.configs import get_config
from repro.core.predictor import StaticPredictor
from repro.serving.benchmark import BenchmarkRunner
from repro.serving.scheduler import EngineConfig
from repro.workload import SessionConfig, SessionWorkload

BACKENDS = ["thread", "process"]
REPLICAS = [1, 2, 4]
QPS = 6.0
# One deliberately slow predictor step: socket round trips and engine CPU
# absorb wall time into the virtual timeline (Eq. 1) on the process
# backend; the parity bar is "within one of these".  Sized so that even a
# noisy shared CI machine's wall-rate absorption stays well inside a step.
SLOW_STEP_S = 100e-3
MAX_NUM_SEQS = 8
MAX_BATCHED_TOKENS = 512


def _engine_cfg() -> EngineConfig:
    return EngineConfig(policy="vllm", max_num_seqs=MAX_NUM_SEQS,
                        max_batched_tokens=MAX_BATCHED_TOKENS, block_size=16,
                        num_blocks=16384, chip="h200-sxm",
                        enable_prefix_caching=False)


def _workload(n: int, qps: float = QPS):
    return sharegpt_workload(n=n, qps=qps, seed=17, prompt_len_mean=150,
                             output_len_mean=8, max_output_len=12)


def _build(backend: str, replicas: int, *, warm: int = 0):
    return build_cluster(get_config("llama3_8b"), _engine_cfg(), replicas,
                         policy="round_robin",
                         predictor=StaticPredictor(SLOW_STEP_S),
                         backend=backend,
                         warm_replicas=warm or None)


def _run(backend: str, replicas: int, n: int, *, workload=None, qps: float = QPS,
         autoscaler_events=None, warm: int = 0):
    """One cell: returns (BenchmarkResult, decisions, placements, latencies).

    ``latencies``: per-request (ttft, e2e) keyed by submit order for open
    loop, by (session_id, turn_index) for closed loop."""
    cluster = _build(backend, replicas, warm=warm)
    asc = None
    if autoscaler_events is not None:
        asc = Autoscaler(cluster, SchedulePolicy(autoscaler_events),
                         AutoscalerConfig(interval_s=0.1,
                                          provision_delay_s=0.1,
                                          min_replicas=1,
                                          max_replicas=replicas + 1))
    try:
        reqs = workload if workload is not None else _workload(n, qps)
        res = BenchmarkRunner(cluster, reqs, transport=cluster.transport,
                              autoscaler=asc).run(timeout=3600)

        def sample(r):
            # the parity quantities of the acceptance bar: TTFT and TPOT
            return (r.ttft(), r.tpot() if r.num_generated > 1 else 0.0)

        if getattr(reqs, "initial_requests", None) is not None:
            lat = {(r.session_id, r.turn_index): sample(r)
                   for r in cluster.finished}
            placements = {(s, t): idx for s, t, _, idx in cluster.placements}
        else:
            ordered = sorted(cluster.finished, key=lambda r: r.arrival_time)
            lat = {k: sample(r) for k, r in enumerate(ordered)}
            placements = list(cluster.router.decisions)
        decisions = list(cluster.router.decisions)
        drained = [m["replica"] for m in cluster.membership_events()
                   if m["drained"] is not None]
        return res, decisions, placements, lat, drained
    finally:
        cluster.shutdown()


def measure(backend: str, replicas: int, n: int) -> dict:
    res, _, _, _, _ = _run(backend, replicas, n)
    return {
        "backend": backend,
        "replicas": replicas,
        "qps": QPS,
        "ttft_p50_ms": round(res.ttft.p50 * 1e3, 1),
        "ttft_p99_ms": round(res.ttft.p99 * 1e3, 1),
        "tpot_p50_ms": round(res.tpot.p50 * 1e3, 2),
        "completed": res.num_requests,
        "virtual_s": round(res.makespan_virtual, 2),
        "wall_s": round(res.wall_seconds, 2),
        "speedup_x": round(res.speedup, 1),
    }


def _latency_errs(lat_a: dict, lat_b: dict):
    """Max per-request |TTFT| and |TPOT| difference between two backends.

    These are the acceptance-bar quantities: a single admission-boundary
    slip bounds the TTFT difference by *strictly less than* one step
    (step − arrival shift), and TPOT spreads any absorbed wall time over
    the whole decode, so both stay inside one slow-step by construction —
    unlike raw e2e, which accumulates absorption over every round."""
    assert lat_a.keys() == lat_b.keys(), "backends completed different sets"
    ttft_err = max(abs(lat_a[k][0] - lat_b[k][0]) for k in lat_a)
    tpot_err = max(abs(lat_a[k][1] - lat_b[k][1]) for k in lat_a)
    return ttft_err, tpot_err


def parity(replicas: int, n: int) -> dict:
    """Same seed through both backends: identical routing decisions,
    per-request TTFT/TPOT within one slow-step.

    The parity cells use *deterministically spaced* arrivals with headroom
    over the per-request service time, unlike the Poisson measure cells.
    The reason is principled, not cosmetic: when a request arrives at a
    busy replica, its admission quantizes to a step boundary, and the
    few-ms wall-rate shift between backends can flip which step admits it
    — a full slow-step of TTFT difference from a millisecond of absorbed
    wall time.  With every arrival landing on an idle replica, service
    starts continuously (no boundary to flip), so the comparison measures
    exactly what it should: coordination + transport semantics, with
    wall-rate absorption bounded at a fraction of a step."""
    n = min(n, 12)

    def spaced():
        reqs = sharegpt_workload(n=n, qps=1.0, seed=17, prompt_len_mean=150,
                                 output_len_mean=4, max_output_len=5)
        for i, r in enumerate(reqs):
            r.arrival_time = 0.35 * i     # > service/replicas: no queueing
        return reqs

    _, dec_t, _, lat_t, _ = _run("thread", replicas, n, workload=spaced())
    _, dec_p, _, lat_p, _ = _run("process", replicas, n, workload=spaced())
    ttft_err, tpot_err = _latency_errs(lat_t, lat_p)
    return {
        "cell": f"parity_{replicas}r",
        "replicas": replicas,
        "decisions_equal": dec_t == dec_p,
        "ttft_err_steps": round(ttft_err / SLOW_STEP_S, 3),
        "tpot_err_steps": round(tpot_err / SLOW_STEP_S, 3),
        "max_err_steps": round(max(ttft_err, tpot_err) / SLOW_STEP_S, 3),
    }


def session_autoscale_parity(n_sessions: int) -> dict:
    """Closed-loop sessions + autoscaler (scale up at 0.7s, drain at 1.8s of
    virtual time — both inside the measured window, not the teardown race)
    through both backends: per-turn placements identical, latencies within
    one slow-step, same drain victim.  The process side activates a warm
    standby child, so scale-up pays only the *modeled* provisioning delay.
    Like :func:`parity`, the cell is sized to mild queueing (two base
    replicas, short turns) so accumulated wall-rate absorption on a loaded
    CI machine stays well inside the one-slow-step bar."""
    events = [(0.7, +1), (1.8, -1)]

    def sessions():
        sw = SessionWorkload(SessionConfig(
            num_sessions=n_sessions, qps=1.0, turns_mean=2.0, max_turns=3,
            think_time_mean=0.8, prompt_len_mean=80, followup_len_mean=30,
            output_len_mean=4, max_output_len=5, seed=23))
        # Deterministically spaced session starts, for the same reason the
        # open-loop parity cell spaces arrivals (see `parity`): turns that
        # land on idle replicas start service continuously, so a few ms of
        # cross-backend wall absorption cannot flip a step-boundary
        # admission and masquerade as a one-step semantic difference.
        for i, s in enumerate(sw.sessions):
            s.arrival_time = 0.5 * i
        return sw

    _, _, pl_t, lat_t, dr_t = _run("thread", 2, 0, workload=sessions(),
                                   autoscaler_events=events)
    _, _, pl_p, lat_p, dr_p = _run("process", 2, 0, workload=sessions(),
                                   autoscaler_events=events, warm=3)
    ttft_err, tpot_err = _latency_errs(lat_t, lat_p)
    return {
        "cell": "session_autoscale_parity",
        "replicas": 2,
        "decisions_equal": pl_t == pl_p,
        "drain_victims_equal": dr_t == dr_p,
        "scaled_and_drained": bool(dr_t),
        "turns": len(lat_t),
        "ttft_err_steps": round(ttft_err / SLOW_STEP_S, 3),
        "tpot_err_steps": round(tpot_err / SLOW_STEP_S, 3),
        "max_err_steps": round(max(ttft_err, tpot_err) / SLOW_STEP_S, 3),
    }


def rows(n: int = 40) -> list:
    return [measure(b, r, n) for b in BACKENDS for r in REPLICAS]


def main(n: int = 40) -> list:
    out = rows(n)
    print_table(out)
    parities = [parity(r, n) for r in (2,)]
    parities.append(session_autoscale_parity(max(4, n // 8)))
    print_table(parities, cols=["cell", "replicas", "decisions_equal",
                                "ttft_err_steps", "tpot_err_steps",
                                "max_err_steps"])
    emit("fig_distributed", out + parities)

    for p in parities:
        assert p["decisions_equal"], \
            f"{p['cell']}: routing decisions diverge between backends"
        assert p["max_err_steps"] <= 1.0, \
            (f"{p['cell']}: thread/process latencies diverge by "
             f"{p['max_err_steps']} slow-steps")
    sess = parities[-1]
    assert sess["scaled_and_drained"], \
        "autoscaler cell never drained a replica"
    assert sess["drain_victims_equal"], \
        "backends drained different replicas"

    proc = [r for r in out if r["backend"] == "process"]
    thr = [r for r in out if r["backend"] == "thread"]
    best = max(proc, key=lambda r: r["speedup_x"])
    print(f"process-mode emulation speedup: up to {best['speedup_x']}x "
          f"virtual/wall at {best['replicas']} replica processes "
          f"(thread mode: "
          f"{max(t['speedup_x'] for t in thr)}x); same-seed parity "
          f"max_err={max(p['max_err_steps'] for p in parities)} slow-steps "
          f"with identical routing decisions")
    return out + parities


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny request counts: CI rot-check, not results")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    main(n=10 if args.smoke else (24 if args.quick else 40))
