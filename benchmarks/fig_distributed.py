"""Distributed time-warp emulation — replica count × transport backend.

The experiment the process-mode runtime exists for: the *same* cluster
scenario evaluated with replicas as in-process threads
(``backend="thread"``) and as OS processes wired to the Timekeeper over
framed TCP (``backend="process"``, the paper's §5 deployment shape) — the
backend is literally the one argument that changes between cells, because
every cell is the same :class:`~repro.scenario.Scenario` handed to
:func:`repro.scenario.run`.  For each cell we report cluster-level
TTFT/TPOT percentiles, virtual makespan, wall time, and the emulation
speedup (virtual seconds per wall second) — the speedup column is the
headline: coordinating real processes over sockets still runs the timeline
orders of magnitude faster than wall-clock sleeping would.

Parity is the acceptance bar (the repo's analogue of the paper's
distributed-causality claim), enforced by :func:`repro.scenario.compare` on
the ``distributed_parity`` preset: a same-seed scenario driven through both
backends must produce **identical routing decisions** and per-request
TTFT / TPOT within **one slow-step** (the deliberately coarse predictor
step, so bounded wall-rate absorption — socket round trips run at wall
rate under Eq. 1 — cannot masquerade as a semantic difference; a single
admission-boundary slip costs strictly less than one step by
construction).  The preset's uniformly spaced arrivals land every request
on an idle replica, so service starts continuously and no step boundary
can flip (see the preset docstring).

A second parity cell runs a closed-loop session scenario with the
autoscaler enabled (scripted scale-up + drain over a warm process pool):
per-turn placements and latencies must again agree, proving the
cross-process completion-listener path and wire-level add/drain preserve
the closed-loop causality invariant.
"""

from __future__ import annotations

import argparse

from benchmarks.common import emit, print_table
from repro.scenario import compare, get_preset, run, scenario_with

BACKENDS = ["thread", "process"]
REPLICAS = [1, 2, 4]
QPS = 6.0
# One deliberately slow predictor step: socket round trips and engine CPU
# absorb wall time into the virtual timeline (Eq. 1) on the process
# backend; the parity bar is "within one of these".  Sized so that even a
# noisy shared CI machine's wall-rate absorption stays well inside a step.
SLOW_STEP_S = 100e-3


def measure_scenario(replicas: int, n: int):
    """One measure cell: an open-loop Poisson stream on a llama3-8b pool
    with the deliberately slow step (same spec runs on both backends)."""
    return scenario_with(
        get_preset("cluster_scaling"),
        name=f"distributed[{replicas}r]",
        **{"workload.num_requests": n,
           "workload.qps": QPS,
           "workload.prompt_len_mean": 150.0,
           "workload.output_len_mean": 8.0,
           "workload.max_output_len": 12,
           "pool.replicas": replicas,
           "pool.step_time_s": SLOW_STEP_S,
           "pool.enable_prefix_caching": False,
           "slo.ttft_s": None,
           "seed": 17})


def measure(backend: str, replicas: int, n: int) -> dict:
    res = run(measure_scenario(replicas, n), backend=backend, timeout=3600)
    tks = res.timekeeper or {}
    return {
        "backend": backend,
        "replicas": replicas,
        "qps": QPS,
        "ttft_p50_ms": round(res.ttft.p50 * 1e3, 1),
        "ttft_p99_ms": round(res.ttft.p99 * 1e3, 1),
        "tpot_p50_ms": round(res.tpot.p50 * 1e3, 2),
        "completed": res.num_requests,
        "virtual_s": round(res.makespan_virtual, 2),
        "wall_s": round(res.wall_seconds, 2),
        "speedup_x": round(res.speedup, 1),
        # barrier pressure: how much clock coordination the cell cost
        "rounds": tks.get("rounds", 0),
        "batched_requests": tks.get("batched_requests", 0),
        "coalesced_parks": tks.get("coalesced_parks", 0),
    }


def parity(replicas: int, n: int) -> dict:
    """Same scenario through both backends via ``compare``: identical
    routing decisions, per-request TTFT/TPOT within one slow-step.  The
    ``distributed_parity`` preset carries the methodology (uniform spaced
    arrivals, idle-replica headroom, slow 50 ms step)."""
    scenario = scenario_with(
        get_preset("distributed_parity"),
        name=f"parity_{replicas}r",
        **{"pool.replicas": replicas,
           "workload.num_requests": min(n, 12)})
    cres = compare(scenario, backends=("thread", "process"), timeout=3600)
    return {
        "cell": f"parity_{replicas}r",
        "replicas": replicas,
        "decisions_equal": cres.decisions_equal,
        "ttft_err_steps": round(cres.max_ttft_err_s / cres.slow_step_s, 3),
        "tpot_err_steps": round(cres.max_tpot_err_s / cres.slow_step_s, 3),
        "max_err_steps": round(cres.max_err_steps, 3),
    }


def session_autoscale_scenario(n_sessions: int):
    """Closed-loop sessions + scripted autoscaler (scale up at 0.7s, drain
    at 1.8s of virtual time — both inside the measured window, not the
    teardown race).  Uniformly spaced session starts for the same reason
    the open-loop parity preset spaces arrivals: turns that land on idle
    replicas start service continuously, so a few ms of cross-backend wall
    absorption cannot flip a step-boundary admission and masquerade as a
    one-step semantic difference.  On the process backend the scale-up
    activates a warm standby child, so it pays only the *modeled*
    provisioning delay."""
    return scenario_with(
        get_preset("distributed_parity"),
        name="session_autoscale_parity",
        **{"workload.kind": "sessions",
           "workload.arrival": "uniform",
           "workload.qps": 2.0,
           "workload.num_sessions": n_sessions,
           "workload.turns_mean": 2.0, "workload.max_turns": 3,
           "workload.think_time_mean": 0.8,
           "workload.prompt_len_mean": 80.0,
           "workload.followup_len_mean": 30.0,
           "workload.output_len_mean": 4.0, "workload.max_output_len": 5,
           "pool.replicas": 2,
           # lighter step than the open-loop parity cell: per-turn service
           # must stay inside the 0.5 s session spacing so every turn lands
           # on an idle replica and submission order stays deterministic
           "pool.step_time_s": 50e-3,
           "autoscale": {
               "policy": "schedule",
               "schedule": [[0.7, 1], [1.8, -1]],
               "interval_s": 0.1, "provision_delay_s": 0.1,
               "min_replicas": 1, "max_replicas": 3},
           "seed": 23})


def session_autoscale_parity(n_sessions: int) -> dict:
    """Per-turn placements identical, latencies within one slow-step, same
    drain victim — ``compare`` checks all three (drain/scale-up divergence
    raises ParityError)."""
    cres = compare(session_autoscale_scenario(n_sessions),
                   backends=("thread", "process"), timeout=3600)
    thread = cres.results["thread"]
    return {
        "cell": "session_autoscale_parity",
        "replicas": 2,
        "decisions_equal": cres.decisions_equal,
        "drain_victims_equal": cres.drained_equal,
        "scaled_and_drained": bool(thread.drained),
        "turns": len(thread.latencies),
        "ttft_err_steps": round(cres.max_ttft_err_s / cres.slow_step_s, 3),
        "tpot_err_steps": round(cres.max_tpot_err_s / cres.slow_step_s, 3),
        "max_err_steps": round(cres.max_err_steps, 3),
    }


def rows(n: int = 40) -> list:
    return [measure(b, r, n) for b in BACKENDS for r in REPLICAS]


def main(n: int = 40) -> list:
    out = rows(n)
    print_table(out)
    parities = [parity(r, n) for r in (2,)]
    parities.append(session_autoscale_parity(max(4, n // 8)))
    print_table(parities, cols=["cell", "replicas", "decisions_equal",
                                "ttft_err_steps", "tpot_err_steps",
                                "max_err_steps"])
    emit("fig_distributed", out + parities)

    for p in parities:
        assert p["decisions_equal"], \
            f"{p['cell']}: routing decisions diverge between backends"
        assert p["max_err_steps"] <= 1.0, \
            (f"{p['cell']}: thread/process latencies diverge by "
             f"{p['max_err_steps']} slow-steps")
    sess = parities[-1]
    assert sess["scaled_and_drained"], \
        "autoscaler cell never drained a replica"
    assert sess["drain_victims_equal"], \
        "backends drained different replicas"

    proc = [r for r in out if r["backend"] == "process"]
    thr = [r for r in out if r["backend"] == "thread"]
    best = max(proc, key=lambda r: r["speedup_x"])
    print(f"process-mode emulation speedup: up to {best['speedup_x']}x "
          f"virtual/wall at {best['replicas']} replica processes "
          f"(thread mode: "
          f"{max(t['speedup_x'] for t in thr)}x); same-seed parity "
          f"max_err={max(p['max_err_steps'] for p in parities)} slow-steps "
          f"with identical routing decisions")
    return out + parities


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny request counts: CI rot-check, not results")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    main(n=10 if args.smoke else (24 if args.quick else 40))
