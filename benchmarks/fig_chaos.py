"""Chaos fault-injection — SLO attainment vs crash count × on-crash policy.

The experiment the fault layer exists for: the *same* open-loop scenario
run with 0 / 1 / 2 deterministic replica crashes (virtual-time
:class:`~repro.cluster.faults.FaultSpec` events, each with a warm-standby
respawn) under both crash policies — ``requeue`` (in-flight requests
re-enter the router with progress reset, so every submitted request still
completes, paying the re-decode in TTFT) and ``fail`` (in-flight requests
surface as failures).  The headline columns are attainment-vs-faults:
``attainment`` counts *submitted* requests (a failed request is an SLO
miss by definition), so the two policies become comparable on one axis.

Every cell is itself a parity check: the scenario runs through
:func:`repro.scenario.compare` on the thread-emulator and the DES, which
raises unless both backends produce the identical fault log (same crashes
applied at the same virtual instants, same requeue/fail counts), identical
routing decisions, and per-request TTFT/TPOT within one slow predictor
step.  A final three-way cell adds the process backend — there the crash
is a real ``SIGKILL`` of a replica OS process, with the parent recovering
in-flight state from its submission ledger — and must agree with the
other two backends bit-for-bit on the fault log.

Conservation is asserted in every cell, smoke included:
``completed + failed == submitted`` — no lost and no duplicated requests,
whatever the backend or crash policy.
"""

from __future__ import annotations

import argparse
import dataclasses

from benchmarks.common import emit, print_table
from repro.cluster.faults import FaultSpec
from repro.scenario import compare, get_preset, scenario_with

POLICIES = ["requeue", "fail"]
CRASHES = [0, 1, 2]
# Crash instants chosen off both grids of the base preset (0.1 s predictor
# steps, 0.5 s arrival spacing): a fault that coincides with a step
# completion or an arrival would make "which applies first" a race in the
# emulator while the DES orders them by event-heap sequence number.
CRASH_TIMES = [0.93, 1.91]
CRASH_REPLICAS = [1, 2]
RESPAWN_DELAY_S = 0.35
SLO_TTFT_S = 0.3


def chaos_scenario(n_crashes: int, on_crash: str, n: int):
    """One grid cell: the ``crash_recovery`` preset widened to 3 replicas
    (so the keep-one-active guard never bites) with ``n_crashes`` staggered
    mid-decode crashes, each recovering from a warm standby."""
    s = scenario_with(
        get_preset("crash_recovery"),
        name=f"chaos[{n_crashes}x_{on_crash}]",
        **{"workload.num_requests": n,
           "pool.replicas": 3,
           "slo.ttft_s": SLO_TTFT_S})
    faults = tuple(
        FaultSpec(kind="crash", time_s=CRASH_TIMES[i],
                  replica=CRASH_REPLICAS[i], on_crash=on_crash,
                  recover=True, respawn_delay_s=RESPAWN_DELAY_S)
        for i in range(n_crashes))
    return dataclasses.replace(s, faults=faults)


def measure(n_crashes: int, on_crash: str, n: int,
            backends=("thread", "des")) -> dict:
    """Run one cell through ``compare`` (the parity assert) and report
    attainment over *submitted* requests plus the conservation check."""
    scenario = chaos_scenario(n_crashes, on_crash, n)
    cres = compare(scenario, backends=backends, timeout=3600)
    for backend, res in cres.results.items():
        assert res.num_requests + res.requests_failed == n, (
            f"{scenario.name}/{backend}: conservation violated — "
            f"{res.num_requests} completed + {res.requests_failed} failed "
            f"!= {n} submitted")
    res = cres.results[backends[0]]
    # attainment over submitted: a failed request is an SLO miss
    attainment = res.slo_attainment() * res.num_requests / n
    return {
        "crashes": n_crashes,
        "on_crash": on_crash,
        "backends": "/".join(backends),
        "submitted": n,
        "completed": res.num_requests,
        "failed": res.requests_failed,
        "requeued": res.requests_requeued,
        "attainment": round(attainment, 4),
        "mean_recovery_s": round(res.mean_recovery_s, 3),
        "faults_equal": cres.faults_equal,
        "decisions_equal": cres.decisions_equal,
        "max_err_steps": round(cres.max_err_steps, 3),
    }


def rows(n: int = 40) -> list:
    out = [measure(c, p, n) for p in POLICIES for c in CRASHES]
    # three-way cell: the process backend SIGKILLs a real replica child and
    # must still match the other backends' fault log exactly
    out.append({"cell": "three_way_sigkill",
                **measure(1, "requeue", min(n, 12),
                          backends=("thread", "process", "des"))})
    return out


def main(n: int = 40) -> list:
    out = rows(n)
    print_table(out, cols=["crashes", "on_crash", "backends", "submitted",
                           "completed", "failed", "requeued", "attainment",
                           "mean_recovery_s", "faults_equal",
                           "decisions_equal", "max_err_steps"])
    emit("fig_chaos", out)

    for r in out:
        assert r["faults_equal"], \
            f"crashes={r['crashes']}/{r['on_crash']}: fault logs diverge"
        assert r["decisions_equal"], \
            f"crashes={r['crashes']}/{r['on_crash']}: routing diverges"
        assert r["max_err_steps"] <= 1.0, \
            (f"crashes={r['crashes']}/{r['on_crash']}: latencies diverge "
             f"by {r['max_err_steps']} slow-steps")

    base = next(r for r in out if r["crashes"] == 0
                and r["on_crash"] == "requeue")
    worst = min((r for r in out if "cell" not in r),
                key=lambda r: r["attainment"])
    print(f"chaos: attainment {base['attainment']:.2f} -> "
          f"{worst['attainment']:.2f} at {worst['crashes']} crashes "
          f"({worst['on_crash']}); fault-log parity held on "
          f"{len(out)} cells incl. process-backend SIGKILL; "
          f"completed+failed==submitted everywhere")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny request counts: CI rot-check, not results")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    main(n=10 if args.smoke else (24 if args.quick else 40))
