"""Fig. 3 — CPU vs GPU execution time split.

The paper processes 100 requests in offline mode and shows GPU computation
accounts for 90-95% of wall time across vLLM and SGLang, which is the
headroom time-warp emulation exploits.  We reproduce the measurement for the
paper's three evaluation models under both scheduler policies: the engine's
control plane runs for real (same Python code in every mode); device time is
the analytical predictor's per-step duration on the paper-spec hardware.

Derived column: gpu_frac — fraction of step time that is (emulated) device
execution; the paper's claim is 0.90–0.95.
"""

from __future__ import annotations

from benchmarks.common import emit, paper_parallelism, print_table, sharegpt_workload
from repro.configs import get_config
from repro.serving.scheduler import EngineConfig
from repro.serving.stack import build_stack

MODELS = ["llama3_8b", "llama3_70b", "qwen3_30b_a3b"]


def measure(arch: str, policy: str, n: int = 100) -> dict:
    cfg = get_config(arch)
    par = paper_parallelism(arch)
    ecfg = EngineConfig(policy=policy, max_num_seqs=64,
                        max_batched_tokens=512, block_size=16,
                        num_blocks=32768, chip="h200-sxm", **par)
    stack = build_stack(cfg, ecfg, "emulate", use_worker_group=False)
    try:
        # offline mode: all requests available at start (paper Fig. 3 setup)
        reqs = sharegpt_workload(n=n, qps=1e9)
        stack.engine.submit_many(reqs)
        stack.engine.start()
        ok = stack.engine.wait_until_complete(n, timeout=600)
        assert ok, f"{arch}/{policy}: engine did not drain"
        cpu = sum(s.cpu_overhead_wall for s in stack.engine.step_log)
        dev = sum(e["total"] for e in stack.runner.step_estimates)
        steps = len(stack.engine.step_log)
    finally:
        stack.shutdown()
    return {
        "arch": arch,
        "policy": policy,
        "steps": steps,
        "cpu_s": round(cpu, 4),
        "device_s": round(dev, 4),
        "gpu_frac": round(dev / (dev + cpu), 4),
    }


def rows(n: int = 100) -> list:
    return [measure(a, p, n) for a in MODELS for p in ("vllm", "sglang")]


def main(n: int = 100) -> list:
    out = rows(n)
    print_table(out)
    emit("fig3_cpu_gpu_split", out)
    worst = min(r["gpu_frac"] for r in out)
    print(f"fig3: min GPU fraction {worst:.2%} "
          f"(paper: 90-95% on H200 with a C++-assisted control plane)")
    return out


if __name__ == "__main__":
    main()
