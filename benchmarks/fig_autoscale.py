"""Autoscaling — policy × burstiness × SLO, on bursty multi-turn sessions.

The sweep the elastic traffic layer exists for: a closed-loop session
workload (multi-turn chat, follow-ups carry the prior turn's tokens) with a
tunable burstiness knob (gamma inter-arrival cv²) drives a cluster whose
membership is controlled by an :class:`~repro.cluster.autoscaler.Autoscaler`
— all under time-warp emulation with a ManualWallSource, so every cell is a
deterministic pure-jump timeline reproducible from its seed.

Per cell we report TTFT percentiles, SLO attainment, and **replica-seconds**
(the cost proxy: how much capacity × time the configuration burned).  The
headline comparison: an SLO-driven policy must match the peak-provisioned
fixed-N deployment's attainment while spending meaningfully fewer
replica-seconds on bursty traffic (fixed-N pays for capacity that idles
between bursts; the autoscaler rents it only during them).

A parity block re-runs an elastic (scale-up + drain mid-run, scripted
SchedulePolicy) scenario on the DES baseline sharing the same router,
predictor, and autoscaler policy objects — per-request latencies must agree
within one predictor step, extending the §2.3 semantic-gap argument to
elastic membership.
"""

from __future__ import annotations

import copy

from benchmarks.common import emit, print_table
from repro.cluster import (Autoscaler, AutoscalerConfig, SchedulePolicy,
                           build_cluster, make_autoscaler_policy, make_router)
from repro.configs import get_config
from repro.core.clock import ManualWallSource
from repro.core.predictor import StaticPredictor
from repro.des.simulator import DESConfig, DiscreteEventSimulator
from repro.serving.benchmark import BenchmarkRunner
from repro.serving.scheduler import EngineConfig
from repro.workload import SessionConfig, SessionWorkload, WorkloadConfig, synthesize

BATCH_S = 20e-3
MAX_NUM_SEQS = 8
MAX_BATCHED_TOKENS = 512
MAX_REPLICAS = 4

BURSTINESS = [1.0, 8.0]                  # gamma cv² (1 = Poisson)
SLOS = [0.25, 0.5]                       # TTFT SLO seconds
POLICIES = ["fixed", "queue_depth", "ttft_slo"]

# min_replicas=2 keeps a floor for the baseline traffic between bursts: a
# 1-replica floor misses the leading burst's SLO no matter how fast the
# policy reacts (provisioning latency is physical), which is the classic
# min-capacity sizing decision, not a policy defect.
ASC = AutoscalerConfig(interval_s=0.1, provision_delay_s=0.5,
                       min_replicas=2, max_replicas=MAX_REPLICAS)


def _engine_cfg(prefix_caching: bool = True) -> EngineConfig:
    return EngineConfig(policy="vllm", max_num_seqs=MAX_NUM_SEQS,
                        max_batched_tokens=MAX_BATCHED_TOKENS, block_size=16,
                        num_blocks=16384, chip="h200-sxm",
                        enable_prefix_caching=prefix_caching)


def _sessions(n: int, cv2: float) -> SessionWorkload:
    """Bursty chat sessions: bursts of conversations arrive together, think
    times open idle valleys between turns — the traffic shape where elastic
    capacity pays off."""
    arrival_kwargs = None if cv2 == 1.0 else {"cv2": cv2}
    arrival = "poisson" if cv2 == 1.0 else "gamma"
    return SessionWorkload(SessionConfig(
        num_sessions=n, qps=6.0, arrival=arrival,
        arrival_kwargs=arrival_kwargs, turns_mean=3.0, max_turns=5,
        think_time_mean=1.5, prompt_len_mean=180.0, followup_len_mean=60.0,
        output_len_mean=40.0, max_output_len=128, seed=13))


def measure(policy: str, cv2: float, slo: float, n: int) -> dict:
    model_cfg = get_config("llama3_8b")
    fixed = policy == "fixed"
    num_replicas = MAX_REPLICAS if fixed else ASC.min_replicas
    cluster = build_cluster(model_cfg, _engine_cfg(), num_replicas,
                            policy="least_outstanding_tokens",
                            predictor=StaticPredictor(BATCH_S),
                            wall=ManualWallSource())
    autoscaler = None
    if not fixed:
        kwargs = ({"slo_ttft_s": slo, "target_attainment": 0.98,
                   "window_s": 2.0} if policy == "ttft_slo"
                  else {"target_depth": 3.0, "low_watermark": 0.5})
        autoscaler = Autoscaler(
            cluster, make_autoscaler_policy(policy, **kwargs), ASC)
    try:
        res = BenchmarkRunner(cluster, _sessions(n, cv2),
                              transport=cluster.transport,
                              autoscaler=autoscaler).run(timeout=3600)
    finally:
        cluster.shutdown()
    return {
        "policy": policy,
        "cv2": cv2,
        "slo_ttft_s": slo,
        "requests": res.num_requests,
        "sessions": res.num_sessions,
        "ttft_p50_ms": round(res.ttft.p50 * 1e3, 1),
        "ttft_p99_ms": round(res.ttft.p99 * 1e3, 1),
        "session_ttft_p50_ms": round(res.session_ttft.p50 * 1e3, 1),
        "slo_attainment": round(res.slo_attainment(slo_ttft_s=slo), 4),
        "replica_seconds": round(res.replica_seconds, 2),
        "makespan_s": round(res.makespan_virtual, 2),
        "wall_s": round(res.wall_seconds, 2),
        "speedup_x": round(res.speedup, 1),
    }


ELASTIC_EVENTS = [(0.3, +1), (2.0, -1)]


def des_parity(n: int) -> dict:
    """Elastic scale-up + drain mid-run, emulator vs DES, same scripted
    policy / router / predictor objects (fresh instances per run — policies
    and routers are stateful)."""
    model_cfg = get_config("llama3_8b")
    asc_cfg = AutoscalerConfig(interval_s=0.1, provision_delay_s=0.5,
                               min_replicas=1, max_replicas=2)
    # arrival-bound regime (one replica keeps up between bursts): the parity
    # question is whether elasticity itself introduces divergence, not
    # whether deep-overload batching cascades do (fig_cluster covers load)
    reqs = synthesize(WorkloadConfig(
        num_requests=n, qps=4.0, prompt_len_mean=180, output_len_mean=40,
        seed=13))
    reqs_des = copy.deepcopy(reqs)

    cluster = build_cluster(model_cfg, _engine_cfg(prefix_caching=False), 1,
                            policy="round_robin",
                            predictor=StaticPredictor(BATCH_S),
                            wall=ManualWallSource())
    asc = Autoscaler(cluster, SchedulePolicy(ELASTIC_EVENTS), asc_cfg)
    try:
        BenchmarkRunner(cluster, reqs, transport=cluster.transport,
                        autoscaler=asc).run(timeout=3600)
        emu_latency = {r.request_id: r.e2e_latency()
                       for r in cluster.finished}
        scaled = len(cluster.engines)
    finally:
        cluster.shutdown()

    des = DiscreteEventSimulator(
        StaticPredictor(BATCH_S),
        DESConfig(max_num_seqs=MAX_NUM_SEQS,
                  max_batched_tokens=MAX_BATCHED_TOKENS, step_overhead_s=0.0),
        num_replicas=1, router=make_router("round_robin", 1),
        autoscaler_policy=SchedulePolicy(ELASTIC_EVENTS),
        autoscaler_cfg=asc_cfg)
    sims = des.run(reqs_des)

    errs = [abs(emu_latency[orig.request_id]
                - (sim.finish_time - sim.arrival_time))
            for orig, sim in zip(reqs_des, sims)]
    return {
        "policy": "schedule(+1@0.3,-1@2.0)",
        "emu_completed": len(emu_latency),
        "des_completed": sum(1 for s in sims if s.finish_time is not None),
        "emu_replicas": scaled,
        "des_replicas": len(des.replicas),
        "max_err_steps": round(max(errs) / BATCH_S, 3),
        "mean_err_steps": round(sum(errs) / len(errs) / BATCH_S, 3),
    }


def rows(n: int = 16) -> list:
    return [measure(p, b, s, n)
            for p in POLICIES for b in BURSTINESS for s in SLOS]


def main(n: int = 16) -> list:
    out = rows(n)
    print_table(out)
    parity = des_parity(max(8, n))
    print_table([parity])
    emit("fig_autoscale", out + [parity])

    assert parity["emu_completed"] == parity["des_completed"], \
        "elastic emulator/DES completed-request counts diverge"
    assert parity["max_err_steps"] <= 1.0, \
        f"elastic emulator/DES diverges by {parity['max_err_steps']} steps"

    # headline: SLO-driven scaling matches fixed-N attainment at lower cost
    # on the bursty workload
    cv2, slo = BURSTINESS[-1], SLOS[-1]
    cell = {r["policy"]: r for r in out
            if r["cv2"] == cv2 and r["slo_ttft_s"] == slo}
    fixed, auto = cell["fixed"], cell["ttft_slo"]
    assert auto["slo_attainment"] >= fixed["slo_attainment"] - 0.02, \
        (f"SLO-driven attainment {auto['slo_attainment']} fell below "
         f"fixed-N {fixed['slo_attainment']}")
    assert auto["replica_seconds"] < fixed["replica_seconds"], \
        (f"SLO-driven cost {auto['replica_seconds']} not below fixed-N "
         f"{fixed['replica_seconds']}")
    saving = 1 - auto["replica_seconds"] / fixed["replica_seconds"]
    print(f"autoscale: ttft_slo matches fixed-{MAX_REPLICAS} attainment "
          f"({auto['slo_attainment']:.1%} vs {fixed['slo_attainment']:.1%}) "
          f"at {saving:.0%} fewer replica-seconds (cv2={cv2}, "
          f"slo={slo}s); elastic emu/DES parity "
          f"max_err={parity['max_err_steps']} steps")
    return out + [parity]


if __name__ == "__main__":
    main()
