"""Autoscaling — policy × burstiness × SLO, on bursty multi-turn sessions.

The sweep the elastic traffic layer exists for: a closed-loop session
workload (multi-turn chat, follow-ups carry the prior turn's tokens) with a
tunable burstiness knob (gamma inter-arrival cv²) drives a cluster whose
membership is controlled by an :class:`~repro.cluster.autoscaler.Autoscaler`.
Every cell is a :class:`~repro.scenario.Scenario` derived from the
``autoscale_burst`` preset — the policy variants differ only in their
``autoscale`` sub-spec — executed by :func:`repro.scenario.run` on the
thread backend (ManualWallSource: every cell is a deterministic pure-jump
timeline reproducible from its seed).

Per cell we report TTFT percentiles, SLO attainment, and **replica-seconds**
(the cost proxy: how much capacity × time the configuration burned).  The
headline comparison: an SLO-driven policy must match the peak-provisioned
fixed-N deployment's attainment while spending meaningfully fewer
replica-seconds on bursty traffic (fixed-N pays for capacity that idles
between bursts; the autoscaler rents it only during them).

The parity block is a :func:`repro.scenario.compare` of an elastic
(scale-up + drain mid-run, scripted schedule policy) scenario on the thread
emulator vs the DES baseline — per-request latencies must agree within one
predictor step, extending the §2.3 semantic-gap argument to elastic
membership.
"""

from __future__ import annotations

import dataclasses

from benchmarks.common import emit, print_table
from repro.scenario import (AutoscaleSpec, compare, get_preset, run,
                            scenario_with)

MAX_REPLICAS = 4

BURSTINESS = [1.0, 8.0]                  # gamma cv² (1 = Poisson)
SLOS = [0.25, 0.5]                       # TTFT SLO seconds
POLICIES = ["fixed", "queue_depth", "ttft_slo"]

# min_replicas=2 keeps a floor for the baseline traffic between bursts: a
# 1-replica floor misses the leading burst's SLO no matter how fast the
# policy reacts (provisioning latency is physical), which is the classic
# min-capacity sizing decision, not a policy defect.
MIN_REPLICAS = 2


def cell(policy: str, cv2: float, slo: float, n: int):
    """One grid cell as a scenario: the preset base with the burstiness /
    SLO coordinates applied, and the autoscale sub-spec swapped per policy
    variant (``fixed`` = peak-provisioned pool, no autoscaler)."""
    s = scenario_with(
        get_preset("autoscale_burst"),
        name=f"autoscale[{policy},cv2={cv2},slo={slo}]",
        **{"workload.num_sessions": n,
           "workload.arrival": "poisson" if cv2 == 1.0 else "gamma",
           "workload.arrival_kwargs": (None if cv2 == 1.0
                                       else {"cv2": cv2}),
           "slo.ttft_s": slo})
    if policy == "fixed":
        return dataclasses.replace(
            s, autoscale=None,
            pool=dataclasses.replace(s.pool, replicas=MAX_REPLICAS))
    kwargs = ({"slo_ttft_s": slo, "target_attainment": 0.98,
               "window_s": 2.0} if policy == "ttft_slo"
              else {"target_depth": 3.0, "low_watermark": 0.5})
    return dataclasses.replace(
        s,
        pool=dataclasses.replace(s.pool, replicas=MIN_REPLICAS),
        autoscale=AutoscaleSpec(
            policy=policy, kwargs=kwargs, interval_s=0.1,
            provision_delay_s=0.5, min_replicas=MIN_REPLICAS,
            max_replicas=MAX_REPLICAS))


def measure(policy: str, cv2: float, slo: float, n: int) -> dict:
    res = run(cell(policy, cv2, slo, n), backend="thread", timeout=3600)
    return {
        "policy": policy,
        "cv2": cv2,
        "slo_ttft_s": slo,
        "requests": res.num_requests,
        "sessions": res.num_sessions,
        "ttft_p50_ms": round(res.ttft.p50 * 1e3, 1),
        "ttft_p99_ms": round(res.ttft.p99 * 1e3, 1),
        "session_ttft_p50_ms": round(res.session_ttft.p50 * 1e3, 1),
        "slo_attainment": round(res.slo_attainment(), 4),
        "replica_seconds": round(res.replica_seconds, 2),
        "makespan_s": round(res.makespan_virtual, 2),
        "wall_s": round(res.wall_seconds, 2),
        "speedup_x": round(res.speedup, 1),
    }


ELASTIC_EVENTS = ((0.3, 1), (2.0, -1))


def des_parity(n: int) -> dict:
    """Elastic scale-up + drain mid-run, emulator vs DES through one
    ``compare`` call (the scenario carries the scripted schedule; both
    backends get fresh policy/router objects built from it).

    Arrival-bound open-loop regime (one replica keeps up between bursts):
    the parity question is whether elasticity itself introduces divergence,
    not whether deep-overload batching cascades do (fig_cluster covers
    load)."""
    scenario = scenario_with(
        get_preset("autoscale_burst"),
        name="autoscale_parity",
        **{"workload.kind": "open",
           "workload.arrival": "poisson",
           "workload.arrival_kwargs": None,
           "workload.qps": 4.0,
           "workload.num_requests": n,
           "workload.max_output_len": 1024,
           "pool.replicas": 1,
           "pool.enable_prefix_caching": False,
           "routing.policy": "round_robin",
           "autoscale": {
               "policy": "schedule",
               "schedule": list(list(e) for e in ELASTIC_EVENTS),
               "interval_s": 0.1, "provision_delay_s": 0.5,
               "min_replicas": 1, "max_replicas": 2},
           "slo.ttft_s": None})
    cres = compare(scenario, backends=("thread", "des"), timeout=3600)
    emu, des = cres.results["thread"], cres.results["des"]
    return {
        "policy": "schedule(+1@0.3,-1@2.0)",
        "emu_completed": emu.num_requests,
        "des_completed": des.num_requests,
        "emu_replicas": len(emu.replica_tiers),
        "des_replicas": len(des.replica_tiers),
        "max_err_steps": round(cres.max_err_steps, 3),
        "ttft_err_steps": round(cres.max_ttft_err_s / cres.slow_step_s, 3),
    }


def rows(n: int = 16) -> list:
    return [measure(p, b, s, n)
            for p in POLICIES for b in BURSTINESS for s in SLOS]


def main(n: int = 16) -> list:
    out = rows(n)
    print_table(out)
    parity = des_parity(max(8, n))
    print_table([parity])
    emit("fig_autoscale", out + [parity])

    assert parity["emu_completed"] == parity["des_completed"], \
        "elastic emulator/DES completed-request counts diverge"
    assert parity["max_err_steps"] <= 1.0, \
        f"elastic emulator/DES diverges by {parity['max_err_steps']} steps"

    # headline: SLO-driven scaling matches fixed-N attainment at lower cost
    # on the bursty workload
    cv2, slo = BURSTINESS[-1], SLOS[-1]
    cell_rows = {r["policy"]: r for r in out
                 if r["cv2"] == cv2 and r["slo_ttft_s"] == slo}
    fixed, auto = cell_rows["fixed"], cell_rows["ttft_slo"]
    assert auto["slo_attainment"] >= fixed["slo_attainment"] - 0.02, \
        (f"SLO-driven attainment {auto['slo_attainment']} fell below "
         f"fixed-N {fixed['slo_attainment']}")
    assert auto["replica_seconds"] < fixed["replica_seconds"], \
        (f"SLO-driven cost {auto['replica_seconds']} not below fixed-N "
         f"{fixed['replica_seconds']}")
    saving = 1 - auto["replica_seconds"] / fixed["replica_seconds"]
    print(f"autoscale: ttft_slo matches fixed-{MAX_REPLICAS} attainment "
          f"({auto['slo_attainment']:.1%} vs {fixed['slo_attainment']:.1%}) "
          f"at {saving:.0%} fewer replica-seconds (cv2={cv2}, "
          f"slo={slo}s); elastic emu/DES parity "
          f"max_err={parity['max_err_steps']} steps")
    return out + [parity]


if __name__ == "__main__":
    main()
