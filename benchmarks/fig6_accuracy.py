"""Fig. 6 — end-to-end accuracy: Revati (emulate) vs real execution.

The paper compares emulated TTFT/TPOT distributions against real GPU
execution on three models.  On this CPU-only container "real execution" is
the actual JAX model running on CPU (reduced same-family configs — the
control plane is identical at any scale); the emulator's TablePredictor is
calibrated from a *disjoint* profiling workload, then both modes replay the
same evaluation stream.

Derived column: p50/p90/p99 relative error between the real and emulated
TTFT/TPOT distributions — the paper's claim is <5% at the median.
"""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import emit, print_table, small_workload
from repro.configs import get_reduced_config
from repro.core.predictor import LinearPredictor
from repro.models.transformer import build_model
from repro.serving.benchmark import BenchmarkRunner, compare_distributions
from repro.serving.scheduler import EngineConfig
from repro.serving.stack import build_stack

ARCHS = ["qwen2_5_3b", "granite_8b", "mixtral_8x7b"]


def engine_cfg(**kw):
    base = dict(policy="vllm", max_num_seqs=8, max_batched_tokens=64,
                block_size=4, num_blocks=4096)
    base.update(kw)
    return EngineConfig(**base)


def run_real(arch: str, reqs, *, max_len=256):
    import jax
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0), jnp.float32)
    stack = build_stack(cfg, engine_cfg(), "real", model=model,
                        params=params, max_len=max_len, max_seqs=8)
    try:
        res = BenchmarkRunner(stack.engine, reqs).run(timeout=900)
        samples = list(stack.runner.samples)
        return res, samples
    finally:
        stack.shutdown()


def run_emulated(arch: str, reqs, predictor):
    cfg = get_reduced_config(arch)
    stack = build_stack(cfg, engine_cfg(), "emulate", predictor=predictor,
                        use_worker_group=False)
    try:
        return BenchmarkRunner(stack.engine, reqs,
                               transport=stack.transport).run(timeout=900)
    finally:
        stack.shutdown()


def measure(arch: str, n: int = 30) -> dict:
    # calibration workload (disjoint seed) -> operator-linear predictor
    calib = small_workload(n=max(10, n // 2), qps=15.0, seed=123)
    _, samples = run_real(arch, calib)
    table = LinearPredictor()      # Vidur-style operator-linear fit
    table.fit(samples)

    # evaluation: same stream through real (twice: noise floor) and emulated
    res_real, _ = run_real(arch, small_workload(n=n, qps=15.0, seed=7))
    res_real2, _ = run_real(arch, small_workload(n=n, qps=15.0, seed=7))
    res_emu = run_emulated(arch, small_workload(n=n, qps=15.0, seed=7), table)

    ttft = compare_distributions(res_real.ttft, res_emu.ttft)
    tpot = compare_distributions(res_real.tpot, res_emu.tpot)
    noise = compare_distributions(res_real.ttft, res_real2.ttft)
    return {
        "arch": arch,
        "n": n,
        "real_ttft_p50_ms": round(res_real.ttft.p50 * 1e3, 3),
        "emu_ttft_p50_ms": round(res_emu.ttft.p50 * 1e3, 3),
        "ttft_p50_err": round(ttft["median_rel_err"], 4),
        "ttft_p99_err": round(ttft["p99_rel_err"], 4),
        "tpot_p50_err": round(tpot["median_rel_err"], 4),
        "tpot_p99_err": round(tpot["p99_rel_err"], 4),
        # run-to-run variability of *real* execution on this shared 1-core
        # container — the measurement noise floor any predictor is bound by
        # (the paper's dedicated H200s have ~stable kernel times instead)
        "real_noise_floor": round(noise["median_rel_err"], 4),
        "real_wall_s": round(res_real.wall_seconds, 2),
        "emu_wall_s": round(res_emu.wall_seconds, 2),
        "speedup_vs_real": round(
            res_real.wall_seconds / max(res_emu.wall_seconds, 1e-9), 2),
    }


def rows(n: int = 30) -> list:
    return [measure(a, n) for a in ARCHS]


def main(n: int = 30) -> list:
    out = rows(n)
    print_table(out)
    emit("fig6_accuracy", out)
    worst = max(r["ttft_p50_err"] for r in out)
    floor = max(r["real_noise_floor"] for r in out)
    print(f"fig6: worst median TTFT error {worst:.2%} vs a real-vs-real "
          f"run-to-run noise floor of {floor:.2%} on this shared 1-core "
          f"container (paper: <5% on dedicated H200s)")
    return out


if __name__ == "__main__":
    main()
