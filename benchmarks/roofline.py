"""Roofline analysis over the multi-pod dry-run artifacts (deliverable g).

For every (arch x shape x mesh) cell recorded by ``repro.launch.dryrun``,
derive the three roofline terms on TPU v5e:

    compute    = HLO_FLOPs_per_chip    / (peak 197 TF/s bf16)
    memory     = HLO_bytes_per_chip    / (819 GB/s HBM)
    collective = coll_bytes_per_chip   / (~50 GB/s/link ICI)

``compiled.cost_analysis()`` and the HLO collective parse both report the
post-SPMD *per-device* module, so the assignment's "/ chips" denominators
cancel with per-chip numerators; global totals are per-chip x 256 (or 512).

Also reported per cell:
    MODEL_FLOPS      6·N·D (train), 2·N·D (prefill) or 2·N_active·B (decode)
    useful_ratio     MODEL_FLOPS / global HLO FLOPs (remat/redundancy waste)
    bound            dominant term
    roofline_frac    MODEL_FLOPS / (chips·peak) / max(terms) — the MFU the
                     step would achieve executing exactly at its roofline.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from benchmarks.common import ARTIFACTS, emit, print_table

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

DEFAULT_IN = ARTIFACTS / "dryrun.jsonl"


def model_flops(rec: dict) -> float:
    """Paper-agnostic useful-FLOPs accounting per lowered step."""
    n = rec["model_params"]
    n_active = rec["model_active_params"]
    d = rec["tokens_per_step"]
    if rec["entry"] == "train_step":
        return 6.0 * n_active * d
    # serving: forward only; decode touches only active params
    return 2.0 * n_active * d


def analyze(rec: dict) -> dict:
    chips = rec["chips"]
    fl = rec["cost"]["flops"]                      # per-chip (post-SPMD)
    by = rec["cost"]["bytes_accessed"]
    cb = rec["collectives"]["total_bytes"]
    t_comp = fl / PEAK_FLOPS
    t_mem = by / HBM_BW
    t_coll = cb / ICI_BW
    t_roof = max(t_comp, t_mem, t_coll)
    bound = {t_comp: "compute", t_mem: "memory", t_coll: "collective"}[t_roof]
    mf = model_flops(rec)
    useful = mf / (fl * chips) if fl else 0.0
    mfu_at_roofline = (mf / (chips * PEAK_FLOPS)) / t_roof if t_roof else 0.0
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "opts": "+".join(rec.get("opts", [])) or "-",
        "entry": rec["entry"],
        "compute_s": t_comp,
        "memory_s": t_mem,
        "collective_s": t_coll,
        "bound": bound,
        "useful_ratio": round(useful, 3),
        "roofline_frac": round(mfu_at_roofline, 4),
        "hbm_gb_per_chip": round(rec["memory"]["peak_per_device"] / 2**30, 2),
        "coll_ops": rec["collectives"]["total_ops"],
    }


def rows(path: Path = DEFAULT_IN, mesh: str | None = "16x16") -> list:
    if not Path(path).exists():
        return []                      # no dryrun artifacts on this machine
    recs = [json.loads(l) for l in open(path)]
    # keep the LATEST record per (arch, shape, mesh, opts): perf iterations
    # append; baseline and optimized lowerings coexist as separate rows
    latest = {}
    for r in recs:
        latest[(r["arch"], r["shape"], r["mesh"],
                tuple(r.get("opts", [])))] = r
    out = [analyze(r) for r in latest.values()
           if mesh is None or r["mesh"] == mesh]
    out.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"], r["opts"]))
    return out


def pick_hillclimb_targets(table: list) -> dict:
    """The three assignment-mandated hillclimb cells (baseline rows only)."""
    table = [r for r in table if r["opts"] == "-"]
    worst = min(table, key=lambda r: r["roofline_frac"] if r["roofline_frac"]
                else 1.0)
    coll = max(table, key=lambda r: r["collective_s"]
               / max(r["compute_s"], r["memory_s"], 1e-12))
    # most representative of the paper: the serving decode step of its
    # largest eval-adjacent MoE (continuous-batching decode dominates
    # serving-system evaluation time)
    rep = next((r for r in table
                if r["arch"] == "mixtral_8x7b" and r["shape"] == "decode_32k"),
               table[0])
    return {"worst_roofline": worst, "most_collective_bound": coll,
            "paper_representative": rep}


def main() -> list:
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default=str(DEFAULT_IN))
    ap.add_argument("--mesh", default="16x16",
                    help="16x16 | 2x16x16 | all")
    args, _ = ap.parse_known_args()
    mesh = None if args.mesh == "all" else args.mesh
    table = rows(Path(args.inp), mesh)
    print_table(table)
    emit("roofline", table)
    targets = pick_hillclimb_targets(table)
    print("\nhillclimb targets:")
    for k, r in targets.items():
        print(f"  {k}: {r['arch']} x {r['shape']} "
              f"(bound={r['bound']}, roofline_frac={r['roofline_frac']})")
    return table


if __name__ == "__main__":
    main()
