"""Fig. 8 — accuracy & speedup vs batch duration (5-40 ms static).

The paper's ablation: replace the runtime predictor with static batch
durations between 5 and 40 ms and compare Revati against the sleep-based
strawman.  Accuracy stays <5% while speedup grows with batch duration
(more skippable device time per step), up to 27x at 40 ms.

Derived: ttft_p50_err (vs sleep baseline) and speedup_x per duration.
"""

from __future__ import annotations

from benchmarks.common import emit, print_table, sharegpt_workload, run_stack
from repro.configs import get_config
from repro.core.predictor import StaticPredictor
from repro.serving.benchmark import compare_distributions
from repro.serving.scheduler import EngineConfig

DURATIONS_MS = [5, 10, 20, 40]


def measure(batch_ms: float, n: int = 50, qps: float = 4.0) -> dict:
    cfg = get_config("llama3_8b")
    ecfg = EngineConfig(policy="vllm", max_num_seqs=64,
                        max_batched_tokens=512, block_size=16,
                        num_blocks=32768, chip="h200-sxm")
    pred = StaticPredictor(batch_ms * 1e-3)
    reqs = lambda: sharegpt_workload(n=n, qps=qps, seed=5,
                                     prompt_len_mean=180, output_len_mean=60)
    res_sleep = run_stack(cfg, ecfg, "sleep", reqs(), predictor=pred,
                          timeout=3600)
    res_emu = run_stack(cfg, ecfg, "emulate", reqs(), predictor=pred,
                        use_worker_group=False)
    ttft = compare_distributions(res_sleep.ttft, res_emu.ttft)
    tpot = compare_distributions(res_sleep.tpot, res_emu.tpot)
    return {
        "batch_ms": batch_ms,
        "ttft_p50_err": round(ttft["median_rel_err"], 4),
        "ttft_p99_err": round(ttft["p99_rel_err"], 4),
        "tpot_p50_err": round(tpot["median_rel_err"], 4),
        "sleep_wall_s": round(res_sleep.wall_seconds, 2),
        "emu_wall_s": round(res_emu.wall_seconds, 2),
        "speedup_x": round(res_sleep.wall_seconds
                           / max(res_emu.wall_seconds, 1e-9), 1),
    }


def rows(n: int = 50) -> list:
    return [measure(d, n) for d in DURATIONS_MS]


def main(n: int = 50) -> list:
    out = rows(n)
    print_table(out)
    emit("fig8_batch_duration", out)
    print("fig8: speedup should grow with batch duration "
          "(paper: up to 27x at 40 ms); error should stay <5% at p50")
    return out


if __name__ == "__main__":
    main()
