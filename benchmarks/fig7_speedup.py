"""Fig. 7 — end-to-end speedup of Revati over real execution.

The paper reports 5-17x on vLLM and 6-12x on SGLang, growing with model
size (more GPU time to skip).  We reproduce the trend with the analytical
predictor on the paper's three models: the *same* control plane processes
the same ShareGPT-like stream in emulate mode (time jumps) and sleep mode
(the strawman that pays device time in wall clock — a stand-in for real
GPU execution speed, as the paper's Figs. 8-10 do).

Derived: speedup_x = sleep-mode wall / emulate-mode wall.
"""

from __future__ import annotations

from benchmarks.common import (emit, paper_parallelism, print_table,
                               sharegpt_workload, run_stack)
from repro.configs import get_config
from repro.serving.scheduler import EngineConfig

MODELS = ["llama3_8b", "llama3_70b", "qwen3_30b_a3b"]


def measure(arch: str, policy: str, n: int = 60, qps: float = 4.0) -> dict:
    cfg = get_config(arch)
    par = paper_parallelism(arch)
    ecfg = EngineConfig(policy=policy, max_num_seqs=64,
                        max_batched_tokens=512, block_size=16,
                        num_blocks=32768, chip="h200-sxm", **par)
    reqs = lambda: sharegpt_workload(n=n, qps=qps, seed=11)
    res_emu = run_stack(cfg, ecfg, "emulate", reqs(), use_worker_group=False)
    res_sleep = run_stack(cfg, ecfg, "sleep", reqs(), timeout=3600)
    return {
        "arch": arch,
        "policy": policy,
        "virtual_makespan_s": round(res_emu.makespan_virtual, 2),
        "emulate_wall_s": round(res_emu.wall_seconds, 2),
        "sleep_wall_s": round(res_sleep.wall_seconds, 2),
        "speedup_x": round(res_sleep.wall_seconds
                           / max(res_emu.wall_seconds, 1e-9), 1),
        "accel_vs_virtual_x": round(res_emu.speedup, 1),
    }


def rows(n: int = 60) -> list:
    return [measure(a, p, n) for a in MODELS for p in ("vllm", "sglang")]


def main(n: int = 60) -> list:
    out = rows(n)
    print_table(out)
    emit("fig7_speedup", out)
    lo = min(r["speedup_x"] for r in out)
    hi = max(r["speedup_x"] for r in out)
    print(f"fig7: speedup range {lo}-{hi}x (paper: 5-17x vLLM, 6-12x SGLang)")
    return out


if __name__ == "__main__":
    main()
