"""Table 1 — modeling-domain feature matrix, demonstrated live.

The paper's Table 1 claims Revati covers every modern serving feature *by
construction* (it runs the real control plane) while DES baselines must
re-implement each one.  This benchmark exercises each feature through the
unmodified engine under emulation and records the observable evidence;
the last row quantifies the DES semantic gap on a prefix-heavy workload.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, print_table, small_workload
from repro.configs import get_config, get_reduced_config
from repro.core.predictor import (AnalyticalPredictor, BatchSpec,
                                  ParallelSpec, SeqSpec, StaticPredictor)
from repro.core.hardware import get_chip
from repro.serving.benchmark import BenchmarkRunner
from repro.serving.scheduler import EngineConfig
from repro.serving.stack import build_stack

MODEL = get_reduced_config("qwen2_5_3b")


def engine_cfg(**kw):
    base = dict(policy="vllm", max_num_seqs=8, max_batched_tokens=64,
                block_size=4, num_blocks=4096)
    base.update(kw)
    return EngineConfig(**base)


def run(reqs, *, predictor=None, cfg=None, model_cfg=None):
    stack = build_stack(model_cfg or MODEL, cfg or engine_cfg(), "emulate",
                        predictor=predictor or StaticPredictor(4e-3),
                        use_worker_group=False)
    try:
        res = BenchmarkRunner(stack.engine, reqs,
                              transport=stack.transport).run(timeout=300)
        return res, stack.engine
    finally:
        stack.shutdown()


# ---------------------------------------------------------------- features --

def continuous_batching() -> dict:
    res, eng = run(small_workload(n=20, qps=100.0))
    mixed = sum(1 for s in eng.step_log
                if s.num_prefill_tokens > 0 and s.num_decode > 0)
    return {"feature": "continuous batching (mixed)", "supported": mixed > 0,
            "evidence": f"{mixed}/{len(eng.step_log)} steps mixed P+D"}


def chunked_prefill() -> dict:
    reqs = small_workload(n=6, qps=100.0, prompt_len_mean=200,
                          max_prompt_len=400, min_prompt_len=150)
    res, eng = run(reqs, cfg=engine_cfg(max_batched_tokens=64))
    multi = sum(1 for r in reqs if r.prompt_len > 64)
    return {"feature": "chunked prefill", "supported": multi > 0,
            "evidence": f"{multi} prompts > 64-token budget, all finished"}


def prefix_caching() -> dict:
    reqs = small_workload(n=20, qps=100.0, shared_prefix_len=32,
                          prompt_len_mean=48)
    res, eng = run(reqs)
    hr = eng.prefix_cache.stats.hit_rate
    return {"feature": "prefix caching (radix)", "supported": hr > 0,
            "evidence": f"hit rate {hr:.1%}"}


def hierarchical_cache() -> dict:
    evid = []
    for policy in ("write_through", "write_through_selective"):
        reqs = small_workload(n=16, qps=100.0, shared_prefix_len=32,
                              prompt_len_mean=48, seed=4)
        res, eng = run(reqs, cfg=engine_cfg(host_tier_blocks=256,
                                            host_write_policy=policy))
        evid.append(f"{policy}: {len(eng.prefix_cache._host)} host blocks")
    return {"feature": "hierarchical cache (2 policies)", "supported": True,
            "evidence": "; ".join(evid)}


def pd_disaggregation() -> dict:
    from repro.core.client import LocalTransport, TimeJumpClient
    from repro.core.timekeeper import Timekeeper
    from repro.serving.disagg import DisaggConfig, DisaggregatedCluster
    from repro.serving.engine import LLMEngine
    from repro.serving.model_runner import TimeWarpModelRunner

    tk = Timekeeper(jitter_cooldown=0.0)
    tr = LocalTransport(tk)
    mk = lambda n: LLMEngine(engine_cfg(), TimeWarpModelRunner(
        StaticPredictor(4e-3), TimeJumpClient(tr, f"{n}-w",
                                              auto_register=False)),
        tk.clock, name=n)
    cluster = DisaggregatedCluster(MODEL, mk("pre"), mk("dec"),
                                   DisaggConfig(kv_link_bandwidth=1e5),
                                   transport=tr)
    cluster.start()
    for r in small_workload(n=8, qps=100.0):
        cluster.submit(r)
    ok = cluster.wait_until_complete(8, timeout=120)
    xfer = np.mean([r.kv_transfer_time for r in cluster.finished]) if ok else 0
    cluster.stop()
    tk.close()
    return {"feature": "PD disaggregation", "supported": bool(ok),
            "evidence": f"mean KV transfer {xfer*1e3:.1f} ms virtual"}


def dp_attention() -> dict:
    """Two engine replicas (DP) share one Timekeeper; a round-robin router
    splits the stream — the control planes stay unmodified."""
    from repro.core.client import LocalTransport, TimeJumpClient
    from repro.core.timekeeper import Timekeeper
    from repro.serving.engine import LLMEngine
    from repro.serving.model_runner import TimeWarpModelRunner

    tk = Timekeeper(jitter_cooldown=0.0)
    tr = LocalTransport(tk)
    engines = []
    for i in range(2):
        eng = LLMEngine(engine_cfg(), TimeWarpModelRunner(
            StaticPredictor(4e-3), TimeJumpClient(tr, f"dp{i}-w",
                                                  auto_register=False)),
            tk.clock, name=f"dp{i}")
        eng.start()
        engines.append(eng)
    reqs = small_workload(n=12, qps=100.0)
    for i, r in enumerate(reqs):
        engines[i % 2].submit(r)          # round-robin DP routing
    ok = all(e.wait_until_complete(6, timeout=120) for e in engines)
    for e in engines:
        e.stop()
    tk.close()
    return {"feature": "DP attention (2 replicas)", "supported": bool(ok),
            "evidence": f"2 engines x 6 reqs drained on one virtual clock"}


def moe_expert_parallel() -> dict:
    cfg = get_config("mixtral_8x7b")
    pred = AnalyticalPredictor(cfg, ParallelSpec(tp=1, ep=2),
                               get_chip("h200-sxm"))
    est = pred.predict_step(BatchSpec.make([SeqSpec(512, 512)] * 4))
    return {"feature": "MoE / expert parallel", "supported":
            est.collective_bytes > 0,
            "evidence": f"EP all-to-all {est.collective_bytes/1e6:.1f} MB "
                        f"per step in predictor"}


def des_semantic_gap() -> dict:
    from repro.des.simulator import DESConfig, DiscreteEventSimulator
    mk = lambda: small_workload(n=24, qps=30.0, shared_prefix_len=64,
                                prompt_len_mean=96, seed=9)
    res_emu, _ = run(mk(), predictor=StaticPredictor(5e-3),
                     cfg=engine_cfg(max_batched_tokens=128))
    sims = DiscreteEventSimulator(
        StaticPredictor(5e-3),
        DESConfig(max_num_seqs=8, max_batched_tokens=128)).run(mk())
    des_p50 = float(np.percentile(
        [s.ttft() for s in sims if s.ttft() is not None], 50))
    gap = abs(des_p50 - res_emu.ttft.p50) / max(res_emu.ttft.p50, 1e-9)
    return {"feature": "DES gap (no prefix cache)", "supported": True,
            "evidence": f"Vidur-style DES TTFT p50 off by {gap:.0%} "
                        f"on shared-prefix load"}


def rows() -> list:
    return [continuous_batching(), chunked_prefill(), prefix_caching(),
            hierarchical_cache(), pd_disaggregation(), dp_attention(),
            moe_expert_parallel(), des_semantic_gap()]


def main() -> list:
    out = rows()
    print_table(out)
    emit("table1_features", out)
    assert all(r["supported"] for r in out), "feature matrix incomplete"
    print("table1: all features exercised through the unmodified engine")
    return out


if __name__ == "__main__":
    main()
