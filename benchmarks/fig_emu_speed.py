"""Raw emulation speed — the perf trajectory figure (``BENCH_<pr>.json``).

The paper's headline is that time-warp emulation runs the serving timeline
5–17× faster than real execution; this figure is the repo's standing
measurement of *how fast the emulator itself goes*, tracked per-PR so the
coordination hot path cannot silently regress.  Four layers:

**Coordination microbenchmark** — N synthetic actors drive one Timekeeper
through a fixed schedule of 1 ms jump targets under a manual wall (pure
protocol cost, zero engine work), once through the legacy per-target
re-send loop (``unbatched``) and once through :meth:`TimeJumpClient.jump_run`
runs that the barrier resolves as merged bursts (``batched``).  The batched
path must hold ≥ 2× events/sec at 8 actors — that assertion is the fast
path's regression gate.

**Wire microbenchmark** — the same jump traffic from real child
*processes* (one bare :class:`TimeJumpClient` each, staggered cadences, no
engine) over each wire, isolating pure transport cost: frame fan-in, epoch
fan-out, and the context switches per event.  Reported as
``summary.shm_wire_speedup_at_8``; ungated — the gate binds on the
end-to-end cells below, where the transport carries a real serving stack.

**End-to-end cells** — the same ``cluster_scaling``-derived scenario on the
thread backend (2/4/8 replicas) and the process backend over BOTH wires
(tcp and shm, 2/4/8/16 replicas), reporting emulated engine steps per wall
second, virtual-seconds-per-wall-second (the emulation speedup), barrier
rounds/sec, and the Timekeeper's batching counters so barrier pressure is
visible in the artifact.  The shm transport (PR 9) replaces per-replica
epoch broadcast writes with one seqlock word store, every child clock
read with a lock-free shared-memory load, and the per-jump ack round trip
with a pre-send epoch read off the word (one-way fan-in);
``summary.shm_speedup_at_8`` is its regression gate (≥ 2× tcp events/sec
at 8 replicas, full mode).

**Diurnal headline cell** — an hour of virtual time on a 100-replica shm
pool replaying the ``scale_stream`` diurnal trace as a streaming session
workload with ``audit="sampled"``: the paper-style capacity claim (a whole
production hour, a hundred engines, minutes of wall time on one machine)
as a single tracked number.

Writes ``BENCH_9.json`` at the repo root (schema:
``tools/bench_trajectory.py``; CI validates it and uploads it as an
artifact).
"""

from __future__ import annotations

import argparse
import platform
import threading
import time
from pathlib import Path

from benchmarks.common import emit, print_table
from repro.scenario import get_preset, run, scenario_with

REPO_ROOT = Path(__file__).resolve().parent.parent
PR_NUMBER = 9

ACTOR_COUNTS = [2, 4, 8]
THREAD_REPLICAS = [2, 4, 8]
PROCESS_REPLICAS = [2, 4, 8, 16]
STEP_S = 1e-3          # microbench jump size
CHUNK = 40             # targets per jump_run request

# Diurnal headline sizing per mode: (replicas, virtual seconds, session
# arrival qps).  Session count follows as qps * virtual_s, and the trace's
# eight relative-rate segments are stretched to cover exactly one cycle.
# The full cell keeps the paper-shaped 100-replica virtual hour; qps is
# picked so the run finishes in minutes of wall time on a small host:
# ~20 engine events per session at these think times, and a 100-wide
# barrier sustains ~400 events/s/core steady state (the Timekeeper's
# idle sweep is O(replicas)), so qps 3 lands near ten minutes.
DIURNAL = {
    "full": (100, 3600.0, 3.0),
    "quick": (16, 240.0, 50.0),
    "smoke": (4, 24.0, 50.0),
}


# =========================================================================
# coordination microbenchmark (protocol cost only)
# =========================================================================

def coordination_cell(actors: int, steps: int, batched: bool) -> dict:
    """N actor threads × ``steps`` 1 ms targets against one Timekeeper.

    Manual wall source: virtual time moves *only* through barrier
    resolutions, so events/sec is pure coordination throughput — lock
    round-trips, condition-variable wakeups, burst merging — with no
    sleep-based noise floor.
    """
    from repro.core.client import LocalTransport, TimeJumpClient
    from repro.core.clock import ManualWallSource, VirtualClock
    from repro.core.timekeeper import Timekeeper

    tk = Timekeeper(clock=VirtualClock(ManualWallSource()),
                    jitter_cooldown=0.0)
    tr = LocalTransport(tk)
    clients = [TimeJumpClient(tr, f"w{i}", batched=batched)
               for i in range(actors)]
    start = threading.Barrier(actors + 1)

    def drive(c: "TimeJumpClient") -> None:
        start.wait()
        if batched:
            done = 0
            while done < steps:
                k = min(CHUNK, steps - done)
                t0 = c.now()
                c.jump_run([t0 + STEP_S * (j + 1) for j in range(k)])
                done += k
        else:
            for _ in range(steps):
                c.time_jump(STEP_S)
        c.deregister()

    threads = [threading.Thread(target=drive, args=(c,), daemon=True)
               for c in clients]
    for t in threads:
        t.start()
    start.wait()
    wall0 = time.perf_counter()
    for t in threads:
        t.join(timeout=600)
        assert not t.is_alive(), "coordination microbench wedged"
    wall = time.perf_counter() - wall0
    virtual = tk.clock.now()
    stats = tk.stats
    row = {
        "actors": actors,
        "coordination_mode": "batched" if batched else "unbatched",
        "events": actors * steps,
        "wall_s": round(wall, 4),
        "events_per_s": round(actors * steps / wall, 1),
        "rounds_per_s": round(stats.rounds / wall, 1),
        "virtual_per_wall": round(virtual / wall, 1),
        "rounds": stats.rounds,
        "requests": stats.requests,
        "batched_requests": stats.batched_requests,
        "merged_rounds": stats.merged_rounds,
        "coalesced_parks": stats.coalesced_parks,
    }
    tk.close()
    return row


# =========================================================================
# wire microbenchmark (transport cost only: real child processes, no engine)
# =========================================================================

def _wire_child(desc, index: int, steps: int, barrier) -> None:
    """Spawn target: one bare TimeJumpClient over the chosen wire."""
    from repro.core.client import TimeJumpClient

    if desc[0] == "shm":
        from repro.core.shm_transport import ShmEndpoint
        transport = ShmEndpoint.attach(desc[1]).child_transport()
    else:
        from repro.core.transport import SocketTransport
        transport = SocketTransport(tuple(desc[1]))
    client = TimeJumpClient(transport, f"wire{index}")
    # Staggered cadences (actor i jumps (i+1)x1 ms steps): replicas in a
    # real pool run at different phases/durations, so each barrier round
    # releases only the actor(s) whose target arrived.  Lockstep-identical
    # targets would be the degenerate case where every broadcast usefully
    # wakes everyone — it hides the fan-out cost this cell exists to
    # measure.  Every actor covers the same virtual horizon (fast cadences
    # take more jumps), keeping the barrier at full width for the whole
    # run instead of draining from the fastest actor up.
    dt = STEP_S * (index + 1)
    horizon = steps * STEP_S * 4.0
    n = max(1, round(horizon / dt))
    barrier.wait(timeout=120)
    for _ in range(n):
        client.time_jump(dt)
    client.deregister()
    close = getattr(transport, "close", None)
    if close is not None:
        close()


def wire_cell(transport: str, replicas: int, steps: int) -> dict:
    """N bare actor *processes* × ``steps`` 1 ms single-target jumps against
    one Timekeeper over the real wire — no engine, no scheduler.

    Events/sec here is pure transport throughput: frame round-trip, barrier
    resolution, epoch broadcast, wake latency.  This is the cell the
    shm ≥ 2× tcp gate binds on — the e2e cells below keep the serving
    stack's per-step CPU work, which is identical on both wires and so
    dilutes the wire difference both sides pay it on top of.
    """
    import multiprocessing
    ctx = multiprocessing.get_context("spawn")
    barrier = ctx.Barrier(replicas + 1)
    procs: list = []
    endpoints: list = []
    if transport == "shm":
        from repro.core.shm_transport import ShmEndpoint, ShmTimekeeperServer
        server = ShmTimekeeperServer(jitter_cooldown=0.0)
        for i in range(replicas):
            ep = ShmEndpoint.create(server.clock_word.name)
            proc = ctx.Process(target=_wire_child,
                               args=(("shm", ep.spec), i, steps, barrier),
                               daemon=True)
            proc.start()
            ep.accept_wakes(5.0)
            server.serve(ep.tk_c2p, ep.tk_p2c, peer_alive=proc.is_alive,
                         name=f"wire-shm-{i}")
            procs.append(proc)
            endpoints.append(ep)
    else:
        from repro.core.transport import TimekeeperServer
        server = TimekeeperServer(jitter_cooldown=0.0)
        addr = tuple(server.address)
        for i in range(replicas):
            proc = ctx.Process(target=_wire_child,
                               args=(("tcp", addr), i, steps, barrier),
                               daemon=True)
            proc.start()
            procs.append(proc)
    barrier.wait(timeout=120)
    wall0 = time.perf_counter()
    for proc in procs:
        proc.join(timeout=600)
        assert proc.exitcode == 0, \
            f"wire child wedged/crashed (exit {proc.exitcode})"
    wall = time.perf_counter() - wall0
    server.close()
    for ep in endpoints:
        ep.unlink()
    # Mirrors the per-child jump count: equal virtual horizon per actor.
    events = sum(max(1, round(steps * 4.0 / (i + 1)))
                 for i in range(replicas))
    return {
        "transport": transport,
        "replicas": replicas,
        "events": events,
        "wall_s": round(wall, 4),
        "events_per_s": round(events / wall, 1),
    }


# =========================================================================
# end-to-end cells (full serving stack)
# =========================================================================

def e2e_scenario(replicas: int, n: int):
    """Load-scaled cluster_scaling derivative: requests and arrival rate
    grow with the pool so every replica count runs at comparable per-replica
    pressure (otherwise big pools idle and measure park churn, not steps)."""
    return scenario_with(
        get_preset("cluster_scaling"),
        name=f"emu_speed[{replicas}r]",
        **{"workload.num_requests": n * replicas,
           "workload.qps": 8.0 * replicas,
           "workload.prompt_len_mean": 120.0,
           "workload.output_len_mean": 16.0,
           "workload.max_output_len": 24,
           "pool.replicas": replicas,
           "pool.step_time_s": 20e-3,
           "pool.enable_prefix_caching": False,
           "slo.ttft_s": None,
           "seed": 29})


def e2e_cell(backend: str, replicas: int, n: int) -> dict:
    """One serving run.  ``backend`` may be a wire alias (``process-tcp`` /
    ``process-shm``); the artifact row keeps ``backend`` in the schema's
    thread|process enum and carries the wire in ``transport``."""
    res = run(e2e_scenario(replicas, n), backend=backend, timeout=3600)
    tks = res.timekeeper or {}
    wall = max(res.wall_seconds, 1e-9)
    base, _, transport = backend.partition("-")
    row = {
        "backend": base,
        "replicas": replicas,
        "events": res.num_steps,
        "requests": res.num_requests,
        "wall_s": round(res.wall_seconds, 3),
        "virtual_s": round(res.makespan_virtual, 3),
        "events_per_s": round(res.num_steps / wall, 1),
        "rounds_per_s": round(tks.get("rounds", 0) / wall, 1),
        "virtual_per_wall": round(res.makespan_virtual / wall, 1),
        "timekeeper": tks,
    }
    if transport:
        row["transport"] = transport
    return row


def e2e_cells(n: int) -> list:
    cells = [e2e_cell("thread", r, n) for r in THREAD_REPLICAS]
    for transport in ("tcp", "shm"):
        cells += [e2e_cell(f"process-{transport}", r, n)
                  for r in PROCESS_REPLICAS]
    return cells


# =========================================================================
# diurnal headline cell (100-replica virtual hour over shm)
# =========================================================================

def diurnal_cell(replicas: int, virtual_s: float, qps: float) -> dict:
    """Replay one diurnal cycle of ``virtual_s`` virtual seconds of
    streaming sessions on a ``replicas``-wide process-shm pool.

    Sessions arrive at ``qps`` against the scale_stream rate shape
    stretched to one cycle per run; ``audit="sampled"`` keeps memory flat
    (O(1) sketches) regardless of session count; think times are short so
    the barrier population stays dominated by the replicas themselves.
    """
    sessions = int(qps * virtual_s)
    trace = [[virtual_s / 8.0, r] for r in
             (0.3, 0.6, 1.0, 1.5, 1.7, 1.3, 0.8, 0.4)]
    scenario = scenario_with(
        get_preset("scale_stream"),
        name=f"diurnal[{replicas}r,{int(virtual_s)}s]",
        **{"workload.num_sessions": sessions,
           "workload.qps": qps,
           "workload.think_time_mean": 0.02,
           "workload.arrival_kwargs": {"trace": trace},
           "pool.replicas": replicas})
    t0 = time.monotonic()
    res = run(scenario, backend="process-shm", audit="sampled",
              timeout=7200)
    wall = max(time.monotonic() - t0, 1e-9)
    return {
        "backend": "process",
        "transport": "shm",
        "replicas": replicas,
        "sessions": sessions,
        "events": res.num_steps,
        "wall_s": round(wall, 3),
        "virtual_s": round(res.makespan_virtual, 3),
        "events_per_s": round(res.num_steps / wall, 1),
        "virtual_per_wall": round(res.makespan_virtual / wall, 3),
    }


# =========================================================================
# figure entry points
# =========================================================================

def rows(n: int = 24, coord_steps: int = 400) -> list:
    coord = [coordination_cell(a, coord_steps, batched)
             for a in ACTOR_COUNTS for batched in (False, True)]
    wire = [wire_cell(t, 8, coord_steps) for t in ("tcp", "shm")]
    return coord + wire + e2e_cells(n)


def _bench_doc(coord: list, wire: list, e2e: list, diurnal: dict,
               mode: str) -> dict:
    by_mode = {(r["actors"], r["coordination_mode"]): r for r in coord}
    speedup_at_8 = (by_mode[(8, "batched")]["events_per_s"]
                    / by_mode[(8, "unbatched")]["events_per_s"])
    by_wire = {(r.get("transport"), r["replicas"]): r for r in e2e
               if r["backend"] == "process"}
    shm_at_8 = (by_wire[("shm", 8)]["events_per_s"]
                / by_wire[("tcp", 8)]["events_per_s"])
    wire_by = {r["transport"]: r for r in wire}
    shm_wire_at_8 = (wire_by["shm"]["events_per_s"]
                     / wire_by["tcp"]["events_per_s"])
    return {
        "bench": "emu_speed",
        "pr": PR_NUMBER,
        "schema_version": 1,
        "mode": mode,
        "host": {
            "python": platform.python_version(),
            "platform": platform.platform(),
            "cpus": __import__("os").cpu_count() or 1,
        },
        "coordination": coord,
        "wire": wire,
        "end_to_end": [{k: v for k, v in r.items()} for r in e2e],
        "diurnal": diurnal,
        "summary": {
            "batched_speedup_at_8": round(speedup_at_8, 2),
            "shm_speedup_at_8": round(shm_at_8, 2),
            "shm_wire_speedup_at_8": round(shm_wire_at_8, 2),
            "max_events_per_s": max(
                float(r["events_per_s"]) for r in coord + e2e),
            "max_virtual_per_wall": max(
                float(r["virtual_per_wall"]) for r in e2e),
        },
    }


def main(n: int = 24, coord_steps: int = 400, mode: str = "full") -> list:
    from tools.bench_trajectory import write_bench

    coord = [coordination_cell(a, coord_steps, batched)
             for a in ACTOR_COUNTS for batched in (False, True)]
    print_table(coord, cols=["actors", "coordination_mode", "events",
                             "wall_s", "events_per_s", "rounds_per_s",
                             "virtual_per_wall", "batched_requests",
                             "merged_rounds", "coalesced_parks"])
    wire = [wire_cell(t, 8, coord_steps) for t in ("tcp", "shm")]
    print_table(wire)
    e2e = e2e_cells(n)
    printable = [{**{k: v for k, v in r.items() if k != "timekeeper"},
                  "rounds": r["timekeeper"].get("rounds", 0),
                  "batched_requests":
                      r["timekeeper"].get("batched_requests", 0),
                  "coalesced_parks":
                      r["timekeeper"].get("coalesced_parks", 0)}
                 for r in e2e]
    print_table(printable)

    replicas, virtual_s, qps = DIURNAL[mode]
    diurnal = diurnal_cell(replicas, virtual_s, qps)
    print_table([diurnal])
    emit("fig_emu_speed", coord + wire + printable + [diurnal])

    doc = _bench_doc(coord, wire, e2e, diurnal, mode)
    out = write_bench(doc, REPO_ROOT / f"BENCH_{PR_NUMBER}.json")
    print(f"[fig_emu_speed] trajectory point -> {out}")

    speedup = doc["summary"]["batched_speedup_at_8"]
    assert speedup >= 2.0, (
        f"batched coordination regressed: {speedup:.2f}x events/sec over "
        f"unbatched at 8 actors (gate: >= 2.0x)")
    shm_speedup = doc["summary"]["shm_speedup_at_8"]
    if mode == "full":
        # Smoke/quick cells are too small for a stable ratio (process
        # startup dominates); the gate binds on the committed full run.
        assert shm_speedup >= 2.0, (
            f"shm transport below its gate: {shm_speedup:.2f}x tcp "
            f"events/sec at 8 replicas (gate: >= 2.0x)")
    print(f"batched coordination: {speedup:.2f}x events/sec over the "
          f"unbatched path at 8 actors; shm wire: {shm_speedup:.2f}x tcp "
          f"events/sec end-to-end at 8 replicas "
          f"({doc['summary']['shm_wire_speedup_at_8']:.2f}x transport-only); "
          f"diurnal: {diurnal['replicas']} "
          f"replicas x {diurnal['virtual_s']:.0f} virtual s in "
          f"{diurnal['wall_s']:.0f} wall s; best end-to-end "
          f"{doc['summary']['max_virtual_per_wall']:.0f}x virtual/wall")
    return coord + wire + printable + [diurnal]


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes: CI rot-check, not results")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run_mode = "smoke" if args.smoke else ("quick" if args.quick else "full")
    # Full-mode e2e cells use n=96 requests/replica: small cells are
    # dominated by spawn + registration wall time, which dilutes the
    # wire-level shm-vs-tcp ratio the gate binds on.
    sizes = {"full": (96, 400), "quick": (12, 200), "smoke": (6, 120)}
    n_, steps_ = sizes[run_mode]
    main(n=n_, coord_steps=steps_, mode=run_mode)
