"""Raw emulation speed — the perf trajectory figure (``BENCH_<pr>.json``).

The paper's headline is that time-warp emulation runs the serving timeline
5–17× faster than real execution; this figure is the repo's standing
measurement of *how fast the emulator itself goes*, tracked per-PR so the
coordination hot path cannot silently regress.  Two layers:

**Coordination microbenchmark** — N synthetic actors drive one Timekeeper
through a fixed schedule of 1 ms jump targets under a manual wall (pure
protocol cost, zero engine work), once through the legacy per-target
re-send loop (``unbatched``) and once through :meth:`TimeJumpClient.jump_run`
runs that the barrier resolves as merged bursts (``batched``).  The batched
path must hold ≥ 2× events/sec at 8 actors — that assertion is the fast
path's regression gate.

**End-to-end cells** — the same ``cluster_scaling``-derived scenario at 2/4/8
replicas on the thread and process backends, reporting emulated engine
steps per wall second, virtual-seconds-per-wall-second (the emulation
speedup), barrier rounds/sec, and the Timekeeper's batching counters
(``batched_requests``, ``merged_rounds``, ``coalesced_parks``) so barrier
pressure is visible in the artifact.

Writes ``BENCH_6.json`` at the repo root (schema:
``tools/bench_trajectory.py``; CI validates it and uploads it as an
artifact).
"""

from __future__ import annotations

import argparse
import platform
import threading
import time
from pathlib import Path

from benchmarks.common import emit, print_table
from repro.scenario import get_preset, run, scenario_with

REPO_ROOT = Path(__file__).resolve().parent.parent
PR_NUMBER = 6

ACTOR_COUNTS = [2, 4, 8]
REPLICAS = [2, 4, 8]
BACKENDS = ["thread", "process"]
STEP_S = 1e-3          # microbench jump size
CHUNK = 40             # targets per jump_run request


# =========================================================================
# coordination microbenchmark (protocol cost only)
# =========================================================================

def coordination_cell(actors: int, steps: int, batched: bool) -> dict:
    """N actor threads × ``steps`` 1 ms targets against one Timekeeper.

    Manual wall source: virtual time moves *only* through barrier
    resolutions, so events/sec is pure coordination throughput — lock
    round-trips, condition-variable wakeups, burst merging — with no
    sleep-based noise floor.
    """
    from repro.core.client import LocalTransport, TimeJumpClient
    from repro.core.clock import ManualWallSource, VirtualClock
    from repro.core.timekeeper import Timekeeper

    tk = Timekeeper(clock=VirtualClock(ManualWallSource()),
                    jitter_cooldown=0.0)
    tr = LocalTransport(tk)
    clients = [TimeJumpClient(tr, f"w{i}", batched=batched)
               for i in range(actors)]
    start = threading.Barrier(actors + 1)

    def drive(c: "TimeJumpClient") -> None:
        start.wait()
        if batched:
            done = 0
            while done < steps:
                k = min(CHUNK, steps - done)
                t0 = c.now()
                c.jump_run([t0 + STEP_S * (j + 1) for j in range(k)])
                done += k
        else:
            for _ in range(steps):
                c.time_jump(STEP_S)
        c.deregister()

    threads = [threading.Thread(target=drive, args=(c,), daemon=True)
               for c in clients]
    for t in threads:
        t.start()
    start.wait()
    wall0 = time.perf_counter()
    for t in threads:
        t.join(timeout=600)
        assert not t.is_alive(), "coordination microbench wedged"
    wall = time.perf_counter() - wall0
    virtual = tk.clock.now()
    stats = tk.stats
    row = {
        "actors": actors,
        "coordination_mode": "batched" if batched else "unbatched",
        "events": actors * steps,
        "wall_s": round(wall, 4),
        "events_per_s": round(actors * steps / wall, 1),
        "rounds_per_s": round(stats.rounds / wall, 1),
        "virtual_per_wall": round(virtual / wall, 1),
        "rounds": stats.rounds,
        "requests": stats.requests,
        "batched_requests": stats.batched_requests,
        "merged_rounds": stats.merged_rounds,
        "coalesced_parks": stats.coalesced_parks,
    }
    tk.close()
    return row


# =========================================================================
# end-to-end cells (full serving stack)
# =========================================================================

def e2e_scenario(replicas: int, n: int):
    """Load-scaled cluster_scaling derivative: requests and arrival rate
    grow with the pool so every replica count runs at comparable per-replica
    pressure (otherwise big pools idle and measure park churn, not steps)."""
    return scenario_with(
        get_preset("cluster_scaling"),
        name=f"emu_speed[{replicas}r]",
        **{"workload.num_requests": n * replicas,
           "workload.qps": 8.0 * replicas,
           "workload.prompt_len_mean": 120.0,
           "workload.output_len_mean": 16.0,
           "workload.max_output_len": 24,
           "pool.replicas": replicas,
           "pool.step_time_s": 20e-3,
           "pool.enable_prefix_caching": False,
           "slo.ttft_s": None,
           "seed": 29})


def e2e_cell(backend: str, replicas: int, n: int) -> dict:
    res = run(e2e_scenario(replicas, n), backend=backend, timeout=3600)
    tks = res.timekeeper or {}
    wall = max(res.wall_seconds, 1e-9)
    return {
        "backend": backend,
        "replicas": replicas,
        "events": res.num_steps,
        "requests": res.num_requests,
        "wall_s": round(res.wall_seconds, 3),
        "virtual_s": round(res.makespan_virtual, 3),
        "events_per_s": round(res.num_steps / wall, 1),
        "rounds_per_s": round(tks.get("rounds", 0) / wall, 1),
        "virtual_per_wall": round(res.makespan_virtual / wall, 1),
        "timekeeper": tks,
    }


# =========================================================================
# figure entry points
# =========================================================================

def rows(n: int = 24, coord_steps: int = 400) -> list:
    coord = [coordination_cell(a, coord_steps, batched)
             for a in ACTOR_COUNTS for batched in (False, True)]
    e2e = [e2e_cell(b, r, n) for b in BACKENDS for r in REPLICAS]
    return coord + e2e


def _bench_doc(coord: list, e2e: list, mode: str) -> dict:
    by_mode = {(r["actors"], r["coordination_mode"]): r for r in coord}
    speedup_at_8 = (by_mode[(8, "batched")]["events_per_s"]
                    / by_mode[(8, "unbatched")]["events_per_s"])
    return {
        "bench": "emu_speed",
        "pr": PR_NUMBER,
        "schema_version": 1,
        "mode": mode,
        "host": {
            "python": platform.python_version(),
            "platform": platform.platform(),
            "cpus": __import__("os").cpu_count() or 1,
        },
        "coordination": coord,
        "end_to_end": [{k: v for k, v in r.items()} for r in e2e],
        "summary": {
            "batched_speedup_at_8": round(speedup_at_8, 2),
            "max_events_per_s": max(
                float(r["events_per_s"]) for r in coord + e2e),
            "max_virtual_per_wall": max(
                float(r["virtual_per_wall"]) for r in e2e),
        },
    }


def main(n: int = 24, coord_steps: int = 400, mode: str = "full") -> list:
    from tools.bench_trajectory import write_bench

    coord = [coordination_cell(a, coord_steps, batched)
             for a in ACTOR_COUNTS for batched in (False, True)]
    print_table(coord, cols=["actors", "coordination_mode", "events",
                             "wall_s", "events_per_s", "rounds_per_s",
                             "virtual_per_wall", "batched_requests",
                             "merged_rounds", "coalesced_parks"])
    e2e = [e2e_cell(b, r, n) for b in BACKENDS for r in REPLICAS]
    printable = [{**{k: v for k, v in r.items() if k != "timekeeper"},
                  "rounds": r["timekeeper"].get("rounds", 0),
                  "batched_requests":
                      r["timekeeper"].get("batched_requests", 0),
                  "coalesced_parks":
                      r["timekeeper"].get("coalesced_parks", 0)}
                 for r in e2e]
    print_table(printable)
    emit("fig_emu_speed", coord + printable)

    doc = _bench_doc(coord, e2e, mode)
    out = write_bench(doc, REPO_ROOT / f"BENCH_{PR_NUMBER}.json")
    print(f"[fig_emu_speed] trajectory point -> {out}")

    speedup = doc["summary"]["batched_speedup_at_8"]
    assert speedup >= 2.0, (
        f"batched coordination regressed: {speedup:.2f}x events/sec over "
        f"unbatched at 8 actors (gate: >= 2.0x)")
    print(f"batched coordination: {speedup:.2f}x events/sec over the "
          f"unbatched path at 8 actors; best end-to-end "
          f"{doc['summary']['max_virtual_per_wall']:.0f}x virtual/wall")
    return coord + printable


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes: CI rot-check, not results")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run_mode = "smoke" if args.smoke else ("quick" if args.quick else "full")
    sizes = {"full": (24, 400), "quick": (12, 200), "smoke": (6, 120)}
    n_, steps_ = sizes[run_mode]
    main(n=n_, coord_steps=steps_, mode=run_mode)
