"""Shared benchmark plumbing: stack construction, workload presets, CSV rows.

Every ``figN_*.py`` module exposes ``rows() -> list[dict]`` (machine-readable
results) and ``main()`` (prints a human table + the aggregate CSV line the
harness collects).  ``benchmarks.run`` executes all of them.
"""

from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict, List, Optional

ARTIFACTS = Path(__file__).parent / "artifacts"


def emit(name: str, rows: List[Dict]) -> None:
    """Persist benchmark rows as a JSONL artifact.

    The artifacts directory is created here, not at import time: importing a
    benchmark module (docs examples, tests, ``--only`` filtering) must stay
    side-effect free."""
    ARTIFACTS.mkdir(exist_ok=True)
    path = ARTIFACTS / f"{name}.jsonl"
    with open(path, "w") as f:
        for r in rows:
            f.write(json.dumps(r) + "\n")
    print(f"[{name}] {len(rows)} rows -> {path}")


def print_table(rows: List[Dict], cols: Optional[List[str]] = None) -> None:
    if not rows:
        print("(no rows)")
        return
    cols = cols or list(rows[0].keys())
    widths = {c: max(len(c), *(len(_fmt(r.get(c))) for r in rows)) for c in cols}
    print("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        print("  ".join(_fmt(r.get(c)).ljust(widths[c]) for c in cols))


def _fmt(v) -> str:
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1e4 or abs(v) < 1e-3:
            return f"{v:.3e}"
        return f"{v:.4g}"
    return str(v)


def paper_parallelism(arch: str) -> dict:
    """The paper's §6.1 deployment configs."""
    return {
        "llama3_8b": dict(tp=1, pp=1, ep=1),
        "llama3_70b": dict(tp=4, pp=1, ep=1),
        "qwen3_30b_a3b": dict(tp=1, pp=1, ep=2),
    }.get(arch, dict(tp=1, pp=1, ep=1))


def sharegpt_workload(n=100, qps=2.0, seed=0, **kw):
    from repro.workload import WorkloadConfig, synthesize
    base = dict(num_requests=n, qps=qps, prompt_len_mean=220.0,
                output_len_mean=180.0, seed=seed)
    base.update(kw)
    return synthesize(WorkloadConfig(**base))


def small_workload(n=40, qps=20.0, seed=0, **kw):
    """CPU-runnable workload for real-mode fidelity benchmarks."""
    from repro.workload import WorkloadConfig, synthesize
    base = dict(num_requests=n, qps=qps, prompt_len_mean=24.0,
                output_len_mean=8.0, max_prompt_len=96, max_output_len=16,
                vocab_size=500, seed=seed)
    base.update(kw)
    return synthesize(WorkloadConfig(**base))


def run_stack(model_cfg, engine_cfg, mode, reqs, *, predictor=None,
              model=None, params=None, max_len=256, timeout=600.0,
              use_worker_group=True):
    from repro.serving.benchmark import BenchmarkRunner
    from repro.serving.stack import build_stack
    stack = build_stack(model_cfg, engine_cfg, mode, predictor=predictor,
                        model=model, params=params, max_len=max_len,
                        use_worker_group=use_worker_group)
    try:
        return BenchmarkRunner(stack.engine, reqs,
                               transport=stack.transport).run(timeout=timeout)
    finally:
        stack.shutdown()
