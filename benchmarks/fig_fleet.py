"""Fleet consolidation — multiplexed sharing vs static partitioning
(``BENCH_10.json``).

The fleet plane's claim is the classic consolidation argument made
measurable: N models × M tenants multiplexed through one ingress over
*shared* per-model pools matches the SLO attainment of giving every tenant
a statically partitioned private copy of its pool — at materially fewer
replica-seconds.  This figure is the standing measurement of that claim.

Three blocks:

1. **Multiplexed cells** — the ``fleet_mix`` preset (two model pools, three
   tenants on 2:1:1 weighted shares, two LoRA adapters multiplexed over the
   shared chat base) on the thread emulator and the DES; per cell:
   aggregate SLO attainment, Jain fairness over per-tenant attainment,
   replica-seconds, and SLO goodput.
2. **Partitioned counterfactual** — :func:`repro.fleet.partitioned_fleet`
   rewrites the same scenario so every tenant owns a dedicated
   peak-provisioned copy of its target pool (only its own adapter
   resident).  Same workload, same ingress arithmetic — the only delta is
   who shares capacity.
3. **Fleet parity** — the multiplexed scenario through one
   :func:`repro.scenario.compare` call, thread emulator vs DES, including
   the multi-LoRA shared-base cell (two adapter tenants on one base pool):
   identical ingress + routing decisions, completed sets, and per-request
   latencies within one slow-step.

Writes ``BENCH_10.json`` at the repo root (schema + consolidation gates:
``tools/bench_trajectory.py``; CI validates it and gates the trajectory).
"""

from __future__ import annotations

import argparse
import os
import platform
import sys
from pathlib import Path

from benchmarks.common import emit, print_table
from repro.fleet import partitioned_fleet
from repro.scenario import compare, get_preset, run, scenario_with

REPO_ROOT = Path(__file__).resolve().parent.parent
PR_NUMBER = 10

# The committed artifact must keep clearing these (write_bench enforces
# them): consolidation that stops saving replica-seconds, or pays for its
# savings with SLO misses, is a regression — not a data point.
SAVING_FLOOR = 0.20
ATTAINMENT_EPSILON = 0.02
PARITY_BACKENDS = ("thread", "des")


def _base(n: int):
    return scenario_with(get_preset("fleet_mix"),
                         **{"workload.num_requests": n})


def measure(variant: str, scenario, backend: str = "thread") -> dict:
    res = run(scenario, backend=backend, timeout=3600)
    fleet = scenario.fleet
    return {
        "variant": variant,
        "backend": backend,
        "models": len(fleet.models),
        "tenants": len(fleet.tenants),
        "requests": res.num_requests,
        "attainment": round(res.tenant_attainment(), 4),
        "fairness": round(res.fairness, 4),
        "replica_seconds": round(res.replica_seconds, 3),
        "goodput_rps": round(sum(row["goodput_rps"]
                                 for row in res.tenants.values()), 3),
        "wall_s": round(res.wall_seconds, 3),
        "virtual_s": round(res.makespan_virtual, 3),
    }


def des_parity(n: int) -> dict:
    """The multiplexed fleet through ``compare``: the inductive per-pool
    parity argument (see ``repro.fleet.runner``) checked end to end, with
    the multi-LoRA shared-base cell included (tenants acme/bolt multiplex
    adapters alpha/beta over the one chat base pool)."""
    cres = compare(_base(n), backends=PARITY_BACKENDS, timeout=3600)
    return {
        "backends": ",".join(PARITY_BACKENDS),
        "max_err_steps": round(cres.max_err_steps, 3),
        "decisions_equal": cres.decisions_equal,
        "completed_equal": cres.completed_equal,
    }


def _bench_doc(cells: list, parity: dict, mode: str) -> dict:
    thread = {c["variant"]: c for c in cells if c["backend"] == "thread"}
    mux, part = thread["multiplexed"], thread["partitioned"]
    return {
        "bench": "fleet",
        "pr": PR_NUMBER,
        "schema_version": 1,
        "mode": mode,
        "host": {"python": platform.python_version(),
                 "platform": platform.platform(),
                 "cpus": os.cpu_count()},
        "cells": cells,
        "parity": parity,
        "summary": {
            "replica_seconds_saving": round(
                1.0 - mux["replica_seconds"] / part["replica_seconds"], 4),
            "attainment_multiplexed": mux["attainment"],
            "attainment_partitioned": part["attainment"],
            "min_fairness": min(c["fairness"] for c in cells),
            "saving_floor": SAVING_FLOOR,
            "attainment_epsilon": ATTAINMENT_EPSILON,
        },
    }


def main(n: int = 64, mode: str = "full") -> list:
    mux = _base(n)
    part = partitioned_fleet(mux)
    cells = []
    for backend in ("thread", "des"):
        cells.append(measure("multiplexed", mux, backend))
        cells.append(measure("partitioned", part, backend))
    print_table(cells)

    parity = des_parity(n)
    print_table([parity])
    emit("fig_fleet", cells + [parity])

    doc = _bench_doc(cells, parity, mode)
    sys.path.insert(0, str(REPO_ROOT))       # tools/ is not a package
    from tools.bench_trajectory import write_bench
    out = write_bench(doc, REPO_ROOT / f"BENCH_{PR_NUMBER}.json")
    print(f"[fig_fleet] wrote {out}")

    # ---- parity: the fleet layer must not open an emulator/DES gap ------
    assert parity["decisions_equal"] and parity["completed_equal"], \
        "fleet ingress/routing decisions or completed sets diverged"
    assert parity["max_err_steps"] <= 1.0, \
        f"fleet emulator/DES diverges by {parity['max_err_steps']} steps"

    # ---- headline: multiplexing matches partitioned attainment cheaper --
    s = doc["summary"]
    assert s["attainment_multiplexed"] >= \
        s["attainment_partitioned"] - ATTAINMENT_EPSILON, \
        (f"multiplexed attainment {s['attainment_multiplexed']} fell below "
         f"partitioned {s['attainment_partitioned']}")
    assert s["replica_seconds_saving"] >= SAVING_FLOOR, \
        (f"multiplexing saved only {s['replica_seconds_saving']:.1%} "
         f"replica-seconds vs static partitioning (floor: "
         f"{SAVING_FLOOR:.0%})")
    print(f"fleet: multiplexed fleet matches partitioned attainment "
          f"({s['attainment_multiplexed']:.1%} vs "
          f"{s['attainment_partitioned']:.1%}) at "
          f"{s['replica_seconds_saving']:.0%} fewer replica-seconds; "
          f"min fairness {s['min_fairness']:.3f}; emu/DES parity "
          f"max_err={parity['max_err_steps']} steps")
    return cells + [parity]


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    m = "smoke" if args.smoke else ("quick" if args.quick else "full")
    main(n={"full": 64, "quick": 24, "smoke": 12}[m], mode=m)
