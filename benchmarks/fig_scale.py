"""Million-session streaming replay — the scale trajectory (``BENCH_7.json``).

The streaming path's claim is *flat memory at unbounded session counts*:
lazy workloads generate requests with bounded look-ahead, and
``audit="sampled"`` metrics fold every completion into O(1)-memory sketches
instead of retained lists, so replaying 100× more sessions costs wall time
but not RSS.  This figure is the standing measurement of that claim.

Each cell replays the ``scale_stream`` preset (diurnal rate trace, 2-turn
chat sessions) at a fixed offered QPS — session count scales the *duration*
of the virtual day, not the concurrency — and reports sessions/sec,
requests/sec, virtual-s per wall-s, and the cell's own peak RSS.  Every
cell runs in a **fresh subprocess** so ``ru_maxrss`` is a clean per-cell
high-water mark rather than the max over the whole sweep.

Cells override ``think_time_mean`` down to 20 ms: follow-up thinkers are
live actors in the time-warp barrier, so the concurrent thinker population
(~ qps × think time, Little's law) sets the per-round coordination cost —
short thinks keep the barrier small and the replay rate high without
changing the session *shape* (turn counts and token lengths are untouched).

The regression gate is the RSS ratio between the largest and smallest
sampled-audit cell per backend (must stay within ``RSS_FLAT_WITHIN``); a
single ``audit="full"`` contrast cell at the smallest size shows what
retention costs.  Writes ``BENCH_7.json`` at the repo root (schema:
``tools/bench_trajectory.py``; CI validates it and uploads it as an
artifact).
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import subprocess
import sys
import time
from pathlib import Path

from benchmarks.common import emit, print_table

REPO_ROOT = Path(__file__).resolve().parent.parent
PR_NUMBER = 7

THINK_TIME_S = 0.02            # keeps the thinker-actor barrier small
QPS = {"thread": 1200.0, "process": 600.0}
RSS_FLAT_WITHIN = 2.0          # largest/smallest sampled-cell RSS per backend

# session counts per mode; the full thread series ends at one million
SESSIONS = {
    "full":  {"thread": [10_000, 100_000, 1_000_000],
              "process": [10_000, 32_000, 100_000]},
    "quick": {"thread": [2_000, 10_000, 50_000],
              "process": [2_000, 10_000]},
    "smoke": {"thread": [1_000, 2_000, 4_000],
              "process": [500, 1_000]},
}


def run_cell(backend: str, sessions: int, *, audit: str = "sampled",
             qps: float = 0.0, timeout: float = 3600.0) -> dict:
    """One replay in *this* process (the ``--cell`` child entry point)."""
    import resource

    from repro.scenario import get_preset, run, scenario_with

    qps = qps or QPS[backend]
    scenario = scenario_with(get_preset("scale_stream"),
                             workload__num_sessions=sessions,
                             workload__qps=qps,
                             workload__think_time_mean=THINK_TIME_S)
    t0 = time.monotonic()
    res = run(scenario, backend=backend, audit=audit, timeout=timeout)
    wall = time.monotonic() - t0
    peak_rss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
    return {
        "backend": backend,
        "sessions": sessions,
        "requests": res.num_requests,
        "audit": audit,
        "qps": qps,
        "wall_s": round(wall, 3),
        "virtual_s": round(res.makespan_virtual, 3),
        "sessions_per_s": round(sessions / wall, 1),
        "requests_per_s": round(res.num_requests / wall, 1),
        "virtual_per_wall": round(res.makespan_virtual / wall, 3),
        "peak_rss_mb": round(peak_rss_mb, 1),
    }


def spawn_cell(backend: str, sessions: int, *, audit: str = "sampled",
               timeout: float = 3600.0) -> dict:
    """Run one cell in a fresh interpreter and parse its JSON result line.

    A fresh process per cell is the measurement, not a convenience: peak RSS
    is a monotone high-water mark, so sharing a process would let the
    biggest cell's footprint mask every later cell."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (str(REPO_ROOT / "src"), str(REPO_ROOT),
                    env.get("PYTHONPATH", "")) if p)
    spec = json.dumps({"backend": backend, "sessions": sessions,
                       "audit": audit, "timeout": timeout})
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.fig_scale", "--cell", spec],
        cwd=str(REPO_ROOT), env=env, capture_output=True, text=True,
        timeout=timeout + 120.0)
    if proc.returncode != 0:
        raise RuntimeError(
            f"scale cell {backend}/{sessions} failed "
            f"(rc={proc.returncode}):\n{proc.stdout[-2000:]}"
            f"\n{proc.stderr[-2000:]}")
    for line in reversed(proc.stdout.strip().splitlines()):
        if line.startswith("{"):
            return json.loads(line)
    raise RuntimeError(f"scale cell {backend}/{sessions} printed no "
                       f"JSON result:\n{proc.stdout[-2000:]}")


def _rss_ratio(cells: list, backend: str) -> float:
    series = sorted((c for c in cells
                     if c["backend"] == backend and c["audit"] == "sampled"),
                    key=lambda c: c["sessions"])
    if len(series) < 2:
        return 1.0
    return round(series[-1]["peak_rss_mb"] / series[0]["peak_rss_mb"], 3)


def _bench_doc(cells: list, mode: str) -> dict:
    sampled = [c for c in cells if c["audit"] == "sampled"]
    return {
        "bench": "scale",
        "pr": PR_NUMBER,
        "schema_version": 1,
        "mode": mode,
        "host": {"python": platform.python_version(),
                 "platform": platform.platform(),
                 "cpus": os.cpu_count()},
        "cells": cells,
        "summary": {
            "max_sessions": max(c["sessions"] for c in sampled),
            "max_sessions_per_s": max(c["sessions_per_s"] for c in sampled),
            "max_requests_per_s": max(c["requests_per_s"] for c in sampled),
            "max_virtual_per_wall": max(c["virtual_per_wall"]
                                        for c in sampled),
            "rss_ratio_thread": _rss_ratio(cells, "thread"),
            "rss_ratio_process": _rss_ratio(cells, "process"),
            "rss_flat_within": RSS_FLAT_WITHIN,
        },
    }


def main(mode: str = "full", timeout: float = 3600.0) -> list:
    sizes = SESSIONS[mode]
    cells = []
    # full-audit contrast cell first: what per-request retention costs
    contrast_n = sizes["thread"][0]
    print(f"[fig_scale] contrast cell: thread/{contrast_n} audit=full")
    cells.append(spawn_cell("thread", contrast_n, audit="full",
                            timeout=timeout))
    for backend in ("thread", "process"):
        for n in sizes[backend]:
            print(f"[fig_scale] cell: {backend}/{n} audit=sampled")
            cells.append(spawn_cell(backend, n, timeout=timeout))

    print_table(cells)
    emit("fig_scale", cells)

    doc = _bench_doc(cells, mode)
    sys.path.insert(0, str(REPO_ROOT))       # tools/ is not a package
    from tools.bench_trajectory import write_bench
    out = write_bench(doc, REPO_ROOT / f"BENCH_{PR_NUMBER}.json")
    print(f"[fig_scale] wrote {out}")

    s = doc["summary"]
    for backend in ("thread", "process"):
        ratio = s[f"rss_ratio_{backend}"]
        assert ratio <= RSS_FLAT_WITHIN, (
            f"streaming memory regression: {backend} peak RSS grew {ratio}x "
            f"across the session sweep (gate: <= {RSS_FLAT_WITHIN}x) — the "
            f"sampled-audit path is retaining per-request state somewhere")
    print(f"[fig_scale] rss flat: thread={s['rss_ratio_thread']}x "
          f"process={s['rss_ratio_process']}x (gate <= {RSS_FLAT_WITHIN}x), "
          f"max {s['max_sessions']} sessions at "
          f"{s['max_sessions_per_s']:.0f} sessions/s")
    return cells


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--timeout", type=float, default=3600.0)
    ap.add_argument("--cell", default="",
                    help=argparse.SUPPRESS)   # internal: one-cell child mode
    args = ap.parse_args()
    if args.cell:
        spec = json.loads(args.cell)
        print(json.dumps(run_cell(spec.pop("backend"), spec.pop("sessions"),
                                  **spec)))
    else:
        m = "smoke" if args.smoke else ("quick" if args.quick else "full")
        main(mode=m, timeout=args.timeout)
