"""Checkpointing for training state (params + optimizer + step metadata).

Orbax is not available offline, so checkpoints are flat ``.npz`` archives
keyed by pytree key-paths, plus a JSON sidecar for scalars.  Writes are
atomic (tmp file + rename) so a node failure mid-write never corrupts the
latest checkpoint — the restart path picks the newest *complete* step.
"""

from __future__ import annotations

import json
import os
import re
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import numpy as np

PyTree = Any

_SAFE = re.compile(r"[^A-Za-z0-9_.]+")


def _flatten(tree: PyTree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_SAFE.sub("_", str(getattr(p, "key", getattr(p, "idx", p))))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(ckpt_dir: str | Path, step: int, params: PyTree,
                    opt_state: PyTree, *, extra: Optional[dict] = None) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}.npz"
    tmp = ckpt_dir / f".tmp_step_{step:08d}.npz"
    blobs = {}
    for prefix, tree in (("params", params), ("opt", opt_state)):
        for k, v in _flatten(tree).items():
            blobs[f"{prefix}/{k}"] = v
    with open(tmp, "wb") as f:
        np.savez(f, **blobs)
    meta = {"step": step, **(extra or {})}
    (ckpt_dir / f"step_{step:08d}.json").write_text(json.dumps(meta))
    os.replace(tmp, final)          # atomic publish
    return final


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(p.stem.split("_")[1]) for p in ckpt_dir.glob("step_*.npz")]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str | Path, step: int, params_like: PyTree,
                       opt_like: PyTree) -> Tuple[PyTree, PyTree, dict]:
    """Restore into the structure of (params_like, opt_like) templates."""
    ckpt_dir = Path(ckpt_dir)
    data = np.load(ckpt_dir / f"step_{step:08d}.npz")
    meta = json.loads((ckpt_dir / f"step_{step:08d}.json").read_text())

    def rebuild(prefix: str, tree: PyTree) -> PyTree:
        flat_keys = list(_flatten(tree).keys())
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        new_leaves = []
        for key, leaf in zip(flat_keys, leaves):
            arr = data[f"{prefix}/{key}"]
            assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
            new_leaves.append(arr.astype(leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef, new_leaves)

    return rebuild("params", params_like), rebuild("opt", opt_like), meta
