"""Optimizer substrate: AdamW with decoupled weight decay, global-norm
clipping, cosine schedule with warmup, and a gradient-compression knob.

Implemented from scratch (no optax offline) in the functional style the rest
of the framework uses: ``opt_state`` is a pytree sharded with the same rules
as the parameters (ZeRO: first/second moments inherit the param sharding,
which the mesh rules extend over the data axis — see repro.launch.mesh).

``grad_allreduce_dtype``: casting gradients to bf16 before the data-parallel
mean halves cross-pod all-reduce bytes (distributed-optimization trick; the
cast happens before pjit's automatic reduction because the loss is computed
in the cast dtype).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    grad_allreduce_dtype: Optional[str] = "bfloat16"


class AdamWState(NamedTuple):
    step: jax.Array     # ()
    mu: PyTree          # first moment  (param-shaped)
    nu: PyTree          # second moment (param-shaped)


def init_adamw(params: PyTree) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def abstract_adamw(params: PyTree) -> AdamWState:
    return jax.eval_shape(init_adamw, params)


def cosine_lr(cfg: OptimizerConfig, step) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    progress = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(math.pi * progress))
    scale = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    cfg: OptimizerConfig, params: PyTree, grads: PyTree, state: AdamWState
) -> Tuple[PyTree, AdamWState, dict]:
    if cfg.grad_allreduce_dtype:
        grads = jax.tree.map(
            lambda g: g.astype(cfg.grad_allreduce_dtype).astype(jnp.float32), grads)
    else:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g * clip, grads)

    step = state.step + 1
    b1, b2 = cfg.betas
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cosine_lr(cfg, step)

    def upd(p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (standard practice)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v), metrics


# --------------------------------------------------------------------------
def make_train_step(model, opt_cfg: OptimizerConfig, *, microbatches: int = 1,
                    remat: bool = True):
    """Builds the jittable train_step.

    With ``microbatches > 1`` the batch's leading axis is split and gradients
    are accumulated with ``jax.lax.scan`` (sequential microbatching keeps
    activation memory at 1/k while the optimizer update stays per-step).
    """

    def loss_fn(params, batch):
        loss, metrics = model.train_loss(params, batch, remat=remat)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc(carry, mbatch):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(params, mbatch)
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (g_sum, l_sum), _ = jax.lax.scan(acc, (zeros, jnp.float32(0)), mb)
            grads = jax.tree.map(lambda g: g / microbatches, g_sum)
            loss = l_sum / microbatches
            metrics = {"loss": loss}
        params, opt_state, opt_metrics = adamw_update(opt_cfg, params, grads, opt_state)
        metrics = dict(metrics, **opt_metrics)
        return params, opt_state, metrics

    return train_step
