"""Model assembly: decoder LMs (dense / MoE / SSM / hybrid) and the
whisper-style encoder-decoder, as pure-functional models.

Three execution entry points per model (the serving engine and the dry-run
launcher lower exactly these):

* ``train_loss(params, batch)``            — teacher-forced LM loss.
* ``prefill(params, inputs, cache)``       — process T>=1 new tokens against
  an existing cache (chunked prefill = repeated calls; fresh cache = full
  prefill).  Returns logits of the last position.
* ``decode_step(params, cache, tokens)``   — T=1 specialisation.

Layer iteration strategy:

* uniform ``layer_pattern`` (all archs but RecurrentGemma) — parameters are
  stacked with a leading layer axis and iterated with ``jax.lax.scan``
  (compile time O(1) in depth; remat applied to the body for training);
* mixed patterns — an unrolled Python loop over per-layer parameter trees
  (RecurrentGemma's 26 layers compile fine unrolled).

KV cache layout (``extend`` mode):

* attention layers: ``k``/``v`` of shape (L, B, S, Hkv, D) plus a shared
  position tag array ``kv_pos`` (B, S) with −1 for empty slots.  Windowed
  layers allocate S = window and write round-robin (``idx % S``) — the tag
  array makes ring masking trivial and is what bounds `long_500k` memory for
  SWA models (mixtral).
* SSD layers: fp32 state (L, B, H, N, P) + conv state.
* RG-LRU layers: fp32 state (L, B, W) + conv state.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from .config import ModelConfig

PyTree = Any


def _scores_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.attn_scores_dtype == "bfloat16" else jnp.float32


# ==========================================================================
# per-block parameter init / apply
# ==========================================================================

def block_params(cfg: ModelConfig, kind: str, key, dtype) -> Dict:
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": L.norm_params(cfg, dtype)}
    if kind in ("attn", "local_attn"):
        p["attn"] = L.attn_params(cfg, ks[0], dtype)
        p["norm2"] = L.norm_params(cfg, dtype)
        if cfg.moe is not None:
            p["moe"] = L.moe_params(cfg, ks[1], dtype)
        else:
            p["mlp"] = L.mlp_params(cfg, ks[1], dtype)
    elif kind == "rglru":
        p["rglru"] = L.rglru_params(cfg, ks[0], dtype)
        p["norm2"] = L.norm_params(cfg, dtype)
        p["mlp"] = L.mlp_params(cfg, ks[1], dtype)
    elif kind == "ssd":
        p["ssd"] = L.ssd_params(cfg, ks[0], dtype)
    else:
        raise ValueError(kind)
    return p


def block_cache(cfg: ModelConfig, kind: str, batch: int, cache_size: int, dtype,
                window_slack: int = 0):
    """Per-layer cache leaves (no leading layer axis; stacking happens above).

    ``window_slack`` grows windowed ring buffers beyond the window.  The
    real-mode runner uses it as a scratch region so *padded* prefill
    positions (written at indices >= the real context) can never alias live
    ring slots; masking stays correct because windows are enforced by
    position tags, not buffer size."""
    if kind in ("attn", "local_attn"):
        S = _cache_span(cfg, kind, cache_size) + window_slack
        shape = (batch, S, cfg.num_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
                "kv_pos": jnp.full((batch, S), -1, jnp.int32)}
    if kind == "ssd":
        ssm = cfg.ssm
        H = ssm.num_heads(cfg.d_model)
        return {
            "state": jnp.zeros((batch, H, ssm.state_dim, ssm.head_dim), jnp.float32),
            "conv": jnp.zeros(
                (batch, ssm.conv_width - 1,
                 ssm.d_inner(cfg.d_model) + 2 * ssm.state_dim), jnp.float32),
        }
    if kind == "rglru":
        rg = cfg.rglru
        return {
            "state": jnp.zeros((batch, rg.lru_width), jnp.float32),
            "conv": jnp.zeros((batch, rg.conv_width - 1, rg.lru_width), jnp.float32),
        }
    raise ValueError(kind)


def _cache_span(cfg: ModelConfig, kind: str, cache_size: int) -> int:
    if kind == "local_attn" or (kind == "attn" and cfg.sliding_window):
        return min(cache_size, cfg.sliding_window)
    return cache_size


def run_block(
    cfg: ModelConfig,
    kind: str,
    p: Dict,
    x,                       # (B, T, d)
    positions,               # (B, T) absolute positions of the new tokens
    cache: Optional[Dict],   # per-layer cache dict or None (train mode)
    *,
    enc_kv: Optional[Tuple] = None,   # cross-attention K/V (enc-dec decoder)
    cross_p: Optional[Dict] = None,
) -> Tuple[jax.Array, Optional[Dict], Dict]:
    """One residual block.  Returns (y, new_cache, aux)."""
    aux: Dict[str, Any] = {}
    new_cache: Optional[Dict] = None
    h = L.apply_norm(cfg, x, p["norm1"])

    if kind in ("attn", "local_attn"):
        window = cfg.sliding_window if (kind == "local_attn" or cfg.sliding_window) else None
        q, k_new, v_new = L.attn_qkv(cfg, p["attn"], h, positions)
        if cache is None:
            mask = L.causal_mask(positions, positions, window)
            ctx = L.attention(q, k_new, v_new, mask,
                              scores_dtype=_scores_dtype(cfg))
        elif cfg.kv_append == "defer":
            # §Perf "kv_defer_append": attend over [stale cache ‖ new chunk]
            # via an exact two-segment online-softmax merge; the cache write
            # happens ONCE for all layers after the stack (one in-place
            # scatter) instead of a full per-layer cache rewrite inside the
            # scan carry.  Unwritten/stale slots are masked by kv_pos tags.
            mask_c = L.causal_mask(positions, cache["kv_pos"], window)
            mask_s = L.causal_mask(positions, positions, window)
            sd = _scores_dtype(cfg)
            seg_c = L.attention_partial(q, cache["k"], cache["v"], mask_c,
                                        scores_dtype=sd)
            seg_s = L.attention_partial(q, k_new, v_new, mask_s,
                                        scores_dtype=sd)
            ctx = L.attention_merge2(seg_c, seg_s, x.dtype)
            new_cache = {"k_new": k_new.astype(cache["k"].dtype),
                         "v_new": v_new.astype(cache["v"].dtype)}
        else:
            S = cache["k"].shape[1]
            B, T = positions.shape
            widx = positions % S                                   # ring or linear
            b_idx = jnp.arange(B)[:, None]
            k_c = cache["k"].at[b_idx, widx].set(k_new.astype(cache["k"].dtype))
            v_c = cache["v"].at[b_idx, widx].set(v_new.astype(cache["v"].dtype))
            kv_pos = cache["kv_pos"].at[b_idx, widx].set(positions)
            mask = L.causal_mask(positions, kv_pos, window)
            ctx = L.attention(q, k_c, v_c, mask,
                              scores_dtype=_scores_dtype(cfg))
            new_cache = {"k": k_c, "v": v_c, "kv_pos": kv_pos}
        x = x + L.attn_out(p["attn"], ctx)
        if enc_kv is not None:
            hx = L.apply_norm(cfg, x, cross_p["norm"])
            qx = jnp.einsum("btd,dhk->bthk", hx, cross_p["attn"]["wq"])
            ek, ev = enc_kv
            xmask = L.full_mask(positions, jnp.broadcast_to(
                jnp.arange(ek.shape[1])[None, :], (ek.shape[0], ek.shape[1])))
            ctxx = L.attention(qx, ek, ev, xmask,
                               scores_dtype=_scores_dtype(cfg))
            x = x + L.attn_out(cross_p["attn"], ctxx)
        h2 = L.apply_norm(cfg, x, p["norm2"])
        if cfg.moe is not None:
            moe_fn = L.moe_a2a if cfg.moe_impl == "a2a" else L.moe
            y, moe_aux = moe_fn(cfg, p["moe"], h2)
            aux.update(moe_aux)
        else:
            y = L.mlp(cfg, p["mlp"], h2)
        x = x + y

    elif kind == "rglru":
        if cache is None:
            y, _, _ = L.rglru(cfg, p["rglru"], h)
        else:
            y, hT, convT = L.rglru(
                cfg, p["rglru"], h, h0=cache["state"], conv_state=cache["conv"])
            new_cache = {"state": hT, "conv": convT}
        x = x + y
        h2 = L.apply_norm(cfg, x, p["norm2"])
        x = x + L.mlp(cfg, p["mlp"], h2)

    elif kind == "ssd":
        if cache is None:
            y, _, _ = L.ssd_prefill(cfg, p["ssd"], h)
        elif positions.shape[1] == 1:
            y, sT, convT = L.ssd_decode_step(
                cfg, p["ssd"], h, cache["state"], cache["conv"])
            new_cache = {"state": sT, "conv": convT}
        else:
            y, sT, convT = L.ssd_prefill(
                cfg, p["ssd"], h, state=cache["state"], conv_state=cache["conv"])
            new_cache = {"state": sT, "conv": convT}
        x = x + y

    else:
        raise ValueError(kind)
    return x, new_cache, aux


def _apply_deferred_append(cache_layers, new_kv, positions, *,
                           layer_axis: bool = True):
    """Write the stacked per-layer new KV into the cache with one scatter.

    cache_layers: {"k": (L,B,S,H,D), "v": ..., "kv_pos": (L,B,S)} (or without
    the leading L when ``layer_axis=False``); new_kv: {"k_new": (L,B,T,H,D),
    "v_new": ...}.  The scatter targets are donated scan carries, so XLA
    updates them in place — traffic is the T new tokens, not the cache.
    """
    k, v, kv_pos = cache_layers["k"], cache_layers["v"], cache_layers["kv_pos"]
    S = k.shape[2] if layer_axis else k.shape[1]
    B, T = positions.shape
    widx = positions % S
    b_idx = jnp.arange(B)[:, None]
    if layer_axis:
        idx = (slice(None), b_idx, widx)
    else:
        idx = (b_idx, widx)
    return {
        "k": k.at[idx].set(new_kv["k_new"].astype(k.dtype)),
        "v": v.at[idx].set(new_kv["v_new"].astype(v.dtype)),
        "kv_pos": kv_pos.at[idx].set(positions),
    }


# ==========================================================================
# decoder-only LM
# ==========================================================================

class TransformerLM:
    """Decoder LM over any ``layer_pattern``.

    Uniform patterns use a scanned stack; mixed patterns unroll.  The public
    surface (init / train_loss / prefill / decode_step / init_cache) is what
    the serving engine, the trainer and the dry-run lower.
    """

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        kinds = set(cfg.layer_pattern)
        self.uniform: Optional[str] = cfg.layer_pattern[0] if len(kinds) == 1 else None

    # ------------------------------------------------------------- params --
    def init(self, key, dtype=jnp.float32) -> PyTree:
        cfg = self.cfg
        k_embed, k_blocks, k_head = jax.random.split(key, 3)
        params: Dict[str, Any] = {
            "embed": L.embed_init(k_embed, (cfg.vocab_size, cfg.d_model), dtype),
            "final_norm": L.norm_params(cfg, dtype),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = L.dense_init(
                k_head, (cfg.d_model, cfg.vocab_size), dtype=dtype)
        if self.uniform:
            keys = jax.random.split(k_blocks, cfg.num_layers)
            params["blocks"] = jax.vmap(
                lambda k: block_params(cfg, self.uniform, k, dtype))(keys)
        else:
            keys = jax.random.split(k_blocks, cfg.num_layers)
            params["blocks"] = [
                block_params(cfg, kind, keys[i], dtype)
                for i, kind in enumerate(cfg.layer_pattern)
            ]
        return params

    def abstract_params(self, dtype=jnp.bfloat16) -> PyTree:
        """ShapeDtypeStruct tree — dry-run / emulated mode (no allocation)."""
        return jax.eval_shape(lambda: self.init(jax.random.key(0), dtype))

    # -------------------------------------------------------------- embed --
    def _embed_inputs(self, params, inputs) -> Tuple[jax.Array, jax.Array]:
        """Returns (x (B,T,d), positions (B,T))."""
        cfg = self.cfg
        tokens = inputs["tokens"]
        x = params["embed"][tokens]
        if cfg.frontend is not None and "frontend_embeds" in inputs:
            fe = inputs["frontend_embeds"].astype(x.dtype)
            x = jnp.concatenate([fe, x], axis=1)
        B, T = x.shape[:2]
        positions = inputs.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
        return x, positions

    def _unembed(self, params, x):
        cfg = self.cfg
        w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
        return x @ w

    # --------------------------------------------------------------- body --
    def _run_stack(self, params, x, positions, cache, *, remat: bool = False):
        cfg = self.cfg
        total_aux: Dict[str, Any] = {}
        if self.uniform:
            kind = self.uniform

            def body(h, scanned):
                p_l, cache_l = scanned
                h, new_cache_l, aux = run_block(cfg, kind, p_l, h, positions, cache_l)
                return h, (new_cache_l, aux)

            if remat:
                body = jax.checkpoint(body)
            xs = (params["blocks"],
                  cache["layers"] if cache is not None else None)
            if cache is None:
                # scan needs a concrete xs tree; use params only
                def body_nc(h, p_l):
                    h, _, aux = run_block(cfg, kind, p_l, h, positions, None)
                    return h, aux
                if remat:
                    body_nc = jax.checkpoint(body_nc)
                x, auxs = jax.lax.scan(body_nc, x, params["blocks"])
                total_aux = {k: jnp.sum(v) if v.ndim >= 1 else v
                             for k, v in auxs.items()} if auxs else {}
                new_cache = None
            else:
                x, (new_layers, auxs) = jax.lax.scan(body, x, xs)
                if (cfg.kv_append == "defer"
                        and kind in ("attn", "local_attn")):
                    # one in-place scatter appends every layer's new KV —
                    # the scan carry never rewrote the cache (§Perf
                    # "kv_defer_append")
                    new_layers = _apply_deferred_append(
                        cache["layers"], new_layers, positions)
                new_cache = {"layers": new_layers}
                total_aux = {k: jnp.sum(v, axis=0) for k, v in auxs.items()} if auxs else {}
        else:
            new_layers = []
            for i, kind in enumerate(cfg.layer_pattern):
                cache_l = cache["layers"][i] if cache is not None else None
                x, new_cache_l, aux = run_block(
                    cfg, kind, params["blocks"][i], x, positions, cache_l)
                if (cfg.kv_append == "defer" and new_cache_l is not None
                        and "k_new" in new_cache_l):
                    # unrolled path: apply immediately (no carry to save)
                    new_cache_l = _apply_deferred_append(
                        cache_l, new_cache_l, positions, layer_axis=False)
                new_layers.append(new_cache_l)
                for k, v in aux.items():
                    total_aux[k] = total_aux.get(k, 0.0) + v
            new_cache = {"layers": new_layers} if cache is not None else None
        return x, new_cache, total_aux

    # ---------------------------------------------------------- train ----
    def train_loss(self, params, batch, *, remat: bool = True,
                   loss_chunk: int = 512):
        """Teacher-forced CE loss.  Logits are computed in sequence chunks so
        the (B, S, vocab) tensor is never fully materialised (matters at
        vocab 150k+ / seq 4k; see EXPERIMENTS.md §Perf)."""
        cfg = self.cfg
        x, positions = self._embed_inputs(params, batch)
        x, _, aux = self._run_stack(params, x, positions, None, remat=remat)
        x = L.apply_norm(cfg, x, params["final_norm"])

        labels = batch["labels"]
        mask = batch.get("loss_mask")
        if cfg.frontend is not None and "frontend_embeds" in batch:
            # frontend positions carry no LM loss
            F = batch["frontend_embeds"].shape[1]
            x = x[:, F:, :]

        B, S, _ = x.shape
        pad = (-S) % loss_chunk
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)))
            mask = jnp.pad(mask, ((0, 0), (0, pad))) if mask is not None else \
                jnp.pad(jnp.ones((B, S), jnp.float32), ((0, 0), (0, pad)))
        elif mask is None:
            mask = jnp.ones((B, S), jnp.float32)
        n_chunks = x.shape[1] // loss_chunk

        def chunk_loss(carry, inp):
            xc, yc, mc = inp                      # (B,C,d), (B,C), (B,C)
            logits = self._unembed(params, xc).astype(jnp.float32)
            logp = jax.nn.log_softmax(logits, axis=-1)
            ll = jnp.take_along_axis(logp, yc[..., None], axis=-1)[..., 0]
            return carry + jnp.sum(-ll * mc), None

        xs = (
            x.reshape(B, n_chunks, loss_chunk, -1).swapaxes(0, 1),
            labels.reshape(B, n_chunks, loss_chunk).swapaxes(0, 1),
            mask.reshape(B, n_chunks, loss_chunk).swapaxes(0, 1),
        )
        total, _ = jax.lax.scan(chunk_loss, jnp.float32(0.0), xs)
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        loss = total / denom
        metrics = {"loss": loss, "tokens": denom}
        if "moe_aux_loss" in aux:
            loss = loss + 0.01 * aux["moe_aux_loss"]
            metrics["moe_aux_loss"] = aux["moe_aux_loss"]
        return loss, metrics

    # ----------------------------------------------------------- serving --
    def init_cache(self, batch: int, cache_size: int, dtype=jnp.bfloat16,
                   window_slack: int = 0) -> PyTree:
        cfg = self.cfg
        if self.uniform:
            one = block_cache(cfg, self.uniform, batch, cache_size, dtype,
                              window_slack)
            layers = jax.tree.map(
                lambda leaf: jnp.broadcast_to(
                    leaf[None], (cfg.num_layers,) + leaf.shape
                ).copy() if leaf.ndim > 0 else leaf,
                one,
            )
            return {"layers": layers, "cache_len": jnp.zeros((batch,), jnp.int32)}
        layers = [
            block_cache(cfg, kind, batch, cache_size, dtype, window_slack)
            for kind in cfg.layer_pattern
        ]
        return {"layers": layers, "cache_len": jnp.zeros((batch,), jnp.int32)}

    def abstract_cache(self, batch, cache_size, dtype=jnp.bfloat16) -> PyTree:
        return jax.eval_shape(lambda: self.init_cache(batch, cache_size, dtype))

    def prefill(self, params, inputs, cache):
        """Extend ``cache`` with T new tokens per sequence; returns
        (last-position logits, new cache).  Positions default to
        cache_len + arange(T) (uniform chunked prefill)."""
        cfg = self.cfg
        x, _ = self._embed_inputs(params, inputs)
        B, T = x.shape[:2]
        positions = inputs.get("positions")
        if positions is None:
            positions = cache["cache_len"][:, None] + jnp.arange(T, dtype=jnp.int32)[None]
        x, new_cache, _ = self._run_stack(params, x, positions, cache)
        x = L.apply_norm(cfg, x, params["final_norm"])
        logits = self._unembed(params, x[:, -1:, :])
        new_cache["cache_len"] = cache["cache_len"] + T
        return logits[:, 0, :], new_cache

    def decode_step(self, params, cache, tokens):
        """tokens: (B, 1) -> (logits (B, V), new cache)."""
        return self.prefill(params, {"tokens": tokens}, cache)


# ==========================================================================
# encoder-decoder (whisper)
# ==========================================================================

class EncDecLM:
    """Whisper-style enc-dec.  The audio conv frontend is stubbed: inputs
    carry precomputed frame embeddings (B, F, d) per the assignment."""

    def __init__(self, cfg: ModelConfig):
        assert cfg.encoder is not None
        if cfg.kv_append == "defer":
            cfg = cfg.replace(kv_append="inline")  # enc-dec keeps inline
        self.cfg = cfg

    def init(self, key, dtype=jnp.float32) -> PyTree:
        cfg = self.cfg
        ke, kd, kx, kt, kp = jax.random.split(key, 5)
        enc_keys = jax.random.split(ke, cfg.encoder.num_layers)
        dec_keys = jax.random.split(kd, cfg.num_layers)
        x_keys = jax.random.split(kx, cfg.num_layers)
        params = {
            "embed": L.embed_init(kt, (cfg.vocab_size, cfg.d_model), dtype),
            "pos_embed": L.embed_init(kp, (cfg.max_seq_len, cfg.d_model), dtype),
            "encoder": jax.vmap(lambda k: block_params(cfg, "attn", k, dtype))(enc_keys),
            "decoder": jax.vmap(lambda k: block_params(cfg, "attn", k, dtype))(dec_keys),
            "cross": jax.vmap(
                lambda k: {"norm": L.norm_params(cfg, dtype),
                           "attn": L.attn_params(cfg, k, dtype)})(x_keys),
            "enc_final_norm": L.norm_params(cfg, dtype),
            "final_norm": L.norm_params(cfg, dtype),
        }
        return params

    def abstract_params(self, dtype=jnp.bfloat16) -> PyTree:
        return jax.eval_shape(lambda: self.init(jax.random.key(0), dtype))

    # ------------------------------------------------------------ encoder --
    def encode(self, params, frame_embeds):
        cfg = self.cfg
        x = frame_embeds
        B, F = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32)[None], (B, F))

        def body(h, p_l):
            hn = L.apply_norm(cfg, h, p_l["norm1"])
            q, k, v = L.attn_qkv(cfg, p_l["attn"], hn, positions)
            mask = L.full_mask(positions, positions)
            h = h + L.attn_out(p_l["attn"], L.attention(
                q, k, v, mask, scores_dtype=_scores_dtype(cfg)))
            h2 = L.apply_norm(cfg, h, p_l["norm2"])
            h = h + L.mlp(cfg, p_l["mlp"], h2)
            return h, None

        x, _ = jax.lax.scan(body, x, params["encoder"])
        return L.apply_norm(cfg, x, params["enc_final_norm"])

    def _cross_kv(self, params, enc_out):
        """Precompute per-layer cross-attention K/V from encoder states."""
        def one(cp):
            k = jnp.einsum("bfd,dhk->bfhk", enc_out, cp["attn"]["wk"])
            v = jnp.einsum("bfd,dhk->bfhk", enc_out, cp["attn"]["wv"])
            return k, v
        return jax.vmap(one, in_axes=0, out_axes=0)(params["cross"])

    # ------------------------------------------------------------ decoder --
    def _decoder_stack(self, params, x, positions, cache, cross_kv, *, remat=False):
        cfg = self.cfg

        def body(h, scanned):
            p_l, cp_l, cache_l, (ek, ev) = scanned
            h, new_cache_l, _ = run_block(
                cfg, "attn", p_l, h, positions, cache_l,
                enc_kv=(ek, ev), cross_p=cp_l)
            return h, new_cache_l

        if remat:
            body = jax.checkpoint(body)
        xs = (params["decoder"], params["cross"],
              cache["layers"] if cache is not None else None, cross_kv)
        if cache is None:
            def body_nc(h, scanned):
                p_l, cp_l, (ek, ev) = scanned
                h, _, _ = run_block(cfg, "attn", p_l, h, positions, None,
                                    enc_kv=(ek, ev), cross_p=cp_l)
                return h, None
            if remat:
                body_nc = jax.checkpoint(body_nc)
            x, _ = jax.lax.scan(body_nc, x,
                                (params["decoder"], params["cross"], cross_kv))
            return x, None
        x, new_layers = jax.lax.scan(body, x, xs)
        return x, {"layers": new_layers}

    # ------------------------------------------------------------- train --
    def train_loss(self, params, batch, *, remat: bool = True, loss_chunk: int = 512):
        cfg = self.cfg
        enc_out = self.encode(params, batch["frontend_embeds"])
        cross_kv = self._cross_kv(params, enc_out)
        tokens, labels = batch["tokens"], batch["labels"]
        B, S = tokens.shape
        x = params["embed"][tokens] + params["pos_embed"][:S][None]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        x, _ = self._decoder_stack(params, x, positions, None, cross_kv, remat=remat)
        x = L.apply_norm(cfg, x, params["final_norm"])
        logits = (x @ params["embed"].T).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        mask = batch.get("loss_mask")
        if mask is None:
            mask = jnp.ones((B, S), jnp.float32)
        loss = jnp.sum(-ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return loss, {"loss": loss, "tokens": jnp.sum(mask)}

    # ----------------------------------------------------------- serving --
    def init_cache(self, batch: int, cache_size: int, dtype=jnp.bfloat16,
                   window_slack: int = 0, *, enc_frames: Optional[int] = None) -> PyTree:
        cfg = self.cfg
        F = enc_frames or cfg.encoder.max_source_positions
        one = block_cache(cfg, "attn", batch, cache_size, dtype, window_slack)
        layers = jax.tree.map(
            lambda leaf: jnp.broadcast_to(
                leaf[None], (cfg.num_layers,) + leaf.shape).copy(),
            one,
        )
        xk = jnp.zeros((cfg.num_layers, batch, F, cfg.num_kv_heads, cfg.head_dim), dtype)
        return {
            "layers": layers,
            "cross_k": xk,
            "cross_v": jnp.zeros_like(xk),
            "cache_len": jnp.zeros((batch,), jnp.int32),
        }

    def abstract_cache(self, batch, cache_size, dtype=jnp.bfloat16):
        return jax.eval_shape(lambda: self.init_cache(batch, cache_size, dtype))

    def prefill(self, params, inputs, cache):
        """Encoder pass (if frame embeddings present) + decoder extension."""
        cfg = self.cfg
        if "frontend_embeds" in inputs:
            enc_out = self.encode(params, inputs["frontend_embeds"])
            ck, cv = self._cross_kv(params, enc_out)
            cache = dict(cache, cross_k=ck.astype(cache["cross_k"].dtype),
                         cross_v=cv.astype(cache["cross_v"].dtype))
        tokens = inputs["tokens"]
        B, T = tokens.shape
        positions = inputs.get("positions")
        if positions is None:
            positions = cache["cache_len"][:, None] + jnp.arange(T, dtype=jnp.int32)[None]
        x = params["embed"][tokens] + jnp.take(
            params["pos_embed"], jnp.clip(positions, 0, cfg.max_seq_len - 1), axis=0)
        x, new_dec = self._decoder_stack(
            params, x, positions, {"layers": cache["layers"]},
            (cache["cross_k"], cache["cross_v"]))
        x = L.apply_norm(cfg, x, params["final_norm"])
        logits = x[:, -1:, :] @ params["embed"].T
        new_cache = dict(cache, layers=new_dec["layers"],
                         cache_len=cache["cache_len"] + T)
        return logits[:, 0, :], new_cache

    def decode_step(self, params, cache, tokens):
        return self.prefill(params, {"tokens": tokens}, cache)


# ==========================================================================
# factory
# ==========================================================================

def build_model(cfg: ModelConfig):
    if cfg.is_enc_dec:
        return EncDecLM(cfg)
    return TransformerLM(cfg)
