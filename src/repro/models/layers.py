"""Model building blocks, pure JAX.

Everything here is a pure function over explicit parameter pytrees — no
framework, no globals — so the same code path serves:

* real-mode execution on CPU (serving fidelity benchmarks),
* TPU execution (where `repro.kernels.*.ops` swap in Pallas kernels),
* abstract lowering for the multi-pod dry-run (ShapeDtypeStruct inputs).

Conventions:
  B batch, T query tokens, S KV length, H heads, Hkv KV heads, D head_dim,
  d  = d_model, F = d_ff, E experts, N ssm state, P ssd head dim, W lru width.
Compute is performed in the input dtype with fp32 softmax/norm/recurrence
accumulators (TPU-friendly: bf16 in, fp32 accum).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig

# --------------------------------------------------------------------------
# initialisation helpers
# --------------------------------------------------------------------------

def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    y = x32 * inv
    if scale is not None:
        y = y * scale.astype(jnp.float32)
    return y.astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    """LayerNorm; with ``scale=bias=None`` this is OLMo's non-parametric LN."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dt)


def apply_norm(cfg: ModelConfig, x, params):
    if cfg.norm == "rmsnorm":
        return rms_norm(x, params["scale"])
    if cfg.norm == "layernorm":
        return layer_norm(x, params["scale"], params["bias"])
    if cfg.norm == "nonparametric_ln":
        return layer_norm(x, None, None)
    raise ValueError(cfg.norm)


def norm_params(cfg: ModelConfig, dtype):
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((cfg.d_model,), dtype),
                "bias": jnp.zeros((cfg.d_model,), dtype)}
    return {}  # non-parametric


# --------------------------------------------------------------------------
# rotary position embedding
# --------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """x: (B, T, H, D); positions: (B, T) int32."""
    d_half = x.shape[-1] // 2
    freq = theta ** (-jnp.arange(0, d_half, dtype=jnp.float32) / d_half)
    angles = positions[..., None].astype(jnp.float32) * freq  # (B,T,d/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention (reference; Pallas kernels override on TPU via repro.kernels)
# --------------------------------------------------------------------------

def attention(q, k, v, mask, *, softmax_scale: Optional[float] = None,
              scores_dtype=jnp.float32):
    """GQA attention.  q: (B,T,Hq,D); k,v: (B,S,Hkv,D); mask: (B,T,S) bool.

    ``scores_dtype``: dtype of the materialized score/prob tensors.  This
    dense lowering is the dry-run stand-in for the Pallas flash kernel (which
    accumulates fp32 in VMEM and never materialises scores); bf16 scores
    halve the lowering's HBM traffic (§Perf "scores_bf16")."""
    B, T, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(D)
    qg = q.reshape(B, T, Hkv, G, D)
    scores = jnp.einsum("bthgd,bshd->bhgts", qg, k).astype(scores_dtype) * scale
    neg = jnp.finfo(scores_dtype).min / 2
    scores = jnp.where(mask[:, None, None, :, :], scores, neg)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    probs = probs.astype(scores_dtype)
    out = jnp.einsum("bhgts,bshd->bthgd", probs.astype(v.dtype), v)
    return out.reshape(B, T, Hq, D)


def attention_partial(q, k, v, mask, *, softmax_scale: Optional[float] = None,
                      scores_dtype=jnp.float32):
    """Unnormalised attention segment for online-softmax merging.

    Returns (acc (B,T,Hq,D) = Σ exp(s−m)·v, m (B,T,Hq) row max,
    l (B,T,Hq) = Σ exp(s−m)).  Two segments combine exactly via the flash
    rescale — this is what lets the deferred-append path attend over
    [cache ‖ new chunk] without concatenating (and hence copying) the cache.
    """
    B, T, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(D)
    qg = q.reshape(B, T, Hkv, G, D)
    scores = jnp.einsum("bthgd,bshd->bhgts", qg, k).astype(scores_dtype) * scale
    neg = jnp.finfo(scores_dtype).min / 2
    scores = jnp.where(mask[:, None, None, :, :], scores, neg)
    scores = scores.astype(jnp.float32)
    m = jnp.max(scores, axis=-1)                             # (B,Hkv,G,T)
    p = jnp.exp(scores - m[..., None]).astype(scores_dtype)
    l = jnp.sum(p.astype(jnp.float32), axis=-1)
    acc = jnp.einsum("bhgts,bshd->bthgd", p.astype(v.dtype), v)
    acc = acc.reshape(B, T, Hq, D)
    perm = lambda a: a.transpose(0, 3, 1, 2).reshape(B, T, Hq)
    return acc, perm(m), perm(l)


def attention_merge2(seg_a, seg_b, out_dtype):
    """Exact two-segment online-softmax combine (flash rescale)."""
    acc_a, m_a, l_a = seg_a
    acc_b, m_b, l_b = seg_b
    m = jnp.maximum(m_a, m_b)
    wa = jnp.exp(m_a - m)
    wb = jnp.exp(m_b - m)
    num = acc_a.astype(jnp.float32) * wa[..., None] \
        + acc_b.astype(jnp.float32) * wb[..., None]
    den = l_a * wa + l_b * wb
    den = jnp.where(den == 0.0, 1.0, den)                    # fully-masked rows
    return (num / den[..., None]).astype(out_dtype)


def causal_mask(q_pos, kv_pos, window: Optional[int] = None):
    """q_pos: (B,T), kv_pos: (B,S) (−1 marks invalid KV slots) -> (B,T,S)."""
    m = kv_pos[:, None, :] <= q_pos[:, :, None]
    m &= kv_pos[:, None, :] >= 0
    if window is not None:
        m &= q_pos[:, :, None] - kv_pos[:, None, :] < window
    return m


def full_mask(q_pos, kv_pos):
    """Bidirectional (encoder) mask: only invalid slots masked."""
    B, T = q_pos.shape
    return jnp.broadcast_to(kv_pos[:, None, :] >= 0, (B, T, kv_pos.shape[1]))


# --------------------------------------------------------------------------
# attention block params + apply
# --------------------------------------------------------------------------

def attn_params(cfg: ModelConfig, key, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, (cfg.d_model, cfg.num_heads, cfg.head_dim), dtype=dtype),
        "wk": dense_init(k2, (cfg.d_model, cfg.num_kv_heads, cfg.head_dim), dtype=dtype),
        "wv": dense_init(k3, (cfg.d_model, cfg.num_kv_heads, cfg.head_dim), dtype=dtype),
        "wo": dense_init(k4, (cfg.num_heads, cfg.head_dim, cfg.d_model), in_axis=1, dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads, cfg.head_dim), dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads, cfg.head_dim), dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads, cfg.head_dim), dtype)
    return p


def attn_qkv(cfg: ModelConfig, p, x, positions):
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.rope_theta > 0:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_out(p, ctx):
    return jnp.einsum("bthk,hkd->btd", ctx, p["wo"])


# --------------------------------------------------------------------------
# MLP (dense)
# --------------------------------------------------------------------------

def mlp_params(cfg: ModelConfig, key, dtype, d_ff: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    if cfg.mlp_act == "swiglu":
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "wi": dense_init(k1, (cfg.d_model, d_ff), dtype=dtype),
            "wg": dense_init(k2, (cfg.d_model, d_ff), dtype=dtype),
            "wo": dense_init(k3, (d_ff, cfg.d_model), dtype=dtype),
        }
    k1, k2 = jax.random.split(key)
    return {
        "wi": dense_init(k1, (cfg.d_model, d_ff), dtype=dtype),
        "wo": dense_init(k2, (d_ff, cfg.d_model), dtype=dtype),
    }


def mlp(cfg: ModelConfig, p, x):
    if cfg.mlp_act == "swiglu":
        h = jax.nn.silu(x @ p["wi"]) * (x @ p["wg"])
    else:
        h = jax.nn.gelu(x @ p["wi"])
    return h @ p["wo"]


# --------------------------------------------------------------------------
# Mixture of Experts — sort-based dispatch with ragged_dot (dropless)
# --------------------------------------------------------------------------

def moe_params(cfg: ModelConfig, key, dtype):
    moe = cfg.moe
    n_in = 2 if cfg.mlp_act == "swiglu" else 1
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "router": dense_init(k1, (cfg.d_model, moe.num_experts), dtype=jnp.float32),
        "w_in": dense_init(
            k2, (moe.num_experts, cfg.d_model, n_in * moe.d_ff_expert), in_axis=1, dtype=dtype
        ),
        "w_out": dense_init(
            k3, (moe.num_experts, moe.d_ff_expert, cfg.d_model), in_axis=1, dtype=dtype
        ),
    }


def moe(cfg: ModelConfig, p, x):
    """Dropless MoE: route, sort tokens by expert, grouped matmul, unsort.

    x: (B, T, d) -> (B, T, d), plus aux dict (load-balance loss, counts).
    The sort/ragged_dot formulation computes *exactly* top_k expert FLOPs per
    token (no capacity padding, no dense overcompute), which keeps the
    roofline analysis honest.  Under EP sharding the expert dim of
    ``w_in``/``w_out`` is sharded and XLA materialises the token exchange as
    all-to-all/all-gather collectives — counted by the dry-run parser.
    """
    moe_cfg = cfg.moe
    E, K = moe_cfg.num_experts, moe_cfg.top_k
    B, T, d = x.shape
    xf = x.reshape(B * T, d)
    n = B * T

    logits = (xf.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (n,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, K)                       # (n,K)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)       # renormalise

    flat_expert = idx.reshape(-1)                             # (n*K,)
    sort_idx = jnp.argsort(flat_expert)                       # stable
    token_of = sort_idx // K
    xs = xf[token_of]                                         # (n*K, d)
    group_sizes = jnp.bincount(flat_expert, length=E).astype(jnp.int32)

    h = jax.lax.ragged_dot(xs, p["w_in"], group_sizes)        # (n*K, n_in*ff)
    if cfg.mlp_act == "swiglu":
        hi, hg = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(hi) * hg
    else:
        h = jax.nn.gelu(h)
    ys = jax.lax.ragged_dot(h, p["w_out"], group_sizes)       # (n*K, d)

    # unsort + gate-weighted combine
    flat_gate = gate.reshape(-1)[sort_idx]
    ys = ys * flat_gate[:, None].astype(ys.dtype)
    out = jnp.zeros((n, d), ys.dtype).at[token_of].add(ys)

    # auxiliary load-balance loss (Switch-style)
    me = jnp.mean(probs, axis=0)                              # (E,)
    ce = group_sizes.astype(jnp.float32) / (n * K)
    aux_loss = E * jnp.sum(me * ce)
    return out.reshape(B, T, d), {"moe_aux_loss": aux_loss,
                                  "expert_load": ce}


def _ambient_mesh():
    from jax._src.mesh import thread_resources
    m = thread_resources.env.physical_mesh
    return None if m.empty else m


def moe_a2a(cfg: ModelConfig, p, x):
    """Expert-parallel MoE with explicit dispatch/combine all-to-all
    (§Perf "moe_a2a", MaxText-style).

    GSPMD auto-sharding of the sort+ragged_dot form all-gathers the full
    token activations to every expert shard (O(n·d·ep) bytes per layer).
    Routing is top-k sparse, so the information-theoretic exchange is only
    O(n·k·d): each shard sends exactly the tokens destined to each peer's
    experts and receives the results back.  This implements that exchange
    with ``lax.all_to_all`` over the "model" axis inside ``shard_map``:

        tokens sharded (batch over data, seq over model)
          -> route locally -> bucket by destination shard (capacity-bounded)
          -> all-to-all dispatch -> local expert matmuls
          -> all-to-all combine -> gate-weighted scatter-add.

    Capacity drops (GLaM semantics) replace the dropless guarantee of the
    ragged form; ``capacity_factor`` bounds the drop probability.  Falls
    back to :func:`moe` when no mesh is ambient or shapes don't divide.
    """
    mesh = _ambient_mesh()
    moe_cfg = cfg.moe
    E, K = moe_cfg.num_experts, moe_cfg.top_k
    B, T, d = x.shape
    if (mesh is None or "model" not in mesh.axis_names):
        return moe(cfg, p, x)
    ep = mesh.shape["model"]
    if ep == 1 or E % ep or T % ep:
        return moe(cfg, p, x)            # indivisible: keep ragged lowering
    E_loc = E // ep
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bsz = 1
    for a in batch_axes:
        bsz *= mesh.shape[a]
    b_spec = batch_axes if B % bsz == 0 else None
    B_loc = B // bsz if b_spec else B
    T_loc = T // ep
    n_loc = B_loc * T_loc
    cap = max(1, int(math.ceil(n_loc * K / ep * moe_cfg.capacity_factor)))

    try:
        from jax import shard_map               # jax >= 0.6
        _check_kw = {"check_vma": False}
    except ImportError:                         # jax 0.4/0.5 experimental API
        from jax.experimental.shard_map import shard_map
        _check_kw = {"check_rep": False}
    from jax.sharding import PartitionSpec as P

    x_spec = P(b_spec, "model", None)

    def body(xs, router, w_in, w_out):
        nloc, dm = n_loc, d
        xf = xs.reshape(nloc, dm)
        logits = (xf.astype(jnp.float32) @ router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, idx = jax.lax.top_k(probs, K)                     # (n,K)
        gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

        flat_e = idx.reshape(-1)                                # (nK,)
        flat_g = gate.reshape(-1)
        tok_of = jnp.arange(nloc * K) // K
        dest = flat_e // E_loc                                  # (nK,)
        onehot = (dest[:, None] == jnp.arange(ep)[None, :])
        pos = jnp.cumsum(onehot, axis=0) - 1                    # (nK, ep)
        slot = jnp.take_along_axis(pos, dest[:, None], 1)[:, 0]
        keep = slot < cap
        slot = jnp.where(keep, slot, cap - 1)

        send_x = jnp.zeros((ep, cap, dm), xs.dtype)
        send_x = send_x.at[dest, slot].set(
            jnp.where(keep[:, None], xf[tok_of], 0.0).astype(xs.dtype),
            mode="drop")
        send_e = jnp.zeros((ep, cap), jnp.int32).at[dest, slot].set(
            jnp.where(keep, flat_e % E_loc, 0), mode="drop")
        # valid marker rides sign bit of gate buffer (0 => empty slot)
        send_v = jnp.zeros((ep, cap), jnp.float32).at[dest, slot].set(
            jnp.where(keep, 1.0, 0.0), mode="drop")

        rx = jax.lax.all_to_all(send_x, "model", 0, 0, tiled=False)
        re = jax.lax.all_to_all(send_e, "model", 0, 0, tiled=False)
        rv = jax.lax.all_to_all(send_v, "model", 0, 0, tiled=False)

        rxf = rx.reshape(ep * cap, dm)
        ref_ = re.reshape(ep * cap)
        rvf = rv.reshape(ep * cap)
        out = jnp.zeros((ep * cap, dm), jnp.float32)
        n_in = 2 if cfg.mlp_act == "swiglu" else 1
        for el in range(E_loc):                                  # static unroll
            m = ((ref_ == el) & (rvf > 0)).astype(rxf.dtype)[:, None]
            h = (rxf * m) @ w_in[el]
            if cfg.mlp_act == "swiglu":
                hi, hg = jnp.split(h, 2, axis=-1)
                h = jax.nn.silu(hi) * hg
            else:
                h = jax.nn.gelu(h)
            out = out + ((h @ w_out[el]) * m).astype(jnp.float32)

        back = jax.lax.all_to_all(out.reshape(ep, cap, dm).astype(xs.dtype),
                                  "model", 0, 0, tiled=False)
        got = back[dest, slot]                                   # (nK, d)
        got = got * (flat_g * keep)[:, None].astype(got.dtype)
        y = jnp.zeros((nloc, dm), got.dtype).at[tok_of].add(got)

        # load-balance aux (local shard statistics)
        me_ = jnp.mean(probs, axis=0)
        ce_ = jnp.bincount(flat_e, length=E).astype(jnp.float32) / (nloc * K)
        aux = E * jnp.sum(me_ * ce_)
        return y.reshape(B_loc, T_loc, dm), aux, ce_

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, P(None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(x_spec, P(), P()),
        **_check_kw,
    )
    y, aux, ce = fn(x, p["router"], p["w_in"], p["w_out"])
    return y, {"moe_aux_loss": aux, "expert_load": ce}


# --------------------------------------------------------------------------
# RG-LRU (RecurrentGemma / Griffin)
# --------------------------------------------------------------------------

def rglru_params(cfg: ModelConfig, key, dtype):
    rg = cfg.rglru
    w = rg.lru_width
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    return {
        "w_x": dense_init(k1, (cfg.d_model, w), dtype=dtype),      # input branch
        "w_gate_in": dense_init(k2, (cfg.d_model, w), dtype=dtype),  # gate branch
        "w_a": dense_init(k3, (w, w), dtype=dtype),                # recurrence gate
        "w_i": dense_init(k4, (w, w), dtype=dtype),                # input gate
        "w_out": dense_init(k5, (w, cfg.d_model), dtype=dtype),
        "conv": dense_init(k6, (rg.conv_width, w), dtype=dtype),
        # Λ init so a = sigmoid(Λ)^(8r) spans the "stable but long memory"
        # range used by Griffin.
        "log_lambda": jnp.linspace(-4.3, -9.0, w).astype(jnp.float32),
    }


def _causal_conv1d(x, weights, state=None):
    """Depthwise causal conv.  x: (B,T,W); weights: (K,W); state: (B,K-1,W)."""
    K = weights.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, T+K-1, W)
    out = sum(xp[:, i : i + x.shape[1], :] * weights[i] for i in range(K))
    new_state = xp[:, -(K - 1):, :] if K > 1 else None
    return out, new_state


def rglru(cfg: ModelConfig, p, x, h0=None, conv_state=None):
    """RG-LRU block.  x: (B,T,d) -> (B,T,d); returns (y, hT, conv_stateT).

    h_t = a_t ⊙ h_{t−1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ u_t)
    a_t = exp(c · softplus(Λ) · (−r_t)), r/i gates from the conv'd branch.
    Implemented with an associative scan (log-depth on TPU).
    """
    B, T, _ = x.shape
    u = x @ p["w_x"]                                       # (B,T,W)
    g = jax.nn.silu(x @ p["w_gate_in"])                    # gate branch
    u_conv, conv_state = _causal_conv1d(u, p["conv"], conv_state)

    r = jax.nn.sigmoid(u_conv @ p["w_a"]).astype(jnp.float32)
    i = jax.nn.sigmoid(u_conv @ p["w_i"]).astype(jnp.float32)
    c = 8.0
    log_a = -c * jax.nn.softplus(p["log_lambda"]) * r      # (B,T,W) fp32
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - a * a, 0.0, 1.0)) * i * u_conv.astype(jnp.float32)

    if h0 is None:
        h0 = jnp.zeros((B, u.shape[-1]), jnp.float32)

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, b_l * a_r + b_r

    a_sc, b_sc = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = a_sc * h0[:, None, :] + b_sc                       # (B,T,W)
    y = ((h.astype(x.dtype) * g) @ p["w_out"])
    return y, h[:, -1, :], conv_state


def rglru_step(cfg: ModelConfig, p, x_t, h_prev, conv_state):
    """Single decode step.  x_t: (B,1,d); h_prev: (B,W); conv: (B,K-1,W)."""
    y, h, conv_state = rglru(cfg, p, x_t, h0=h_prev, conv_state=conv_state)
    return y, h, conv_state


# --------------------------------------------------------------------------
# Mamba2 / SSD (state-space duality)
# --------------------------------------------------------------------------

def ssd_params(cfg: ModelConfig, key, dtype):
    ssm = cfg.ssm
    d_in = ssm.d_inner(cfg.d_model)
    nheads = ssm.num_heads(cfg.d_model)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        # in_proj emits [z (gate), x, B, C, dt]
        "w_in": dense_init(
            k1, (cfg.d_model, 2 * d_in + 2 * ssm.state_dim + nheads), dtype=dtype
        ),
        "conv": dense_init(k2, (ssm.conv_width, d_in + 2 * ssm.state_dim), dtype=dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)).astype(jnp.float32),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "w_out": dense_init(k3, (d_in, cfg.d_model), dtype=dtype),
        "norm_scale": jnp.ones((d_in,), dtype),
    }


def _ssd_split(cfg: ModelConfig, p, x):
    ssm = cfg.ssm
    d_in = ssm.d_inner(cfg.d_model)
    nheads = ssm.num_heads(cfg.d_model)
    zxbcdt = x @ p["w_in"]
    z, xbc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * ssm.state_dim], axis=-1)
    return z, xbc, dt, d_in, nheads


def ssd_prefill(cfg: ModelConfig, p, x, state=None, conv_state=None):
    """Mamba2 block over a sequence (chunked SSD).  x: (B,T,d).

    Returns (y, final_state (B,H,P,N), conv_state (B,K-1,d_conv)).
    """
    ssm = cfg.ssm
    B, T, _ = x.shape
    z, xbc, dt, d_in, H = _ssd_split(cfg, p, x)
    xbc, conv_state = _causal_conv1d(xbc, p["conv"], conv_state)
    xbc = jax.nn.silu(xbc)
    xs, Bmat, Cmat = jnp.split(xbc, [d_in, d_in + ssm.state_dim], axis=-1)
    P, N = ssm.head_dim, ssm.state_dim
    xh = xs.reshape(B, T, H, P)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])       # (B,T,H)
    A = -jnp.exp(p["A_log"])                                          # (H,)

    y, state = ssd_chunked_ref(
        xh, dt, A, Bmat.astype(jnp.float32), Cmat.astype(jnp.float32),
        chunk=min(ssm.chunk_size, T), initial_state=state,
    )
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, T, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, p["norm_scale"])
    return y @ p["w_out"], state, conv_state


def ssd_chunked_ref(xh, dt, A, Bmat, Cmat, *, chunk: int, initial_state=None):
    """Chunked SSD reference (pure jnp; the Pallas kernel mirrors this).

    xh:(B,T,H,P) dt:(B,T,H) A:(H,) B/C:(B,T,N).  h_t = a_t h_{t-1} + dt_t B_t x_t,
    y_t = C_t·h_t, with a_t = exp(dt_t A).  Intra-chunk term is quadratic
    (MXU-friendly), inter-chunk term is a short scan over chunk states.
    """
    B, T, H, P = xh.shape
    N = Bmat.shape[-1]
    assert T % chunk == 0, (T, chunk)
    C_ = T // chunk
    xh = xh.astype(jnp.float32).reshape(B, C_, chunk, H, P)
    dt = dt.reshape(B, C_, chunk, H)
    Bm = Bmat.reshape(B, C_, chunk, N)
    Cm = Cmat.reshape(B, C_, chunk, N)

    dA = dt * A[None, None, None, :]                    # (B,C,Q,H) log-decay
    cum = jnp.cumsum(dA, axis=2)                        # inclusive
    # L[i,j] = exp(cum_i - cum_j) for i >= j  (decay from j+1..i applied to
    # the dt_j-weighted input); mask below diagonal.  The mask is applied to
    # the *exponent*: upper-triangle deltas are positive and exp would
    # overflow to inf, which poisons the VJP (inf·0 = NaN) even though the
    # forward select discards it.
    Q = chunk
    li = cum[:, :, :, None, :]                          # i
    lj = cum[:, :, None, :, :]                          # j
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    delta = jnp.where(mask[None, None, :, :, None], li - lj, -jnp.inf)
    L = jnp.exp(delta)                                   # (B,C,i,j,H)

    dx = xh * dt[..., None]                              # dt_j B_j x_j
    scores = jnp.einsum("bcin,bcjn->bcij", Cm, Bm)       # (B,C,i,j)
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", scores, L, dx)

    # chunk-local final states: S_c = sum_j exp(cum_Q - cum_j) B_j (dt_j x_j)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)      # (B,C,Q,H)
    S_local = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", Bm, decay_to_end, dx)
    chunk_decay = jnp.exp(cum[:, :, -1, :])              # (B,C,H)

    # inter-chunk recurrence (scan over C_ chunk states)
    if initial_state is None:
        initial_state = jnp.zeros((B, H, N, P), jnp.float32)

    def step(s_prev, inp):
        s_loc, decay = inp                               # (B,H,N,P), (B,H)
        s = s_prev * decay[:, :, None, None] + s_loc
        return s, s_prev

    S_final, S_prev = jax.lax.scan(
        step,
        initial_state,
        (jnp.moveaxis(S_local, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    S_prev = jnp.moveaxis(S_prev, 0, 1)                  # (B,C,H,N,P)

    # inter-chunk contribution: y_i += C_i · (decay_{0..i} * S_{prev chunk})
    decay_from_start = jnp.exp(cum)                      # (B,C,Q,H)
    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp", Cm, decay_from_start, S_prev)

    y = (y_intra + y_inter).reshape(B, T, H, P)
    return y, S_final


def ssd_decode_step(cfg: ModelConfig, p, x_t, state, conv_state):
    """Single-token SSD update.  x_t: (B,1,d); state: (B,H,N,P)."""
    ssm = cfg.ssm
    B = x_t.shape[0]
    z, xbc, dt, d_in, H = _ssd_split(cfg, p, x_t)
    xbc, conv_state = _causal_conv1d(xbc, p["conv"], conv_state)
    xbc = jax.nn.silu(xbc)
    xs, Bmat, Cmat = jnp.split(xbc, [d_in, d_in + ssm.state_dim], axis=-1)
    P, N = ssm.head_dim, ssm.state_dim
    xh = xs.reshape(B, H, P).astype(jnp.float32)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt1 * A[None, :])                                       # (B,H)
    Bv = Bmat[:, 0].astype(jnp.float32)                                 # (B,N)
    Cv = Cmat[:, 0].astype(jnp.float32)
    dx = xh * dt1[..., None]                                            # (B,H,P)
    state = state * a[:, :, None, None] + jnp.einsum("bn,bhp->bhnp", Bv, dx)
    y = jnp.einsum("bn,bhnp->bhp", Cv, state)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(B, 1, d_in).astype(x_t.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, p["norm_scale"])
    return y @ p["w_out"], state, conv_state
