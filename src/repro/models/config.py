"""Model configuration schema shared by the model zoo, the runtime predictor,
the serving engine, and the dry-run launcher.

One :class:`ModelConfig` instance fully determines:

* the parameter tree (`repro.models.transformer/encdec/ssm` build from it),
* the analytical cost model (`repro.core.predictor`),
* KV-cache / recurrent-state geometry (`repro.serving.kv_cache`),
* the sharding rules (`repro.launch.mesh`).

Layer pattern mini-language: ``layer_pattern`` is a list of block kinds, one
entry per layer, drawn from ``{"attn", "local_attn", "rglru", "ssd"}``.  Dense
transformers use ``["attn"] * L``; RecurrentGemma uses the 1:2 pattern
``["rglru", "rglru", "local_attn"] * (L//3)``; Mamba2 uses ``["ssd"] * L``.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

__all__ = ["MoEConfig", "SSMConfig", "EncoderConfig", "ModelConfig"]


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_jitter: float = 0.0

    @property
    def active_ratio(self) -> float:
        return self.top_k / self.num_experts


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block geometry [arXiv:2405.21060]."""

    state_dim: int = 128          # N: SSM state size
    head_dim: int = 64            # P: channels per SSD head
    expand: int = 2               # d_inner = expand * d_model
    chunk_size: int = 128         # SSD chunk length (TPU: multiple of 128)
    conv_width: int = 4           # short causal conv

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder tower for enc-dec models (whisper).  The conv/audio frontend is
    a stub per the assignment: ``input_specs()`` feeds precomputed frame
    embeddings of shape (batch, n_frames, d_model)."""

    num_layers: int
    num_heads: int
    max_source_positions: int = 1500  # whisper: 30 s of audio @ 50 Hz


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU block geometry [arXiv:2402.19427]."""

    lru_width: int = 2560
    conv_width: int = 4
    block_width_multiplier: float = 1.0


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                     # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # defaults to d_model // num_heads
    qkv_bias: bool = False
    mlp_act: str = "swiglu"         # swiglu | gelu
    norm: str = "rmsnorm"           # rmsnorm | layernorm | nonparametric_ln
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None      # SWA (mixtral) / local attn span
    layer_pattern: Optional[Sequence[str]] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encoder: Optional[EncoderConfig] = None   # present => enc-dec
    tie_embeddings: bool = False
    max_seq_len: int = 131_072
    dtype: str = "bfloat16"
    # Modality frontends (stubs per assignment): inputs arrive as embeddings.
    frontend: Optional[str] = None  # None | "audio_frames" | "vision_patches"
    frontend_tokens: int = 0        # frames/patches prepended per sample
    # §Perf lowering knobs (EXPERIMENTS.md): dtype of materialized attention
    # scores in the dense lowering, the MoE execution strategy, and the
    # KV-append strategy (defer = one post-stack scatter for all layers via
    # two-segment online-softmax attention, instead of a full per-layer
    # cache rewrite inside the scan carry).
    attn_scores_dtype: str = "float32"   # float32 | bfloat16
    moe_impl: str = "ragged"             # ragged | a2a (shard_map EP)
    kv_append: str = "inline"            # inline | defer

    # ------------------------------------------------------------ derived --
    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.layer_pattern is None:
            kind = "ssd" if self.family == "ssm" else "attn"
            object.__setattr__(self, "layer_pattern", tuple([kind] * self.num_layers))
        else:
            pat = tuple(self.layer_pattern)
            assert len(pat) == self.num_layers, (
                f"layer_pattern length {len(pat)} != num_layers {self.num_layers}"
            )
            object.__setattr__(self, "layer_pattern", pat)

    @property
    def is_enc_dec(self) -> bool:
        return self.encoder is not None

    @property
    def q_size(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_size(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def dtype_bytes(self) -> int:
        return {"bfloat16": 2, "float32": 4, "float16": 2, "float8": 1}[self.dtype]

    # --------------------------------------------------------- accounting --
    def attn_params_per_layer(self) -> int:
        qkv = self.d_model * (self.q_size + 2 * self.kv_size)
        if self.qkv_bias:
            qkv += self.q_size + 2 * self.kv_size
        out = self.q_size * self.d_model
        return qkv + out

    def mlp_params_per_layer(self) -> int:
        n_mats = 3 if self.mlp_act == "swiglu" else 2
        if self.moe is not None:
            router = self.d_model * self.moe.num_experts
            return router + self.moe.num_experts * n_mats * self.d_model * self.moe.d_ff_expert
        return n_mats * self.d_model * self.d_ff

    def active_mlp_params_per_layer(self) -> int:
        """Params touched per token (MoE: only top-k experts)."""
        n_mats = 3 if self.mlp_act == "swiglu" else 2
        if self.moe is not None:
            router = self.d_model * self.moe.num_experts
            return router + self.moe.top_k * n_mats * self.d_model * self.moe.d_ff_expert
        return n_mats * self.d_model * self.d_ff

    def ssd_params_per_layer(self) -> int:
        assert self.ssm is not None
        d_in = self.ssm.d_inner(self.d_model)
        nheads = self.ssm.num_heads(self.d_model)
        # in_proj produces [z, x, B, C, dt]; out_proj back to d_model.
        zx = 2 * d_in
        bc = 2 * self.ssm.state_dim
        proj_in = self.d_model * (zx + bc + nheads)
        conv = self.ssm.conv_width * (d_in + 2 * self.ssm.state_dim)
        skip = nheads * 3  # A_log, D, dt_bias
        gate_norm = d_in   # pre-out-proj RMSNorm scale
        proj_out = d_in * self.d_model
        return proj_in + conv + skip + gate_norm + proj_out

    def rglru_params_per_layer(self) -> int:
        assert self.rglru is not None
        w = self.rglru.lru_width
        # x/gate in-proj + out-proj + recurrence/input gates + conv + Λ.
        return (
            2 * self.d_model * w      # in-proj (x branch, gate branch)
            + w * self.d_model        # out-proj
            + 2 * w * w               # RG-LRU recurrence + input gates
            + self.rglru.conv_width * w
            + w                       # Λ (log-recurrence weights)
        )

    def block_params(self, kind: str) -> int:
        if kind in ("attn", "local_attn"):
            return self.attn_params_per_layer() + self.mlp_params_per_layer()
        if kind == "ssd":
            return self.ssd_params_per_layer()
        if kind == "rglru":
            return self.rglru_params_per_layer() + self.mlp_params_per_layer()
        raise ValueError(f"unknown block kind {kind!r}")

    def norm_unit(self) -> int:
        """Parameters per norm instance."""
        return {"rmsnorm": self.d_model, "layernorm": 2 * self.d_model,
                "nonparametric_ln": 0}[self.norm]

    def param_count(self) -> int:
        """Total parameters (embeddings + blocks + norms [+ encoder]).

        Exact by construction — tests/test_models_smoke.py asserts equality
        against the real parameter tree for every architecture; the
        analytical predictor and the roofline both trust this number."""
        n = self.vocab_size * self.d_model  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model  # unembed
        n += sum(self.block_params(k) for k in self.layer_pattern)
        u = self.norm_unit()
        # SSD blocks carry a single pre-norm; every other kind has two.
        norms = sum(1 if k == "ssd" else 2 for k in self.layer_pattern) + 1
        n += u * norms
        if self.encoder is not None:
            # learned absolute positions for the decoder
            n += self.max_seq_len * self.d_model
            enc_layer = (self.attn_params_per_layer()
                         + self.mlp_params_per_layer() + 2 * u)
            n += self.encoder.num_layers * enc_layer + u  # + enc_final_norm
            # decoder cross-attention: one (norm + attn) block per layer
            n += self.num_layers * (self.attn_params_per_layer() + u)
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (= param_count for dense)."""
        n = self.param_count()
        if self.moe is not None:
            n -= sum(
                self.mlp_params_per_layer() - self.active_mlp_params_per_layer()
                for k in self.layer_pattern
                if k in ("attn", "local_attn")
            )
        return n

    def kv_bytes_per_token_per_layer(self) -> int:
        return 2 * self.kv_size * self.dtype_bytes

    def num_attn_layers(self) -> int:
        return sum(1 for k in self.layer_pattern if k in ("attn", "local_attn"))

    def kv_bytes_per_token(self) -> int:
        return self.num_attn_layers() * self.kv_bytes_per_token_per_layer()

    def recurrent_state_bytes(self) -> int:
        """Per-sequence fixed-size state (SSD / RG-LRU), bytes, fp32 state."""
        total = 0
        for k in self.layer_pattern:
            if k == "ssd":
                assert self.ssm is not None
                nheads = self.ssm.num_heads(self.d_model)
                total += nheads * self.ssm.head_dim * self.ssm.state_dim * 4
                total += self.ssm.conv_width * self.ssm.d_inner(self.d_model) * 4
            elif k == "rglru":
                assert self.rglru is not None
                total += self.rglru.lru_width * 4
        return total

    def supports_long_context(self) -> bool:
        """True iff decode cost is sub-quadratic in context (long_500k cell)."""
        kinds = set(self.layer_pattern)
        if kinds <= {"ssd", "rglru", "local_attn"}:
            return True
        if kinds == {"attn"} and self.sliding_window is not None:
            return True  # SWA bounds per-step KV reads
        return False

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
