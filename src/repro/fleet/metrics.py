"""Per-tenant streaming metrics + fairness for the fleet plane.

One :class:`TenantAccumulator` per tenant, O(1) memory: submitted/completed/
failed conservation counts, SLO-attained count against the tenant's own
:class:`~repro.scenario.SLOSpec`, and running latency sums.  Fairness across
tenants is Jain's index over normalized attainment — 1.0 when every tenant
attains equally, 1/n when one tenant gets everything.

>>> round(jain_index([1.0, 1.0, 1.0]), 3)
1.0
>>> round(jain_index([1.0, 0.0, 0.0]), 3)
0.333
>>> jain_index([])
1.0
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

__all__ = ["jain_index", "TenantAccumulator"]


def jain_index(values: Sequence[float]) -> float:
    """Jain's fairness index ``(Σx)² / (n·Σx²)`` ∈ (0, 1].

    Empty input and all-zero input both return 1.0 (nothing is being
    shared unfairly); a single value is always perfectly fair.
    """
    xs = [float(v) for v in values]
    if not xs:
        return 1.0
    s2 = sum(x * x for x in xs)
    if s2 == 0.0:
        return 1.0
    s = sum(xs)
    return (s * s) / (len(xs) * s2)


@dataclass
class TenantAccumulator:
    """O(1)-memory per-tenant rollup (see module docstring).

    ``observe`` judges each completion against the tenant's SLO bounds
    (``None`` = unconstrained on that axis, exactly like
    :meth:`ScenarioResult.slo_attainment`).  ``attainment`` is attained
    over *submitted* — an unfinished or failed request counts as an SLO
    miss, so conservation (completed + failed == submitted) and attainment
    share one denominator.
    """

    name: str
    slo_ttft_s: Optional[float] = None
    slo_tpot_s: Optional[float] = None
    submitted: int = 0
    completed: int = 0
    failed: int = 0
    attained: int = 0
    ttft_sum: float = 0.0
    e2e_sum: float = 0.0
    extra: dict = field(default_factory=dict)   # static labels (model, ...)

    def observe(self, ttft: Optional[float], tpot: Optional[float],
                e2e: Optional[float]) -> None:
        self.completed += 1
        ttft_ok = (self.slo_ttft_s is None or ttft is None
                   or ttft <= self.slo_ttft_s)
        tpot_ok = (self.slo_tpot_s is None or tpot is None
                   or tpot <= self.slo_tpot_s)
        self.attained += int(ttft_ok and tpot_ok)
        if ttft is not None:
            self.ttft_sum += ttft
        if e2e is not None:
            self.e2e_sum += e2e

    def close(self) -> None:
        """Seal the books: anything submitted but never completed failed."""
        self.failed = self.submitted - self.completed

    @property
    def attainment(self) -> float:
        return self.attained / self.submitted if self.submitted else 0.0

    def goodput_rps(self, makespan: float) -> float:
        return self.attained / makespan if makespan else 0.0

    def row(self, makespan: float = 0.0) -> dict:
        out = {
            "submitted": self.submitted,
            "completed": self.completed,
            "failed": self.failed,
            "attained": self.attained,
            "attainment": round(self.attainment, 4),
            "goodput_rps": round(self.goodput_rps(makespan), 3),
            "mean_ttft_s": round(self.ttft_sum / self.completed, 4)
            if self.completed else None,
            "mean_e2e_s": round(self.e2e_sum / self.completed, 4)
            if self.completed else None,
        }
        out.update(self.extra)
        return out
