"""Execute a fleet scenario: ingress split → per-pool runs → one result.

A fleet run decomposes exactly: the ingress tenant→model mapping is a
deterministic function of the spec (no feedback from pool state), and model
pools share no capacity, so each pool's sub-run is an independent serving
experiment on the shared virtual origin — per-pool timelines compose without
a cross-pool Timekeeper, on every backend.  ``run_fleet`` materializes the
scenario's open-loop stream once, splits it through
:class:`~repro.fleet.router.ModelRouter`, executes each model pool through
the *same* per-backend internals single-pool scenarios use
(``_run_emulated`` / ``_run_des``), and aggregates one
:class:`~repro.scenario.runner.ScenarioResult` with per-tenant metrics,
Jain fairness, and parity-comparable audit trails keyed
``(pool_name, local_index)``.

The parity argument is inductive: the ingress is backend-invariant by
construction, each sub-run meets the repo's single-pool parity bar, and the
aggregation applies identical arithmetic (swap-shift re-addition) to every
backend's samples — so fleet ``compare()`` inherits the one-slow-step bar.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

from repro.fleet.metrics import TenantAccumulator, jain_index
from repro.fleet.router import ModelRouter
from repro.fleet.spec import FleetSpec, ModelPoolSpec
from repro.scenario.spec import Scenario, SpecError

__all__ = ["run_fleet", "fleet_slow_step_s", "partitioned_fleet"]


def _pool_scenario(scenario: Scenario, mp: ModelPoolSpec) -> Scenario:
    """The single-pool scenario a model pool's sub-run executes: the pool
    (with adapter KV overhead debited), its routing and autoscaler, the
    parent's SLO/seed.  ``fleet=None`` — sub-runs are ordinary scenarios."""
    return dataclasses.replace(
        scenario, name=f"{scenario.name}[{mp.name}]",
        pool=mp.effective_pool(), routing=mp.routing,
        autoscale=mp.autoscale, faults=(), fleet=None)


def fleet_slow_step_s(scenario: Scenario) -> float:
    """The coarsest predictor step across all model pools — the fleet's
    parity unit (every pool's latency discretization is bounded by it)."""
    from repro.scenario.runner import _Wiring
    assert scenario.fleet is not None
    return max(_Wiring(_pool_scenario(scenario, mp)).slow_step_s()
               for mp in scenario.fleet.models)


def partitioned_fleet(scenario: Scenario) -> Scenario:
    """The statically partitioned counterfactual of a multiplexed fleet.

    Every tenant gets a *dedicated* copy of its target model pool,
    peak-provisioned at the shared pool's replica count (each tenant's
    burst must be absorbable without the others' headroom — the classic
    static-partitioning cost), keeping only that tenant's adapter resident.
    ``fig_fleet`` runs this against the multiplexed original to make the
    headline claim: same attainment, materially fewer replica-seconds.
    """
    fleet = scenario.fleet
    assert fleet is not None
    models, tenants = [], []
    for t in fleet.tenants:
        src = fleet.model(t.model)
        pool_name = f"{t.name}-{t.model}"
        adapters = tuple(a for a in src.adapters if a.name == t.adapter)
        models.append(dataclasses.replace(
            src, name=pool_name, adapters=adapters))
        tenants.append(dataclasses.replace(t, model=pool_name))
    return dataclasses.replace(
        scenario, name=f"{scenario.name}-partitioned",
        fleet=FleetSpec(models=tuple(models), tenants=tuple(tenants)))


def run_fleet(scenario: Scenario, backend: str = "thread", *,
              timeout: float = 600.0, audit: str = "full"):
    """Execute one fleet scenario on one backend (see module docstring).

    Called by :func:`repro.scenario.run` when ``scenario.fleet`` is set;
    the same backend names/aliases apply.  Fleet aggregation attributes
    every completion to its tenant, so it requires ``audit="full"``.
    """
    from repro.scenario.runner import (BACKEND_ALIASES, BACKENDS,
                                       ScenarioResult, _Wiring, _run_des,
                                       _run_emulated)
    from repro.serving.benchmark import LatencyStats

    if audit != "full":
        raise SpecError("audit: fleet runs need audit='full' (per-tenant "
                        "attribution reads the per-request trails)")
    base, transport = BACKEND_ALIASES.get(backend, (backend, None))
    if base not in BACKENDS:
        raise SpecError(
            f"backend: invalid value {backend!r} (choose from "
            f"{sorted(BACKENDS) + sorted(BACKEND_ALIASES)})")
    scenario.validate()
    fleet = scenario.fleet
    assert fleet is not None

    requests = scenario.workload.materialize(scenario.seed)
    assignment = ModelRouter(fleet).assign(requests)

    # per-tenant books; a tenant with no explicit SLO bound inherits the
    # scenario-level SLO (so every tenant is judged against *something*)
    accs: Dict[str, TenantAccumulator] = {}
    for t in fleet.tenants:
        slo = t.slo if (t.slo.ttft_s is not None
                        or t.slo.tpot_s is not None) else scenario.slo
        accs[t.name] = TenantAccumulator(
            name=t.name, slo_ttft_s=slo.ttft_s, slo_tpot_s=slo.tpot_s,
            submitted=assignment.submitted[t.name],
            extra={"model": t.model, "adapter": t.adapter,
                   "share": t.share})

    wall0 = time.monotonic()
    sub: Dict[str, Tuple[ModelPoolSpec, list, object]] = {}
    for mp in fleet.models:
        reqs = assignment.pools[mp.name]
        if not reqs:
            continue                  # no tenant targets this pool
        s = _pool_scenario(scenario, mp)
        wiring = _Wiring(s)
        if base == "des":
            r = _run_des(s, wiring, timeout, audit, workload_override=reqs)
        else:
            r = _run_emulated(s, wiring, base, timeout, audit,
                              transport=transport, workload_override=reqs)
        sub[mp.name] = (mp, reqs, r)
    wall = time.monotonic() - wall0

    # ---- aggregate: (pool, local_idx)-keyed trails + per-tenant books ----
    latencies: Dict[object, tuple] = {}
    routing_decisions: List[object] = [("ingress", t)
                                       for t in assignment.ingress]
    scaleups: List[Tuple[float, Optional[str]]] = []
    drained: List[object] = []
    replica_tiers: List[object] = []
    tier_seconds: Dict[Optional[str], float] = {}
    ttfts: List[float] = []
    tpots: List[float] = []
    e2es: List[float] = []
    slo_samples: List[tuple] = []
    num_requests = 0
    num_steps = 0
    replica_seconds = 0.0
    cost_dollars = 0.0
    makespan = 0.0
    out_tokens = 0.0
    pools: Dict[str, dict] = {}

    for mp in fleet.models:
        if mp.name not in sub:
            continue
        mp, reqs, r = sub[mp.name]
        # same keying as the sub-run built: arrival order, stable sort
        ordered = sorted(reqs, key=lambda q: q.arrival_time)
        for i, req in enumerate(ordered):
            sample = r.latencies.get(i)
            if sample is None:
                continue              # never completed -> tenant "failed"
            ttft, tpot, e2e = sample
            shift = assignment.swap_shift.get(req.request_id, 0.0)
            if shift:
                # the adapter cold-load the ingress jumped service past:
                # the tenant pays it in reported TTFT/e2e
                ttft = None if ttft is None else ttft + shift
                e2e = None if e2e is None else e2e + shift
            latencies[(mp.name, i)] = (ttft, tpot, e2e)
            accs[req.tenant].observe(ttft, tpot, e2e)
            if ttft is not None:
                ttfts.append(ttft)
            if tpot is not None:
                tpots.append(tpot)
            if e2e is not None:
                e2es.append(e2e)
            slo_samples.append((ttft, tpot))
        routing_decisions.extend((mp.name, d)
                                 for d in r.routing_decisions)
        scaleups.extend((t, f"{mp.name}:{tier or '?'}")
                        for t, tier in r.scaleups)
        drained.extend((mp.name, d) for d in r.drained)
        replica_tiers.extend((mp.name, t) for t in r.replica_tiers)
        for tier, s in (r.tier_seconds or {}).items():
            tier_seconds[tier] = tier_seconds.get(tier, 0.0) + s
        num_requests += r.num_requests
        num_steps += r.num_steps
        replica_seconds += r.replica_seconds
        cost_dollars += r.cost_dollars
        out_tokens += r.throughput_tokens_per_s * r.makespan_virtual
        makespan = max(makespan, r.makespan_virtual)
        pools[mp.name] = {
            "model": mp.pool.model,
            "replicas": mp.pool.replicas,
            "adapters": len(mp.adapters),
            "requests": r.num_requests,
            "replica_seconds": round(r.replica_seconds, 3),
            "virtual_s": round(r.makespan_virtual, 3),
        }

    for acc in accs.values():
        acc.close()
    scaleups.sort(key=lambda e: (e[0], e[1]))

    return ScenarioResult(
        scenario=scenario.name, backend=backend, seed=scenario.seed,
        num_requests=num_requests, num_sessions=0,
        ttft=LatencyStats.of(ttfts), tpot=LatencyStats.of(tpots),
        e2e=LatencyStats.of(e2es), session_ttft=None,
        makespan_virtual=makespan, wall_seconds=wall,
        throughput_tokens_per_s=(out_tokens / makespan if makespan else 0.0),
        slo_samples=slo_samples,
        num_slo_samples=len(slo_samples),
        slo_ttft_s=scenario.slo.ttft_s, slo_tpot_s=scenario.slo.tpot_s,
        audit=audit,
        replica_seconds=replica_seconds,
        cost_dollars=cost_dollars,
        tier_seconds=tier_seconds or None,
        num_steps=num_steps,
        routing_decisions=routing_decisions,
        placements=None,
        latencies=latencies,
        replica_tiers=replica_tiers,
        scaleups=scaleups,
        drained=drained,
        tenants={name: acc.row(makespan) for name, acc in accs.items()},
        pools=pools,
        fairness=jain_index([acc.attainment for acc in accs.values()]),
    )
