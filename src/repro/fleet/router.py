"""The ingress: deterministic tenant assignment + per-model demultiplexing.

:class:`ModelRouter` sits *above* the per-pool request routers: it walks the
scenario's open-loop request stream in arrival order, assigns each request
to a tenant by smooth weighted round-robin over the tenants' traffic shares
(deterministic — no RNG, so every backend sees the identical split), tags
the request with its tenant and LoRA adapter, and buckets it into its target
model pool's stream.  Adapter cold-loads are applied here as virtual-time
stalls: the first request of each adapter has its service start shifted past
``swap_s`` (the engine's dispatcher literally jumps the virtual clock over
the swap) and the shift is recorded so the fleet aggregation re-adds it to
that request's *reported* TTFT/e2e — the tenant pays for the swap, the
parity arithmetic stays backend-identical.

Smooth WRR: per step every tenant's credit grows by its share; the richest
tenant (ties: higher ``priority``, then spec order) takes the request and
pays the total share back.  For shares 2:1:1 the emitted sequence is
A B C A · A B C A · … — the classic interleaved schedule, a function of the
spec alone, independent of request contents.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

from repro.fleet.spec import FleetSpec

__all__ = ["ModelRouter", "FleetAssignment"]


@dataclass
class FleetAssignment:
    """What the ingress produced for one run (all maps keyed stably)."""

    # model pool name -> its arrival-ordered, tenant-tagged request stream
    pools: Dict[str, List] = field(default_factory=dict)
    # ingress audit: tenant name per request, arrival order (deterministic
    # function of the spec — identical on every backend by construction)
    ingress: List[str] = field(default_factory=list)
    # request_id -> virtual seconds of adapter cold-load the request's
    # service start was shifted past (re-added to reported TTFT/e2e)
    swap_shift: Dict[int, float] = field(default_factory=dict)
    # tenant name -> number of requests assigned (submitted)
    submitted: Dict[str, int] = field(default_factory=dict)


class ModelRouter:
    """Deterministic multi-model ingress (see module docstring)."""

    def __init__(self, fleet: FleetSpec):
        self.fleet = fleet
        self._tenants = list(fleet.tenants)
        self._total_share = sum(t.share for t in self._tenants)
        # smooth-WRR credit per tenant, spec order
        self._credit = [0.0] * len(self._tenants)

    def _next_tenant(self) -> int:
        """One smooth-WRR step; returns the chosen tenant's spec index."""
        for i, t in enumerate(self._tenants):
            self._credit[i] += t.share
        best = min(
            range(len(self._tenants)),
            key=lambda i: (-self._credit[i], -self._tenants[i].priority, i))
        self._credit[best] -= self._total_share
        return best

    def assign(self, requests: Sequence) -> FleetAssignment:
        """Split an arrival-ordered request stream across the fleet.

        Mutates the requests (tenant/adapter tags + swap-shifted arrival
        times) — callers pass a freshly materialized workload, one per run.
        """
        out = FleetAssignment(
            pools={m.name: [] for m in self.fleet.models},
            submitted={t.name: 0 for t in self._tenants})
        seen_adapters: set = set()
        ordered = sorted(requests, key=lambda r: r.arrival_time)
        for req in ordered:
            tenant = self._tenants[self._next_tenant()]
            req.tenant = tenant.name
            req.adapter = tenant.adapter
            out.ingress.append(tenant.name)
            out.submitted[tenant.name] += 1
            if tenant.adapter is not None:
                key = (tenant.model, tenant.adapter)
                if key not in seen_adapters:
                    seen_adapters.add(key)
                    swap = self.fleet.model(tenant.model) \
                        .adapter(tenant.adapter).swap_s
                    if swap > 0:
                        # cold load: service start jumps past the swap;
                        # the shift is re-added to reported latency
                        req.arrival_time += swap
                        out.swap_shift[req.request_id] = swap
            out.pools[tenant.model].append(req)
        return out

    def tenant_targets(self) -> List[Tuple[str, str]]:
        """(tenant, model) pairs, spec order (docs/CLI introspection)."""
        return [(t.name, t.model) for t in self._tenants]
