"""Fleet plane: multi-model, multi-tenant serving above the cluster layer.

The ROADMAP's "multi-model, multi-tenant serving plane" item: one scenario
declares N model pools (each a full :class:`~repro.scenario.PoolSpec` with
its own routing policy and autoscaler, optionally multiplexing LoRA
adapters onto shared base replicas) and M tenants (weighted traffic shares,
priorities, per-tenant SLOs).  A deterministic ingress
(:class:`ModelRouter`) splits the open-loop stream, :func:`run_fleet`
executes every pool through the same backend internals single-pool
scenarios use, and the aggregated result reports per-tenant attainment,
goodput, and Jain fairness — on the thread emulator, the process emulator
(tcp or shm wire), and the DES baseline, with ``compare()`` holding the
repo's one-slow-step parity bar across them.

Entry points: set ``Scenario.fleet`` and call :func:`repro.scenario.run`
(the dispatch is automatic), or use the ``fleet_mix`` preset.  See
``docs/scenarios.md`` and ``benchmarks/fig_fleet.py`` for the headline
multiplexed-vs-partitioned experiment.
"""

from .metrics import TenantAccumulator, jain_index
from .router import FleetAssignment, ModelRouter
from .runner import fleet_slow_step_s, partitioned_fleet, run_fleet
from .spec import AdapterSpec, FleetSpec, ModelPoolSpec, TenantSpec

__all__ = [
    "AdapterSpec",
    "ModelPoolSpec",
    "TenantSpec",
    "FleetSpec",
    "ModelRouter",
    "FleetAssignment",
    "TenantAccumulator",
    "jain_index",
    "run_fleet",
    "partitioned_fleet",
    "fleet_slow_step_s",
]
