"""Fleet specs: N model pools + M tenants over one declarative scenario.

A :class:`FleetSpec` extends a :class:`~repro.scenario.Scenario` (its
``fleet`` field) into a multi-model, multi-tenant serving plane:

* ``models`` — one :class:`ModelPoolSpec` per served model: a full
  :class:`~repro.scenario.PoolSpec` (model config, engine knobs, tiers,
  predictor), its own routing policy and optional per-pool autoscaler, and
  optionally a set of LoRA :class:`AdapterSpec` entries multiplexed onto the
  shared base-model replicas;
* ``tenants`` — one :class:`TenantSpec` per traffic source: a weighted share
  of the scenario's open-loop stream, a priority for ingress tie-breaking, a
  per-tenant :class:`~repro.scenario.SLOSpec`, and the target model (or
  model + adapter) its requests are served by.

The specs reuse the scenario codec (`to_dict`/`from_dict` with dotted-path
``SpecError``\\ s), so a fleet is just more JSON in the same scenario file,
and list-valued fields report errors with indexed paths
(``fleet.tenants[1].slo.ttft_s``).

Capacity semantics: each adapter's ``kv_blocks`` is debited from its base
pool's ``num_blocks`` (resident adapter weights/KV eat into shared HBM), and
``swap_s`` models the one-time adapter cold-load as a virtual-time stall the
first request of that adapter pays — spec-level arithmetic, identical on
every backend, so fleet runs stay parity-comparable.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

from repro.scenario.spec import (AutoscaleSpec, PoolSpec, RoutingSpec,
                                 SLOSpec, SpecError, _SpecBase)

__all__ = [
    "AdapterSpec",
    "ModelPoolSpec",
    "TenantSpec",
    "FleetSpec",
]


@dataclass(frozen=True)
class AdapterSpec(_SpecBase):
    """One LoRA adapter multiplexed onto a shared base-model pool.

    ``kv_blocks`` is the KV/weight overhead of keeping the adapter resident,
    debited from the base pool's ``num_blocks``; ``swap_s`` is the one-time
    cold-load latency the adapter's first request pays (a virtual-time
    stall — the ingress shifts service start past it and re-adds it to that
    request's reported TTFT/e2e).
    """

    name: str = "adapter"
    kv_blocks: int = 0
    swap_s: float = 0.0

    def validate(self, *, path: str = "adapter") -> None:
        if not self.name:
            raise SpecError(f"{path}.name: must be non-empty")
        if self.kv_blocks < 0:
            raise SpecError(f"{path}.kv_blocks: must be >= 0")
        if self.swap_s < 0:
            raise SpecError(f"{path}.swap_s: must be >= 0")


@dataclass(frozen=True)
class ModelPoolSpec(_SpecBase):
    """One served model: a replica pool plus its routing/scaling/adapters."""

    name: str = "model"
    pool: PoolSpec = field(default_factory=PoolSpec)
    routing: RoutingSpec = field(default_factory=RoutingSpec)
    autoscale: Optional[AutoscaleSpec] = None
    adapters: Tuple[AdapterSpec, ...] = ()

    def validate(self, *, path: str = "model") -> None:
        if not self.name:
            raise SpecError(f"{path}.name: must be non-empty")
        self.pool.validate(path=f"{path}.pool")
        self.routing.validate(path=f"{path}.routing")
        if self.routing.policy == "pd_pool":
            raise SpecError(f"{path}.routing.policy: pd_pool is not "
                            "supported inside a fleet pool")
        seen = set()
        for i, a in enumerate(self.adapters):
            a.validate(path=f"{path}.adapters[{i}]")
            if a.name in seen:
                raise SpecError(f"{path}.adapters[{i}].name: duplicate "
                                f"adapter name {a.name!r}")
            seen.add(a.name)
        overhead = sum(a.kv_blocks for a in self.adapters)
        if overhead >= self.pool.num_blocks:
            raise SpecError(
                f"{path}.adapters: resident adapter overhead "
                f"({overhead} blocks) consumes the whole pool "
                f"(pool.num_blocks={self.pool.num_blocks})")
        if self.autoscale is not None:
            self.autoscale.validate(path=f"{path}.autoscale")
            a = self.autoscale
            if not (a.min_replicas <= self.pool.replicas <= a.max_replicas):
                raise SpecError(
                    f"{path}.pool.replicas: initial pool "
                    f"({self.pool.replicas}) outside autoscale bounds "
                    f"[{a.min_replicas}, {a.max_replicas}]")

    def adapter(self, name: str) -> AdapterSpec:
        for a in self.adapters:
            if a.name == name:
                return a
        raise KeyError(name)

    def effective_pool(self) -> PoolSpec:
        """The pool with resident-adapter KV overhead debited from its
        block budget — what the engines are actually built with."""
        overhead = sum(a.kv_blocks for a in self.adapters)
        if overhead == 0:
            return self.pool
        return dataclasses.replace(
            self.pool, num_blocks=self.pool.num_blocks - overhead)


@dataclass(frozen=True)
class TenantSpec(_SpecBase):
    """One traffic source: a weighted slice of the scenario's workload.

    ``share`` is a relative weight (shares need not sum to anything);
    ``priority`` breaks ingress assignment ties (higher first).  ``model``
    names the target :class:`ModelPoolSpec`; ``adapter`` (optional) names a
    LoRA adapter declared on that model.  ``slo`` judges this tenant's
    attainment — per-tenant SLOs are the whole point of the fleet plane.
    """

    name: str = "tenant"
    share: float = 1.0
    priority: int = 0
    model: str = "model"
    adapter: Optional[str] = None
    slo: SLOSpec = field(default_factory=SLOSpec)

    def validate(self, *, path: str = "tenant") -> None:
        if not self.name:
            raise SpecError(f"{path}.name: must be non-empty")
        if self.share <= 0:
            raise SpecError(f"{path}.share: must be > 0")
        self.slo.validate(path=f"{path}.slo")


@dataclass(frozen=True)
class FleetSpec(_SpecBase):
    """The whole plane: model pools + tenants (see module docstring)."""

    models: Tuple[ModelPoolSpec, ...] = ()
    tenants: Tuple[TenantSpec, ...] = ()

    def validate(self, *, path: str = "fleet") -> None:
        if not self.models:
            raise SpecError(f"{path}.models: need at least one model pool")
        if not self.tenants:
            raise SpecError(f"{path}.tenants: need at least one tenant")
        by_name = {}
        for i, m in enumerate(self.models):
            m.validate(path=f"{path}.models[{i}]")
            if m.name in by_name:
                raise SpecError(f"{path}.models[{i}].name: duplicate model "
                                f"name {m.name!r}")
            by_name[m.name] = m
        seen = set()
        for i, t in enumerate(self.tenants):
            t.validate(path=f"{path}.tenants[{i}]")
            if t.name in seen:
                raise SpecError(f"{path}.tenants[{i}].name: duplicate "
                                f"tenant name {t.name!r}")
            seen.add(t.name)
            target = by_name.get(t.model)
            if target is None:
                raise SpecError(
                    f"{path}.tenants[{i}].model: unknown model {t.model!r} "
                    f"(declared: {', '.join(sorted(by_name))})")
            if t.adapter is not None:
                valid = [a.name for a in target.adapters]
                if t.adapter not in valid:
                    raise SpecError(
                        f"{path}.tenants[{i}].adapter: model {t.model!r} "
                        f"declares no adapter {t.adapter!r} "
                        f"(declared: {', '.join(sorted(valid)) or 'none'})")

    def model(self, name: str) -> ModelPoolSpec:
        for m in self.models:
            if m.name == name:
                return m
        raise KeyError(name)

    def tenant(self, name: str) -> TenantSpec:
        for t in self.tenants:
            if t.name == name:
                return t
        raise KeyError(name)
