"""Bounded-memory metrics: quantile sketches, streaming moments, reservoirs.

The scale path replays millions of requests through one scenario; per-request
latency lists would dominate memory long before the emulator's own state
does.  This package keeps every summary the benchmark layer reports —
percentiles, means, SLO attainment, per-session stats — in O(1) (or
O(reservoir)) memory:

- :class:`QuantileSketch` — deterministic online percentile sketch
  (Greenwald–Khanna summary with an exact small-N mode), stdlib-only.
- :class:`StreamingStat` — count / sum / mean / min / max accumulator.
- :class:`ReservoirSample` — seeded Algorithm-R uniform sample.
- :class:`LatencyStats` — the summary dataclass the serving benchmark and
  scenario layers report (moved here from ``repro.serving.benchmark``,
  which re-exports it); raw-sample retention is opt-in.
- :class:`LatencyAccumulator` / :class:`StreamingMetrics` — streaming
  builders feeding the above from a completion stream (audit != "full").
"""

from .latency import (LatencyAccumulator, LatencyStats, StreamingMetrics,
                      compare_distributions)
from .sketch import QuantileSketch, ReservoirSample, StreamingStat

__all__ = [
    "QuantileSketch",
    "ReservoirSample",
    "StreamingStat",
    "LatencyStats",
    "LatencyAccumulator",
    "StreamingMetrics",
    "compare_distributions",
]
