"""Latency summaries on bounded memory.

:class:`LatencyStats` is the summary dataclass every benchmark/scenario
result carries (it lived in ``repro.serving.benchmark`` before the metrics
package existed; that module re-exports it).  Two construction paths:

- :meth:`LatencyStats.of` — from a materialized sample list (the audit=full
  path).  The headline fields (mean/p50/p90/p99) are computed with numpy
  exactly as before, so figure assertions and the parity bar are unmoved.
  Raw-sample retention is **opt-in** via ``keep_raw=True``; by default the
  result keeps only the summary plus an O(1)-memory sketch.
- :class:`LatencyAccumulator` — streaming construction, one ``add`` per
  completion, O(1) memory (audit=sampled/off).  Below the sketch's exact
  cap the percentiles are bit-identical to the materialized path.

:class:`StreamingMetrics` bundles the accumulators a full benchmark result
needs (TTFT/TPOT/e2e, SLO reservoir, per-session stats) behind one
``observe(request)`` call, shared by the emulator completion listener and
the DES sink.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional

import numpy as np

from .sketch import QuantileSketch, ReservoirSample


@dataclass
class LatencyStats:
    """Latency distribution summary.

    ``values`` is raw-sample retention, **opt-in** (``of(..., keep_raw=True)``):
    a million-request run must not hold a million floats per metric.
    ``percentile`` answers arbitrary quantiles — exactly while raw values
    exist, within the sketch's ±eps rank error otherwise.
    """

    mean: float
    p50: float
    p90: float
    p99: float
    values: List[float] = field(repr=False, default_factory=list)
    count: int = 0
    maximum: float = 0.0
    sketch: Optional[QuantileSketch] = field(repr=False, compare=False,
                                             default=None)

    @staticmethod
    def of(values: Iterable[float],
           keep_raw: bool = False) -> "LatencyStats":
        vals = [float(v) for v in values]
        if not vals:
            return LatencyStats(0.0, 0.0, 0.0, 0.0, [])
        arr = np.asarray(vals, dtype=np.float64)
        sketch = QuantileSketch()
        sketch.extend(vals)
        return LatencyStats(
            float(arr.mean()),
            float(np.percentile(arr, 50)),
            float(np.percentile(arr, 90)),
            float(np.percentile(arr, 99)),
            vals if keep_raw else [],
            count=len(vals),
            maximum=float(arr.max()),
            sketch=sketch,
        )

    def percentile(self, q: float) -> float:
        """Quantile lookup: stored fields for 50/90/99, raw values when
        retained, sketch otherwise."""
        if self.count == 0 and not self.values:
            raise ValueError(
                "percentile of empty LatencyStats: no samples were recorded")
        for fixed_q, v in ((50, self.p50), (90, self.p90), (99, self.p99)):
            if q == fixed_q:
                return v
        if self.values:
            return float(np.percentile(
                np.asarray(self.values, dtype=np.float64), q))
        if self.sketch is not None and self.sketch.count:
            return self.sketch.percentile(q)
        raise ValueError(
            f"p{q} unavailable: stats carry neither raw values nor a sketch "
            f"(construct via LatencyStats.of or LatencyAccumulator)")


class LatencyAccumulator:
    """Streaming :class:`LatencyStats` builder: O(1) memory per metric."""

    def __init__(self, eps: float = 0.005, exact_cap: int = 2048):
        self.sketch = QuantileSketch(eps=eps, exact_cap=exact_cap)

    def add(self, value: float) -> None:
        self.sketch.add(value)

    @property
    def count(self) -> int:
        return self.sketch.count

    def stats(self) -> LatencyStats:
        sk = self.sketch
        if sk.count == 0:
            return LatencyStats(0.0, 0.0, 0.0, 0.0, [])
        return LatencyStats(
            mean=sk.mean,
            p50=sk.percentile(50),
            p90=sk.percentile(90),
            p99=sk.percentile(99),
            count=sk.count,
            maximum=sk.maximum,
            sketch=sk,
        )


def compare_distributions(a: LatencyStats, b: LatencyStats) -> Dict[str, float]:
    """Percentile-wise relative error between two latency distributions
    (the paper's Fig. 6/8 accuracy metric: <5% across the CDF).

    Works on raw-valued and sketch-backed stats alike; comparing a
    distribution with no samples is a usage bug and raises instead of
    silently reporting zero error.
    """
    for name, s in (("a", a), ("b", b)):
        if not s.values and not s.count:
            raise ValueError(
                f"compare_distributions: side {name!r} has no samples "
                f"(empty LatencyStats) — filter empty metrics before "
                f"comparing")
    out = {}
    for q in (50, 75, 90, 95, 99):
        va = a.percentile(q)
        vb = b.percentile(q)
        denom = max(abs(va), 1e-9)
        out[f"p{q}_rel_err"] = abs(va - vb) / denom
    out["median_rel_err"] = out["p50_rel_err"]
    return out


class _SessionAgg:
    """Per-live-session running sums; finalized into per-session means."""

    __slots__ = ("turns_seen", "ttft_sum", "ttft_n", "tpot_sum", "tpot_n")

    def __init__(self) -> None:
        self.turns_seen = 0
        self.ttft_sum = 0.0
        self.ttft_n = 0
        self.tpot_sum = 0.0
        self.tpot_n = 0


class StreamingMetrics:
    """One ``observe()`` per completed request; bounded memory throughout.

    Feeds TTFT/TPOT/e2e sketches, an exact completion/token count, the max
    finish time, a seeded reservoir of ``(ttft, tpot)`` SLO samples (with
    the exact sample count kept separately so goodput stays unbiased), and
    per-session mean TTFT/TPOT sketches.  Session state is held only for
    *live* sessions: when ``session_turns`` (a ``sid -> num_turns`` lookup)
    is provided, a session's running sums are folded into the sketches and
    dropped the moment its last turn completes, so memory tracks the number
    of concurrently-open sessions, not the total.  Thread-safe — emulator
    completion listeners fire from concurrent replica step threads.
    """

    def __init__(self, *, slo_reservoir: int = 8192, seed: int = 0,
                 session_turns: Optional[Callable[[int], int]] = None,
                 eps: float = 0.005, exact_cap: int = 2048):
        self._lock = threading.Lock()
        self.ttft = LatencyAccumulator(eps=eps, exact_cap=exact_cap)
        self.tpot = LatencyAccumulator(eps=eps, exact_cap=exact_cap)
        self.e2e = LatencyAccumulator(eps=eps, exact_cap=exact_cap)
        self.session_ttft = LatencyAccumulator(eps=eps, exact_cap=exact_cap)
        self.session_tpot = LatencyAccumulator(eps=eps, exact_cap=exact_cap)
        self.slo = ReservoirSample(slo_reservoir, seed=seed)
        self.count = 0
        self.total_new_tokens = 0
        self.max_finish: Optional[float] = None
        self.num_sessions = 0
        self._session_turns = session_turns
        self._sessions: Dict[int, _SessionAgg] = {}

    def observe(self, req) -> None:
        """``req`` needs ``ttft()``, ``tpot()``, ``num_generated``,
        ``finish_time``, ``arrival_time``, ``session_id``, ``turn_index`` —
        both the serving :class:`Request` and the DES ``SimRequest`` do."""
        ttft = req.ttft()
        tpot = req.tpot() if req.num_generated > 1 else None
        with self._lock:
            self.count += 1
            self.total_new_tokens += int(req.num_generated)
            if ttft is not None:
                self.ttft.add(ttft)
            if tpot is not None:
                self.tpot.add(tpot)
            if req.finish_time is not None:
                self.e2e.add(req.finish_time - req.arrival_time)
                if self.max_finish is None or req.finish_time > self.max_finish:
                    self.max_finish = req.finish_time
            self.slo.add((ttft, tpot))
            sid = req.session_id
            if sid is None:
                return
            agg = self._sessions.get(sid)
            if agg is None:
                agg = self._sessions[sid] = _SessionAgg()
            agg.turns_seen += 1
            if ttft is not None:
                agg.ttft_sum += ttft
                agg.ttft_n += 1
            if tpot is not None:
                agg.tpot_sum += tpot
                agg.tpot_n += 1
            if (self._session_turns is not None
                    and agg.turns_seen >= self._session_turns(sid)):
                self._finalize_session(sid)

    def _finalize_session(self, sid: int) -> None:
        agg = self._sessions.pop(sid)
        self.num_sessions += 1
        if agg.ttft_n:
            self.session_ttft.add(agg.ttft_sum / agg.ttft_n)
        if agg.tpot_n:
            self.session_tpot.add(agg.tpot_sum / agg.tpot_n)

    def finalize(self) -> None:
        """Fold any still-open sessions (run ended early, or no
        ``session_turns`` lookup was available) into the session sketches."""
        with self._lock:
            for sid in sorted(self._sessions):
                self._finalize_session(sid)

    @property
    def num_slo_samples(self) -> int:
        """Exact number of (ttft, tpot) observations — the reservoir holds a
        uniform subset, goodput scales attainment by this true count."""
        return self.slo.count
