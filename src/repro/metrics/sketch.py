"""Deterministic O(1)-memory accumulators (stdlib-only).

:class:`QuantileSketch` is a Greenwald–Khanna (GK) quantile summary with an
exact small-N front end: below ``exact_cap`` samples it simply keeps the
values and answers percentiles with the same linear interpolation
``np.percentile`` uses (bit-for-bit — the small-N figure assertions and the
scenario parity bar must not move when results become sketch-backed).  Past
the cap it spills into a GK summary whose size is O(1/eps · log(eps·n)) and
whose answers carry a ±eps·n rank-error guarantee.

Everything here is deterministic: same insertion order ⇒ same internal state
⇒ same answers, with no wall-clock or global-RNG dependence.  The reservoir
uses its own seeded ``random.Random``.
"""

from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import Any, Iterable, List, Optional


def _np_lerp(a: float, b: float, t: float) -> float:
    """numpy's linear-interpolation kernel (bit-exact with np.percentile)."""
    diff = b - a
    if t >= 0.5:
        return b - diff * (1.0 - t)
    return a + diff * t


def _interpolate(sorted_vals: List[float], frac: float) -> float:
    """Value at cumulative fraction ``frac`` of a sorted sample, matching
    ``np.percentile(..., method="linear")`` exactly."""
    n = len(sorted_vals)
    if n == 1:
        return sorted_vals[0]
    pos = frac * (n - 1)
    lo = int(math.floor(pos))
    hi = min(lo + 1, n - 1)
    return _np_lerp(sorted_vals[lo], sorted_vals[hi], pos - lo)


class QuantileSketch:
    """Online percentile sketch: exact below ``exact_cap``, GK beyond.

    GK invariant: for every summary entry ``(v, g, delta)``,
    ``g + delta <= floor(2 * eps * n)``, which bounds the rank uncertainty
    of any answer by ``eps * n``.  Inserts are buffered and applied as
    sorted batches (one O(entries + batch) merge per ``~1/(2 eps)`` adds),
    so amortized insert cost stays flat.
    """

    def __init__(self, eps: float = 0.005, exact_cap: int = 2048):
        if not 0.0 < eps < 0.5:
            raise ValueError(f"eps must be in (0, 0.5), got {eps}")
        self.eps = float(eps)
        self.exact_cap = int(exact_cap)
        self.count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._exact: Optional[List[float]] = []      # None once in GK mode
        self._entries: List[List[float]] = []        # [v, g, delta], v-sorted
        self._buffer: List[float] = []
        self._buffer_cap = max(16, int(1.0 / (2.0 * self.eps)))

    # ------------------------------------------------------------- insert --
    def add(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self._sum += v
        if v < self._min:
            self._min = v
        if v > self._max:
            self._max = v
        if self._exact is not None:
            self._exact.append(v)
            if len(self._exact) > self.exact_cap:
                self._spill()
            return
        self._buffer.append(v)
        if len(self._buffer) >= self._buffer_cap:
            self._flush()

    def extend(self, values: Iterable[float]) -> None:
        for v in values:
            self.add(v)

    def _spill(self) -> None:
        vals = sorted(self._exact)
        self._exact = None
        self._entries = [[v, 1, 0] for v in vals]
        self._compress()

    def _flush(self) -> None:
        if not self._buffer:
            return
        buf = sorted(self._buffer)
        self._buffer = []
        # GK insert rule: an interior insert may claim delta = floor(2εn)-1;
        # inserts at either extreme are exact (delta = 0).
        dmax = max(0, int(math.floor(2.0 * self.eps * self.count)) - 1)
        entries = self._entries
        out: List[List[float]] = []
        i = 0
        for v in buf:
            while i < len(entries) and entries[i][0] <= v:
                out.append(entries[i])
                i += 1
            delta = 0 if (i == 0 or i == len(entries)) else dmax
            out.append([v, 1, delta])
        out.extend(entries[i:])
        self._entries = out
        self._compress()

    def _compress(self) -> None:
        entries = self._entries
        if len(entries) < 3:
            return
        threshold = int(math.floor(2.0 * self.eps * self.count))
        out = [entries[-1]]
        # right-to-left greedy merge of an entry into its successor; the
        # first and last entries are never merged away (min/max stay exact)
        for i in range(len(entries) - 2, 0, -1):
            e = entries[i]
            succ = out[-1]
            if e[1] + succ[1] + succ[2] <= threshold:
                succ[1] += e[1]
            else:
                out.append(e)
        out.append(entries[0])
        out.reverse()
        self._entries = out

    # -------------------------------------------------------------- query --
    def quantile(self, frac: float) -> float:
        """Value at cumulative fraction ``frac`` in [0, 1]."""
        if self.count == 0:
            raise ValueError("quantile of an empty QuantileSketch")
        if self._exact is not None:
            return _interpolate(sorted(self._exact), frac)
        self._flush()
        n = self.count
        rank = 1.0 + frac * (n - 1)              # fractional 1-based rank
        margin = self.eps * n
        cum = 0
        prev = self._entries[0][0]
        for v, g, d in self._entries:
            cum += g
            if cum + d > rank + margin:
                return prev
            prev = v
        return self._entries[-1][0]

    def percentile(self, q: float) -> float:
        return self.quantile(q / 100.0)

    @property
    def mean(self) -> float:
        return self._sum / self.count if self.count else 0.0

    @property
    def minimum(self) -> float:
        return self._min

    @property
    def maximum(self) -> float:
        return self._max

    @property
    def num_entries(self) -> int:
        """Current summary footprint (exact buffer or GK entry count)."""
        if self._exact is not None:
            return len(self._exact)
        return len(self._entries) + len(self._buffer)

    # -------------------------------------------------------------- merge --
    def _gk_entries(self) -> List[List[float]]:
        if self._exact is not None:
            return [[v, 1, 0] for v in sorted(self._exact)]
        self._flush()
        return [list(e) for e in self._entries]

    def merge(self, other: "QuantileSketch") -> "QuantileSketch":
        """Combine two sketches into a new one.

        Merging keeps each input's ``(g, delta)`` bookkeeping, so the result
        carries the *sum* of the inputs' rank errors (standard GK merge
        behavior) — still bounded, just looser than a single-stream sketch.
        """
        out = QuantileSketch(eps=max(self.eps, other.eps),
                             exact_cap=self.exact_cap)
        out.count = self.count + other.count
        out._sum = self._sum + other._sum
        out._min = min(self._min, other._min)
        out._max = max(self._max, other._max)
        if (self._exact is not None and other._exact is not None
                and out.count <= out.exact_cap):
            out._exact = sorted(self._exact + other._exact)
            return out
        a, b = self._gk_entries(), other._gk_entries()
        merged: List[List[float]] = []
        i = j = 0
        while i < len(a) and j < len(b):
            if a[i][0] <= b[j][0]:
                merged.append(a[i])
                i += 1
            else:
                merged.append(b[j])
                j += 1
        merged.extend(a[i:])
        merged.extend(b[j:])
        out._exact = None
        out._entries = merged
        out._compress()
        return out

    # -------------------------------------------------------------- state --
    def state(self) -> dict:
        """Canonical serializable state (the byte-stability contract)."""
        if self._exact is not None:
            body: dict = {"exact": list(self._exact)}
        else:
            self._flush()
            body = {"entries": [list(e) for e in self._entries]}
        return {"eps": self.eps, "count": self.count, "sum": self._sum,
                "min": self._min, "max": self._max, **body}

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        mode = "exact" if self._exact is not None else "gk"
        return (f"QuantileSketch(eps={self.eps}, n={self.count}, "
                f"mode={mode}, entries={self.num_entries})")


@dataclass
class StreamingStat:
    """Count / sum / mean / min / max in O(1) memory."""

    count: int = 0
    total: float = 0.0
    minimum: float = math.inf
    maximum: float = -math.inf

    def add(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.total += v
        if v < self.minimum:
            self.minimum = v
        if v > self.maximum:
            self.maximum = v

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0


class ReservoirSample:
    """Seeded Algorithm-R uniform reservoir: deterministic under a fixed
    seed and insertion order, O(capacity) memory for any stream length."""

    def __init__(self, capacity: int, seed: int = 0):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.count = 0
        self.items: List[Any] = []
        self._rng = random.Random(seed)

    def add(self, item: Any) -> None:
        self.count += 1
        if len(self.items) < self.capacity:
            self.items.append(item)
            return
        j = self._rng.randrange(self.count)
        if j < self.capacity:
            self.items[j] = item

    @property
    def exact(self) -> bool:
        """True while the reservoir still holds every observed item."""
        return self.count <= self.capacity

    def __len__(self) -> int:
        return len(self.items)
