"""Pure-jnp oracles for every Pallas kernel in this package.

Tests sweep shapes/dtypes and ``assert_allclose`` the kernels (run with
``interpret=True`` on CPU) against these references; real-mode serving on CPU
also executes these (the Pallas kernels are the TPU path).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        softmax_scale: Optional[float] = None):
    """q: (B,T,Hq,D); k,v: (B,S,Hkv,D) -> (B,T,Hq,D).  fp32 softmax."""
    B, T, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = softmax_scale or 1.0 / math.sqrt(D)
    qg = q.reshape(B, T, Hkv, G, D)
    scores = jnp.einsum("bthgd,bshd->bhgts", qg, k).astype(jnp.float32) * scale
    q_pos = jnp.arange(T)[:, None]
    kv_pos = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        # queries are the *last* T positions of the S-long stream
        offset = S - T
        mask &= kv_pos <= q_pos + offset
        if window is not None:
            mask &= (q_pos + offset) - kv_pos < window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgts,bshd->bthgd", probs.astype(v.dtype), v)
    return out.reshape(B, T, Hq, D)


def paged_attention_ref(q, k_pages, v_pages, block_tables, context_lens, *,
                        softmax_scale: Optional[float] = None):
    """Decode attention against a paged KV pool.

    q:            (B, Hq, D)      — one query token per sequence
    k/v_pages:    (num_pages, page_size, Hkv, D)
    block_tables: (B, pages_per_seq) int32 — page ids per sequence
    context_lens: (B,) int32      — valid KV length per sequence
    returns       (B, Hq, D)
    """
    B, Hq, D = q.shape
    P, page_size, Hkv, _ = k_pages.shape
    pages_per_seq = block_tables.shape[1]
    G = Hq // Hkv
    scale = softmax_scale or 1.0 / math.sqrt(D)

    k = k_pages[block_tables]  # (B, pages, page_size, Hkv, D)
    v = v_pages[block_tables]
    S = pages_per_seq * page_size
    k = k.reshape(B, S, Hkv, D)
    v = v.reshape(B, S, Hkv, D)
    qg = q.reshape(B, Hkv, G, D)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg, k).astype(jnp.float32) * scale
    valid = jnp.arange(S)[None, :] < context_lens[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", probs.astype(v.dtype), v)
    return out.reshape(B, Hq, D)


def ssd_scan_ref(xdt, dA, Bm, Cm, *, initial_state=None):
    """Sequential SSD recurrence oracle (exact, O(T)).

    xdt: (B,T,H,P) — dt-premultiplied inputs; dA: (B,T,H) — log decay
    Bm/Cm: (B,T,N); returns (y (B,T,H,P), final_state (B,H,N,P)) in fp32.
    """
    B, T, H, P = xdt.shape
    N = Bm.shape[-1]
    xdt = xdt.astype(jnp.float32)
    dA = dA.astype(jnp.float32)
    Bm = Bm.astype(jnp.float32)
    Cm = Cm.astype(jnp.float32)
    s0 = (jnp.zeros((B, H, N, P), jnp.float32)
          if initial_state is None else initial_state.astype(jnp.float32))

    def step(s, inp):
        x_t, dA_t, B_t, C_t = inp  # (B,H,P),(B,H),(B,N),(B,N)
        s = s * jnp.exp(dA_t)[:, :, None, None] + jnp.einsum("bn,bhp->bhnp", B_t, x_t)
        y = jnp.einsum("bn,bhnp->bhp", C_t, s)
        return s, y

    xs = (jnp.moveaxis(xdt, 1, 0), jnp.moveaxis(dA, 1, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
    s_final, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1), s_final
