"""Pallas flash attention (chunked-prefill path), TPU-native blocking.

Design (TPU, not a CUDA port): the grid streams KV tiles through VMEM while
a (block_q × head_dim) query tile and the online-softmax running statistics
(m, l, acc) live in VMEM scratch across the KV-block grid dimension — TPU
grids execute sequentially over the trailing axis, which is what makes the
running accumulation valid.  Tile sizes default to 128 (MXU-aligned: the
q-tile × kv-tile score matmul and the probs × V matmul both hit the 128×128
systolic array).  GQA is handled in the index map (query head → KV head);
sliding windows and causality by whole-tile skips first, intra-tile iota
masks second.

VMEM footprint per grid step ≈ (block_q + 2·block_k)·D·2B tiles +
block_q·(block_k + D + 2)·4B scratch ≈ 230 KiB at the 128/128/D=128
defaults — comfortably inside ~16 MiB v5e VMEM with double buffering.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,            # (1, bq, 1, D), (1, bk, 1, D), (1, bk, 1, D)
    o_ref,                          # (1, bq, 1, D)
    m_scr, l_scr, acc_scr,          # (bq, 1), (bq, 1), (bq, D) fp32 VMEM
    *,
    softmax_scale: float,
    block_q: int,
    block_k: int,
    seq_q: int,
    seq_k: int,
    causal: bool,
    window: Optional[int],
):
    it = pl.program_id(2)           # query block index
    ik = pl.program_id(3)           # kv block index
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Whole-tile skips.  Queries are the last ``seq_q`` positions of the
    # ``seq_k``-long KV stream (chunked prefill), so absolute query position
    # = row + (seq_k - seq_q).
    offset = seq_k - seq_q
    q_lo = it * block_q + offset
    q_hi = q_lo + block_q - 1
    k_lo = ik * block_k

    run = k_lo < seq_k
    if causal:
        run = jnp.logical_and(run, k_lo <= q_hi)
        if window is not None:
            run = jnp.logical_and(run, k_lo + block_k > q_lo - window + 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * softmax_scale
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (bq, bk)

        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kv_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kv_pos < seq_k
        if causal:
            mask &= kv_pos <= q_pos
            if window is not None:
                mask &= q_pos - kv_pos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)                    # fully-masked rows
        o_ref[0, :, 0, :] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softmax_scale",
                     "block_q", "block_k", "interpret"),
)
def flash_attention(
    q, k, v, *,
    causal: bool = True,
    window: Optional[int] = None,
    softmax_scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
):
    """q: (B, T, Hq, D); k, v: (B, S, Hkv, D) -> (B, T, Hq, D)."""
    B, T, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(D)
    block_q = min(block_q, T)
    block_k = min(block_k, S)

    Tp = -(-T // block_q) * block_q
    Sp = -(-S // block_k) * block_k
    if Tp != T:
        q = jnp.pad(q, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    if Sp != S:
        k = jnp.pad(k, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))

    grid = (B, Hq, Tp // block_q, Sp // block_k)
    kernel = functools.partial(
        _flash_kernel,
        softmax_scale=scale, block_q=block_q, block_k=block_k,
        seq_q=T, seq_k=S, causal=causal, window=window,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, it, ik: (b, it, h, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, it, ik: (b, ik, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, it, ik: (b, ik, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, D), lambda b, h, it, ik: (b, it, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Tp, Hq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :T]
