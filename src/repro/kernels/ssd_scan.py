"""Pallas Mamba2 SSD (state-space duality) chunked scan, TPU-native.

The SSD algorithm splits the linear recurrence h_t = a_t·h_{t−1} + B_t·x̃_t
into (i) an intra-chunk quadratic term — an (Q×Q) masked-decay attention-like
matmul pair that maps straight onto the MXU — and (ii) an inter-chunk state
recurrence.  The GPU reference (Triton) parallelises chunks and then runs a
separate state-passing pass; on TPU we instead exploit the *sequential* grid:
grid = (B, H, T/Q), and the running state (N × P, fp32) lives in VMEM scratch
across the chunk dimension, so a single kernel launch performs both the
intra-chunk matmuls and the cross-chunk recurrence with zero HBM round-trips
for the state.  (This is the DESIGN.md "hardware adaptation" case: same math,
different parallelisation, chosen because TPU grids give us an in-VMEM carry
for free while Triton must spill chunk states to HBM.)

Inputs are pre-projected (the surrounding block does the dt softplus and
x·dt premultiply): xdt (B,T,H,P), dA (B,T,H) log-decays, Bm/Cm (B,T,N).
Chunk length Q should be a multiple of 8 (ideally 128 for MXU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    xdt_ref,                        # (1, Q, 1, P)
    dA_ref,                         # (1, Q, 1)
    B_ref, C_ref,                   # (1, Q, N)
    y_ref,                          # (1, Q, 1, P)
    state_out_ref,                  # (1, 1, N, P)  final state (last chunk wins)
    state_scr,                      # (N, P) fp32 running state
    *,
    chunk: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    xdt = xdt_ref[0, :, 0, :].astype(jnp.float32)        # (Q, P)
    dA = dA_ref[0, :, 0].astype(jnp.float32)             # (Q,)
    Bm = B_ref[0].astype(jnp.float32)                    # (Q, N)
    Cm = C_ref[0].astype(jnp.float32)                    # (Q, N)

    cum = jnp.cumsum(dA)                                 # inclusive (Q,)
    # L[i,j] = exp(cum_i − cum_j) for i ≥ j (decay applied over j+1..i).
    # Mask the exponent, not the result: upper-triangle deltas are positive
    # and would overflow exp to inf (matches the layers.py reference).
    mask = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
            >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    delta = jnp.where(mask, cum[:, None] - cum[None, :], -jnp.inf)
    Lmat = jnp.exp(delta)

    scores = jax.lax.dot_general(                         # C Bᵀ  (Q, Q)
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    y_intra = jax.lax.dot_general(                        # (scores∘L) · xdt
        scores * Lmat, xdt, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (Q, P)

    # inter-chunk: y_i += C_i · (decay_from_chunk_start_i × S_prev)
    decay_from_start = jnp.exp(cum)                       # (Q,)
    y_inter = jax.lax.dot_general(
        Cm, state_scr[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * decay_from_start[:, None]

    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: S = exp(cum_Q)·S_prev + Bᵀ·(decay_to_end ∘ xdt)
    decay_to_end = jnp.exp(cum[-1] - cum)                 # (Q,)
    s_local = jax.lax.dot_general(
        Bm, xdt * decay_to_end[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (N, P)
    state_scr[...] = state_scr[...] * jnp.exp(cum[-1]) + s_local
    state_out_ref[0, 0] = state_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(xdt, dA, Bm, Cm, *, chunk: int = 128, interpret: bool = False):
    """Chunked SSD scan.  xdt: (B,T,H,P) dt-premultiplied inputs;
    dA: (B,T,H) log decays; Bm/Cm: (B,T,N).
    Returns (y (B,T,H,P) fp32, final_state (B,H,N,P) fp32)."""
    B, T, H, P = xdt.shape
    N = Bm.shape[-1]
    chunk = min(chunk, T)
    assert T % chunk == 0, (T, chunk)
    grid = (B, H, T // chunk)

    y, state = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(xdt, dA, Bm, Cm)
    return y, state
