"""Pallas paged decode attention, TPU-native.

One query token per sequence attends over a paged KV pool.  TPU adaptation
of vLLM's PagedAttention CUDA kernel — rather than per-warp gather loops, we
exploit Pallas's *scalar-prefetch* grid: the block table lives in SMEM and
the BlockSpec ``index_map`` dereferences it, so the pipeline DMA engine
streams exactly the pages each sequence owns from HBM into VMEM (the gather
happens in the prefetch stage, not in compute).  Grid =
(batch, kv_head, pages_per_seq); the online-softmax state for the G grouped
query heads rides in VMEM scratch across the page dimension.  Pages past a
sequence's ``context_len`` are skipped with ``pl.when`` — the DMA still
fetches the (arbitrary) page the table points at, so callers should point
unused slots at a valid page id (0 is fine).

Layout choice: K/V pool is (num_pages, page_size, Hkv, D) with page_size a
multiple of 8 so each (page_size, D) tile is rank-2 MXU/VPU friendly.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(
    # scalar prefetch:
    block_tables_ref,               # (B, pages_per_seq) int32, SMEM
    context_lens_ref,               # (B,) int32, SMEM
    # blocks:
    q_ref,                          # (1, 1, G, D)
    k_ref, v_ref,                   # (1, page_size, 1, D)
    o_ref,                          # (1, 1, G, D)
    m_scr, l_scr, acc_scr,          # (G, 1), (G, 1), (G, D)
    *,
    softmax_scale: float,
    page_size: int,
):
    b = pl.program_id(0)
    ip = pl.program_id(2)
    np_ = pl.num_programs(2)

    @pl.when(ip == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    ctx = context_lens_ref[b]
    page_start = ip * page_size

    @pl.when(page_start < ctx)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * softmax_scale      # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)                # (P, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)                  # (G, P)
        pos = page_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < ctx, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, :, 0, :].astype(jnp.float32)                # (P, D)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ip == np_ - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("softmax_scale", "interpret"))
def paged_attention(
    q, k_pages, v_pages, block_tables, context_lens, *,
    softmax_scale: Optional[float] = None,
    interpret: bool = False,
):
    """Decode attention over a paged KV pool.

    q:            (B, Hq, D)
    k/v_pages:    (num_pages, page_size, Hkv, D)
    block_tables: (B, pages_per_seq) int32 (unused slots -> any valid page)
    context_lens: (B,) int32
    returns       (B, Hq, D)
    """
    B, Hq, D = q.shape
    num_pages, page_size, Hkv, _ = k_pages.shape
    pages_per_seq = block_tables.shape[1]
    assert Hq % Hkv == 0
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(D)

    qg = q.reshape(B, Hkv, G, D)
    grid = (B, Hkv, pages_per_seq)

    kernel = functools.partial(
        _paged_kernel, softmax_scale=scale, page_size=page_size)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, D), lambda b, h, ip, bt, cl: (b, h, 0, 0)),
                pl.BlockSpec((1, page_size, 1, D),
                             lambda b, h, ip, bt, cl: (bt[b, ip], 0, h, 0)),
                pl.BlockSpec((1, page_size, 1, D),
                             lambda b, h, ip, bt, cl: (bt[b, ip], 0, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, D),
                                   lambda b, h, ip, bt, cl: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(block_tables, context_lens, qg, k_pages, v_pages)
    return out.reshape(B, Hq, D)
