"""Dispatch layer: Pallas kernels on TPU, pure-jnp references elsewhere.

``repro.models.layers`` and the serving engine's real-mode runner call these;
on this CPU-only container the references execute (bit-identical semantics),
while on TPU the Pallas kernels take over.  ``force`` overrides for tests
("kernel" runs the Pallas body under interpret=True on CPU).
"""

from __future__ import annotations

from typing import Optional

import jax

from . import ref
from .flash_attention import flash_attention as _flash_kernel
from .paged_attention import paged_attention as _paged_kernel
from .ssd_scan import ssd_scan as _ssd_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal=True, window=None,
                    softmax_scale=None, force: Optional[str] = None):
    use_kernel = force == "kernel" or (force is None and _on_tpu())
    if use_kernel:
        return _flash_kernel(
            q, k, v, causal=causal, window=window,
            softmax_scale=softmax_scale, interpret=not _on_tpu())
    return ref.flash_attention_ref(
        q, k, v, causal=causal, window=window, softmax_scale=softmax_scale)


def paged_attention(q, k_pages, v_pages, block_tables, context_lens, *,
                    softmax_scale=None, force: Optional[str] = None):
    use_kernel = force == "kernel" or (force is None and _on_tpu())
    if use_kernel:
        return _paged_kernel(
            q, k_pages, v_pages, block_tables, context_lens,
            softmax_scale=softmax_scale, interpret=not _on_tpu())
    return ref.paged_attention_ref(
        q, k_pages, v_pages, block_tables, context_lens,
        softmax_scale=softmax_scale)


def ssd_scan(xdt, dA, Bm, Cm, *, chunk: int = 128, force: Optional[str] = None):
    use_kernel = force == "kernel" or (force is None and _on_tpu())
    if use_kernel:
        return _ssd_kernel(xdt, dA, Bm, Cm, chunk=chunk, interpret=not _on_tpu())
    return ref.ssd_scan_ref(xdt, dA, Bm, Cm)
