"""Open-loop workload synthesis: request streams for fidelity benchmarks.

ShareGPT-like length marginals (lognormal prompt, lognormal output — the
shapes reported by Vidur/Splitwise trace studies) with a pluggable arrival
process (Poisson by default; see :mod:`repro.workload.arrival` for bursty /
on-off / diurnal traffic), plus deterministic trace replay and a
prefix-sharing workload (same system prompt across requests) for exercising
the radix cache.  Seeded and fully deterministic so real/sleep/emulate runs
see byte-identical request streams.

Closed-loop (multi-turn session) synthesis lives in
:mod:`repro.workload.session`.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

from repro.serving.request import Request

from .arrival import ArrivalProcess, make_arrival

__all__ = ["WorkloadConfig", "synthesize", "replay_trace",
           "lognormal_lengths"]


@dataclass(frozen=True)
class WorkloadConfig:
    num_requests: int = 100
    qps: float = 2.0                      # mean arrival rate
    arrival: str = "poisson"              # arrival-process registry name
    arrival_kwargs: Optional[dict] = None  # e.g. {"cv2": 8.0} for gamma
    prompt_len_mean: float = 220.0        # ShareGPT-ish
    prompt_len_sigma: float = 0.6         # lognormal sigma
    output_len_mean: float = 180.0
    output_len_sigma: float = 0.6
    max_prompt_len: int = 2048
    max_output_len: int = 1024
    min_prompt_len: int = 4
    min_output_len: int = 2
    vocab_size: int = 32000
    shared_prefix_len: int = 0            # >0: common system prompt
    seed: int = 0


def lognormal_lengths(rng: np.random.Generator, n: int, mean: float,
                      sigma: float, lo: int, hi: int) -> np.ndarray:
    mu = np.log(mean) - sigma**2 / 2
    lens = rng.lognormal(mu, sigma, size=n)
    return np.clip(lens.astype(int), lo, hi)


def synthesize(cfg: WorkloadConfig,
               arrival: Optional[ArrivalProcess] = None) -> List[Request]:
    """Generate ``cfg.num_requests`` open-loop requests.

    ``arrival`` overrides the config's registry lookup with a pre-built
    process object.  The draw order (arrival gaps, prompt lengths, output
    lengths, shared prefix, bodies) is frozen: for the default Poisson
    process every non-arrival draw is byte-identical to the historical
    single-process implementation (regression-pinned in
    tests/test_workload.py).
    """
    rng = np.random.default_rng(cfg.seed)
    n = cfg.num_requests

    proc = arrival or make_arrival(cfg.arrival, cfg.qps,
                                   **(cfg.arrival_kwargs or {}))
    arrivals = proc.sample(n, rng)

    prompt_lens = lognormal_lengths(rng, n, cfg.prompt_len_mean,
                                    cfg.prompt_len_sigma,
                                    cfg.min_prompt_len, cfg.max_prompt_len)
    output_lens = lognormal_lengths(rng, n, cfg.output_len_mean,
                                    cfg.output_len_sigma,
                                    cfg.min_output_len, cfg.max_output_len)

    shared = (rng.integers(1, cfg.vocab_size, size=cfg.shared_prefix_len)
              .tolist() if cfg.shared_prefix_len else [])

    reqs = []
    for i in range(n):
        body_len = max(int(prompt_lens[i]) - len(shared), 1)
        body = rng.integers(1, cfg.vocab_size, size=body_len).tolist()
        reqs.append(Request(
            prompt_tokens=shared + body,
            max_new_tokens=int(output_lens[i]),
            arrival_time=float(arrivals[i]),
        ))
    return reqs


def replay_trace(arrivals: Sequence[float], prompt_lens: Sequence[int],
                 output_lens: Sequence[int], *, vocab_size: int = 32000,
                 seed: int = 0) -> List[Request]:
    rng = np.random.default_rng(seed)
    return [
        Request(
            prompt_tokens=rng.integers(1, vocab_size, size=int(p)).tolist(),
            max_new_tokens=int(o),
            arrival_time=float(a),
        )
        for a, p, o in zip(arrivals, prompt_lens, output_lens)
    ]
