"""Pluggable arrival processes: when requests (or sessions) hit the cluster.

The paper's sweep story needs more than a single Poisson knob: real serving
traffic is bursty (overdispersed inter-arrivals), spiky (on/off phases from
upstream batch jobs), and diurnal (rate follows a daily curve).  Each process
here turns ``(n, rng)`` into a sorted array of arrival times with the first
request at ``t=0`` — the stream is *shifted*, never clipped, so the generated
inter-arrival gaps all survive (clobbering the first gap biases effective QPS
for small n; see tests/test_workload.py for the regression).

All processes are seeded through the caller's ``numpy`` Generator, so request
streams stay byte-identical across real/sleep/emulate/DES runs.

Invariants every process guarantees (property-tested in
tests/test_workload.py):

* ``sample(n, rng)`` returns exactly ``n`` non-decreasing times;
* renewal processes (poisson/gamma/onoff) place the first arrival at t=0 by
  *shifting*; trace replay keeps absolute phase instead (see
  :class:`RateTraceArrivals`);
* ``mean_rate()`` equals the configured long-run rate regardless of the
  burstiness knobs, so a burstiness sweep holds offered load constant;
* ``iter_times(rng)`` is the streaming form: an endless generator whose
  first ``n`` values are byte-identical to ``sample(n, rng)`` for every
  ``n`` — random draws happen in bounded chunks, so a million-arrival
  stream never materializes an array of a million gaps.

>>> import numpy as np
>>> from itertools import islice
>>> p = PoissonArrivals(qps=2.0)
>>> lazy = list(islice(p.iter_times(np.random.default_rng(0)), 5))
>>> lazy == p.sample(5, np.random.default_rng(0)).tolist()
True

>>> import numpy as np
>>> times = PoissonArrivals(qps=2.0).sample(5, np.random.default_rng(0))
>>> len(times), float(times[0]), bool(np.all(np.diff(times) >= 0))
(5, 0.0, True)
>>> GammaArrivals(qps=2.0, cv2=8.0).mean_rate()   # burstiness != load
2.0
>>> make_arrival("onoff", 4.0, period_s=5.0, duty=0.5).name
'onoff'
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "ArrivalProcess",
    "UniformArrivals",
    "PoissonArrivals",
    "GammaArrivals",
    "OnOffArrivals",
    "RateTraceArrivals",
    "ARRIVAL_PROCESSES",
    "make_arrival",
]


def _shift_to_zero(times: np.ndarray) -> np.ndarray:
    """First arrival at t=0 by shifting the whole stream (gap-preserving)."""
    if times.size == 0:
        return times
    return times - times[0]


class ArrivalProcess:
    """Base: ``sample(n, rng)`` returns n sorted arrival times, first at 0."""

    name = "?"

    def sample(self, n: int, rng: np.random.Generator) -> np.ndarray:
        raise NotImplementedError

    def iter_times(self, rng: np.random.Generator, chunk: int = 256):
        """Endless generator of arrival times, byte-identical to ``sample``.

        Draws from ``rng`` in ``chunk``-sized batches (numpy Generators fill
        arrays sequentially, so chunked draws reproduce one big draw), so
        look-ahead memory is O(chunk) no matter how far the stream runs.
        """
        raise NotImplementedError

    def mean_rate(self) -> float:
        """Long-run average arrivals/second (used by sizing heuristics)."""
        raise NotImplementedError


class UniformArrivals(ArrivalProcess):
    """Deterministically spaced arrivals: request ``i`` at ``i / qps``.

    No randomness at all — the process draws nothing from ``rng``.  This is
    the arrival shape backend-parity scenarios use: every request lands on an
    idle replica with headroom, so service starts continuously and a few ms
    of cross-backend wall-rate absorption cannot flip a step-boundary
    admission (see ``benchmarks/fig_distributed.py``).

    >>> import numpy as np
    >>> UniformArrivals(qps=4.0).sample(3, np.random.default_rng(0)).tolist()
    [0.0, 0.25, 0.5]
    """

    name = "uniform"

    def __init__(self, qps: float):
        assert qps > 0
        self.qps = qps

    def sample(self, n: int, rng: np.random.Generator) -> np.ndarray:
        return np.arange(n, dtype=np.float64) / self.qps

    def iter_times(self, rng: np.random.Generator, chunk: int = 256):
        i = 0
        while True:
            yield float(np.float64(i) / self.qps)
            i += 1

    def mean_rate(self) -> float:
        return self.qps


class PoissonArrivals(ArrivalProcess):
    """Memoryless baseline: exponential gaps at ``qps``."""

    name = "poisson"

    def __init__(self, qps: float):
        assert qps > 0
        self.qps = qps

    def sample(self, n: int, rng: np.random.Generator) -> np.ndarray:
        gaps = rng.exponential(1.0 / self.qps, size=n)
        return _shift_to_zero(np.cumsum(gaps))

    def iter_times(self, rng: np.random.Generator, chunk: int = 256):
        t = 0.0
        first = None
        while True:
            for g in rng.exponential(1.0 / self.qps, size=chunk):
                t += g
                if first is None:
                    first = t
                yield t - first

    def mean_rate(self) -> float:
        return self.qps


class GammaArrivals(ArrivalProcess):
    """Bursty renewal process: gamma gaps with squared coefficient of
    variation ``cv2`` (cv2=1 degenerates to Poisson; cv2≫1 clusters arrivals
    into bursts separated by long lulls — the overdispersion measured in
    production LLM traces).  Mean rate stays ``qps`` regardless of cv2, so a
    burstiness sweep holds offered load constant."""

    name = "gamma"

    def __init__(self, qps: float, cv2: float = 4.0):
        assert qps > 0 and cv2 > 0
        self.qps = qps
        self.cv2 = cv2

    def sample(self, n: int, rng: np.random.Generator) -> np.ndarray:
        shape = 1.0 / self.cv2
        scale = self.cv2 / self.qps          # shape*scale = 1/qps
        gaps = rng.gamma(shape, scale, size=n)
        return _shift_to_zero(np.cumsum(gaps))

    def iter_times(self, rng: np.random.Generator, chunk: int = 256):
        shape = 1.0 / self.cv2
        scale = self.cv2 / self.qps
        t = 0.0
        first = None
        while True:
            for g in rng.gamma(shape, scale, size=chunk):
                t += g
                if first is None:
                    first = t
                yield t - first

    def mean_rate(self) -> float:
        return self.qps


class OnOffArrivals(ArrivalProcess):
    """Square-wave spikes: Poisson bursts at ``qps/duty`` during the ON
    fraction of each period, silence otherwise (average rate stays ``qps``).
    Models upstream batch jobs / retry storms hammering the cluster in
    phases."""

    name = "onoff"

    def __init__(self, qps: float, period_s: float = 10.0, duty: float = 0.25):
        assert qps > 0 and period_s > 0 and 0 < duty <= 1
        self.qps = qps
        self.period_s = period_s
        self.duty = duty

    def sample(self, n: int, rng: np.random.Generator) -> np.ndarray:
        on_len = self.period_s * self.duty
        off_len = self.period_s - on_len
        gaps = rng.exponential(self.duty / self.qps, size=n)  # ON-phase rate
        times = np.empty(n, dtype=np.float64)
        t = 0.0                              # position within ON time only
        for i, g in enumerate(gaps):
            t += g
            # map accumulated ON-time onto the wall: insert an OFF gap at
            # every period boundary crossed
            periods = int(t // on_len)
            times[i] = t + periods * off_len
        return _shift_to_zero(times)

    def iter_times(self, rng: np.random.Generator, chunk: int = 256):
        on_len = self.period_s * self.duty
        off_len = self.period_s - on_len
        t = 0.0
        first = None
        while True:
            for g in rng.exponential(self.duty / self.qps, size=chunk):
                t += g
                periods = int(t // on_len)
                cur = t + periods * off_len
                if first is None:
                    first = cur
                yield cur - first

    def mean_rate(self) -> float:
        return self.qps


class RateTraceArrivals(ArrivalProcess):
    """Piecewise-constant rate-trace replay (diurnal curves, recorded load).

    ``trace`` is a sequence of ``(duration_s, qps)`` segments, repeated
    cyclically for as long as needed.  Arrivals are drawn by time-rescaling:
    unit-exponential increments are mapped through the inverse cumulative
    rate, the standard inhomogeneous-Poisson construction.  ``scale_to_qps``
    rescales the whole trace so its long-run mean matches a target rate —
    handy for sweeping load while keeping the *shape* of the day.

    Unlike the renewal processes, trace replay is **not** shifted to t=0:
    arrival times keep their absolute phase against the trace (a quiet
    leading segment yields a late first arrival), because the whole point of
    replay is that load aligns with the recorded curve."""

    name = "trace"

    def __init__(self, trace: Sequence[Tuple[float, float]],
                 scale_to_qps: Optional[float] = None):
        assert trace, "rate trace needs at least one (duration, qps) segment"
        durs = np.asarray([d for d, _ in trace], dtype=np.float64)
        rates = np.asarray([r for _, r in trace], dtype=np.float64)
        assert (durs > 0).all() and (rates >= 0).all() and rates.sum() > 0
        if scale_to_qps is not None:
            mean = float((durs * rates).sum() / durs.sum())
            rates = rates * (scale_to_qps / mean)
        self.durations = durs
        self.rates = rates

    def sample(self, n: int, rng: np.random.Generator) -> np.ndarray:
        increments = rng.exponential(1.0, size=n)   # unit-rate Poisson
        targets = np.cumsum(increments)             # cumulative expected count
        times = np.empty(n, dtype=np.float64)
        seg, t0, mass = 0, 0.0, 0.0                 # mass = integral of rate
        nseg = len(self.durations)
        for i, target in enumerate(targets):
            while True:
                d, r = self.durations[seg % nseg], self.rates[seg % nseg]
                seg_mass = d * r
                if mass + seg_mass >= target and r > 0:
                    times[i] = t0 + (target - mass) / r
                    break
                mass += seg_mass
                t0 += d
                seg += 1
        return times                     # phase-aligned: no shift

    def iter_times(self, rng: np.random.Generator, chunk: int = 256):
        seg, t0, mass = 0, 0.0, 0.0
        target = 0.0
        nseg = len(self.durations)
        while True:
            for inc in rng.exponential(1.0, size=chunk):
                target += inc
                while True:
                    d = self.durations[seg % nseg]
                    r = self.rates[seg % nseg]
                    seg_mass = d * r
                    if mass + seg_mass >= target and r > 0:
                        yield float(t0 + (target - mass) / r)
                        break
                    mass += seg_mass
                    t0 += d
                    seg += 1

    def mean_rate(self) -> float:
        return float((self.durations * self.rates).sum()
                     / self.durations.sum())


ARRIVAL_PROCESSES = {
    cls.name: cls
    for cls in (UniformArrivals, PoissonArrivals, GammaArrivals,
                OnOffArrivals, RateTraceArrivals)
}


def make_arrival(name: str, qps: float, **kwargs) -> ArrivalProcess:
    try:
        cls = ARRIVAL_PROCESSES[name]
    except KeyError:
        raise ValueError(
            f"unknown arrival process {name!r}; "
            f"choose from {sorted(ARRIVAL_PROCESSES)}") from None
    if cls is RateTraceArrivals:
        # the trace fixes absolute rates; qps becomes the rescale target
        kwargs.setdefault("scale_to_qps", qps)
        return cls(**kwargs)
    return cls(qps, **kwargs)
