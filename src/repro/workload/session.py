"""Closed-loop session workloads: multi-turn conversations.

A *session* is a chat: turn ``k+1`` is released only after turn ``k``
completes plus a sampled think time — a closed feedback loop, unlike the
open-loop streams from :mod:`repro.workload.synth`.  Each follow-up prompt
*carries the prior turn's tokens* (previous prompt + previous output + the
new user message), so the growing per-session context exercises
``prefix_affinity`` routing and the radix cache with real reuse instead of a
synthetic shared prefix.

Determinism: every token and length is pre-sampled at construction.  Emulated
outputs are always ``DUMMY_TOKEN`` (0) — the control plane never consumes
token *values* (paper §3.3) — so follow-up prompts are precomputable as
``prev_prompt + [0]*prev_output_len + next_body``.  Only the *release times*
of turns ≥ 1 are runtime-dependent (completion + think time), which is
exactly the coupling the closed loop exists to model.  The same
:class:`SessionWorkload` object drives the emulator
(:class:`~repro.serving.benchmark.BenchmarkRunner` re-injects follow-ups via
completion callbacks) and the DES baseline
(:class:`~repro.des.simulator.DiscreteEventSimulator`), so emulator-vs-DES
parity extends to closed-loop traffic.

Real-mode caveat: under ``mode="real"`` generated tokens are actual argmax
outputs, not zeros, so precomputed follow-up prompts would diverge from what
a real chat client would send.  Session workloads target the emulated/DES
modes (the paper's sweep regime).

Invariants (the closed-loop release rule): turn ``k+1``'s arrival is
``finish(k) + think``, never earlier; a session's turn count never exceeds
``max_turns`` and its context never exceeds ``max_context_len`` (sessions
end early rather than overflow); ``initial_requests``/``follow_up`` build
*fresh* Request objects per call so one workload object can drive several
runs with byte-identical token streams.

>>> sw = SessionWorkload(SessionConfig(num_sessions=4, qps=2.0,
...                                    turns_mean=2.0, max_turns=3, seed=0))
>>> 0 < sw.num_sessions <= 4
True
>>> sw.total_requests == sum(s.num_turns for s in sw.sessions)
True
>>> first = sw.initial_requests()
>>> all(r.turn_index == 0 for r in first)
True
>>> follow = sw.follow_up(type("Done", (), {
...     "session_id": first[0].session_id, "turn_index": 0,
...     "finish_time": 7.5})())
>>> follow is None or follow.arrival_time >= 7.5
True
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.serving.request import Request

from .arrival import ArrivalProcess, make_arrival
from .synth import lognormal_lengths

__all__ = ["SessionConfig", "TurnSpec", "Session", "SessionWorkload"]

_DUMMY = 0   # emulated output token value (model_runner.DUMMY_TOKEN)


@dataclass(frozen=True)
class SessionConfig:
    num_sessions: int = 16
    qps: float = 1.0                      # session (first-turn) arrival rate
    arrival: str = "poisson"
    arrival_kwargs: Optional[dict] = None
    turns_mean: float = 3.0               # geometric turns/session (mean)
    max_turns: int = 8
    think_time_mean: float = 2.0          # exponential think time (seconds)
    prompt_len_mean: float = 120.0        # first user message (lognormal)
    prompt_len_sigma: float = 0.6
    followup_len_mean: float = 40.0       # later user messages (lognormal)
    followup_len_sigma: float = 0.6
    output_len_mean: float = 60.0
    output_len_sigma: float = 0.6
    min_prompt_len: int = 4
    min_output_len: int = 2
    max_output_len: int = 512
    max_context_len: int = 2048           # session ends before exceeding this
    vocab_size: int = 32000
    shared_prefix_len: int = 0            # cross-session system prompt
    seed: int = 0


@dataclass
class TurnSpec:
    """One pre-sampled conversation turn (tokens fully materialised)."""
    prompt_tokens: List[int]              # full context incl. prior turns
    max_new_tokens: int
    think_time: float                     # delay after previous turn's finish


@dataclass
class Session:
    session_id: int
    arrival_time: float                   # release of turn 0
    turns: List[TurnSpec] = field(default_factory=list)

    @property
    def num_turns(self) -> int:
        return len(self.turns)


class SessionWorkload:
    """Pre-sampled session set + the closed-loop release rule.

    The object is stateless across runs (pure specs): ``initial_requests``
    and ``follow_up`` build fresh :class:`Request` objects every call, so one
    workload can drive an emulator run and a DES run with byte-identical
    token streams.
    """

    def __init__(self, cfg: SessionConfig,
                 arrival: Optional[ArrivalProcess] = None):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        proc = arrival or make_arrival(cfg.arrival, cfg.qps,
                                       **(cfg.arrival_kwargs or {}))
        arrivals = proc.sample(cfg.num_sessions, rng)

        shared = (rng.integers(1, cfg.vocab_size, size=cfg.shared_prefix_len)
                  .tolist() if cfg.shared_prefix_len else [])

        self.sessions: List[Session] = []
        for sid in range(cfg.num_sessions):
            n_turns = int(min(cfg.max_turns,
                              rng.geometric(min(1.0, 1.0 / cfg.turns_mean))))
            first_len = int(lognormal_lengths(
                rng, 1, cfg.prompt_len_mean, cfg.prompt_len_sigma,
                cfg.min_prompt_len, cfg.max_context_len)[0])
            follow_lens = lognormal_lengths(
                rng, n_turns, cfg.followup_len_mean, cfg.followup_len_sigma,
                1, cfg.max_context_len)
            out_lens = lognormal_lengths(
                rng, n_turns, cfg.output_len_mean, cfg.output_len_sigma,
                cfg.min_output_len, cfg.max_output_len)
            thinks = rng.exponential(cfg.think_time_mean, size=n_turns)

            sess = Session(session_id=sid,
                           arrival_time=float(arrivals[sid]))
            context: List[int] = list(shared)
            for t in range(n_turns):
                body_len = (max(first_len - len(shared), 1) if t == 0
                            else int(follow_lens[t]))
                if len(context) + body_len > cfg.max_context_len:
                    break                 # context full: session ends early
                body = rng.integers(1, cfg.vocab_size,
                                    size=body_len).tolist()
                prompt = context + body
                out = int(out_lens[t])
                sess.turns.append(TurnSpec(
                    prompt_tokens=prompt,
                    max_new_tokens=out,
                    think_time=0.0 if t == 0 else float(thinks[t]),
                ))
                context = prompt + [_DUMMY] * out
            if sess.turns:
                self.sessions.append(sess)
        # Precomputed id -> list-index map (sessions whose first turn didn't
        # fit max_context_len are dropped, so ids aren't dense).  Built
        # eagerly because follow_up() is called from completion contexts
        # that may run concurrently — engine step threads (thread backend)
        # or per-replica completion-frame reader threads (process backend)
        # — and a lazily-built dict would race its own construction.
        self._id_index = {s.session_id: i
                          for i, s in enumerate(self.sessions)}

    # ---------------------------------------------------------- accounting --
    @property
    def num_sessions(self) -> int:
        return len(self.sessions)

    @property
    def total_requests(self) -> int:
        return sum(s.num_turns for s in self.sessions)

    def session_turns(self, session_id: int) -> int:
        """Turn count of one session (streaming metrics use this to drop
        per-session state the moment its last turn completes)."""
        return self.sessions[self._index_of(session_id)].num_turns

    # ------------------------------------------------------------- release --
    def _request(self, sess: Session, turn: int, arrival: float) -> Request:
        spec = sess.turns[turn]
        return Request(
            prompt_tokens=list(spec.prompt_tokens),
            max_new_tokens=spec.max_new_tokens,
            arrival_time=arrival,
            session_id=sess.session_id,
            turn_index=turn,
        )

    def initial_requests(self) -> List[Request]:
        """Turn 0 of every session (open-loop arrivals); fresh objects."""
        return [self._request(s, 0, s.arrival_time) for s in self.sessions]

    def follow_up(self, finished) -> Optional[Request]:
        """The closed-loop rule: given a *finished* turn (anything exposing
        ``session_id`` / ``turn_index`` / ``finish_time`` — an engine
        :class:`Request`, the unpickled copy a process-mode replica ships
        back in its completion frame, or a DES ``SimRequest``), build the
        next turn with ``arrival = finish + think`` — or None if the
        conversation is over.  Thread-safe (pure reads over pre-sampled
        specs): completion contexts on all backends may call it
        concurrently."""
        sid = getattr(finished, "session_id", None)
        if sid is None:
            return None
        sess = self.sessions[self._index_of(sid)]
        turn = finished.turn_index + 1
        if turn >= sess.num_turns:
            return None
        assert finished.finish_time is not None, "follow_up needs finish_time"
        spec = sess.turns[turn]
        return self._request(sess, turn,
                             finished.finish_time + spec.think_time)

    def _index_of(self, session_id: int) -> int:
        return self._id_index[session_id]
