"""Lazy workloads: million-request streams with bounded look-ahead.

The eager synthesizers (:func:`repro.workload.synth.synthesize`,
:class:`repro.workload.session.SessionWorkload`) materialize every request
up front — fine for figure-sized runs, impossible for the ROADMAP's
"millions of users" scale where the request list alone would dwarf the
emulator's own state.  This module provides the streaming forms:

- :class:`StreamingWorkload` — open-loop: a re-iterable request *stream*.
  Each iteration replays the identical stream (fresh seeded generators per
  ``__iter__``), so the emulator and the DES can consume the same workload
  object and still see byte-identical requests — the parity bar survives
  streaming.  Arrival times come from
  :meth:`~repro.workload.arrival.ArrivalProcess.iter_times`; lengths and
  token bodies are drawn from **independent per-component substreams**
  (``default_rng([seed, ns])``), which makes the stream chunk-size
  invariant.  Look-ahead memory is O(chunk).
- :class:`StreamingSessionWorkload` — closed-loop: same release rule as
  :class:`~repro.workload.session.SessionWorkload` (``follow_up`` on
  completion), but turns are materialized **per live session** from
  per-session substreams (``default_rng([seed, ns, sid])``) and dropped when
  the session's last turn completes.  A cheap shape-only pre-pass (lengths,
  no token bodies) fixes ``total_requests`` exactly without ever holding
  token arrays, so memory tracks *concurrently open* sessions.
- :func:`replay_trace_stream` — streaming trace replay over arbitrary
  (possibly lazy) arrival/length iterables.

Streams produced here are *new* deterministic streams — they do not
reproduce the eager synthesizers' draw order (which is regression-pinned and
unchanged).  What is guaranteed: same config ⇒ same stream, every time, on
every backend.
"""

from __future__ import annotations

import threading
from array import array
from typing import Dict, Iterable, Iterator, List, Optional

import numpy as np

from repro.serving.request import Request

from .arrival import ArrivalProcess, make_arrival
from .session import _DUMMY, SessionConfig, TurnSpec
from .synth import WorkloadConfig, lognormal_lengths

__all__ = ["StreamingWorkload", "StreamingSessionWorkload",
           "replay_trace_stream"]

# Substream namespaces: seeding with a sequence ([seed, ns] / [seed, ns, sid])
# gives independent deterministic generators per component, so interleaving
# (and chunk size) cannot shuffle draws between components.
_NS_ARRIVAL = 1
_NS_PROMPT_LEN = 2
_NS_OUTPUT_LEN = 3
_NS_BODY = 4
_NS_SHARED = 5
_NS_SHAPE = 6


def _shared_prefix(seed: int, vocab_size: int, length: int) -> List[int]:
    if not length:
        return []
    rng = np.random.default_rng([seed, _NS_SHARED])
    return rng.integers(1, vocab_size, size=length).tolist()


class StreamingWorkload:
    """Open-loop lazy request stream (the ``synthesize`` counterpart).

    Iterating yields ``cfg.num_requests`` arrival-sorted requests without
    ever holding more than one draw chunk; ``expected`` carries the declared
    request count so :class:`~repro.serving.benchmark.BenchmarkRunner` and
    the DES never fall back to ``len(requests)``.

    >>> sw = StreamingWorkload(WorkloadConfig(num_requests=5, seed=3))
    >>> sw.expected
    5
    >>> a = [r.prompt_tokens for r in sw]
    >>> b = [r.prompt_tokens for r in sw]      # re-iterable, byte-identical
    >>> a == b
    True
    """

    def __init__(self, cfg: WorkloadConfig,
                 arrival: Optional[ArrivalProcess] = None, chunk: int = 256):
        assert chunk > 0
        self.cfg = cfg
        self.chunk = int(chunk)
        self._proc = arrival or make_arrival(cfg.arrival, cfg.qps,
                                             **(cfg.arrival_kwargs or {}))
        self.expected = cfg.num_requests

    @property
    def total_requests(self) -> int:
        return self.expected

    def __iter__(self) -> Iterator[Request]:
        cfg = self.cfg
        shared = _shared_prefix(cfg.seed, cfg.vocab_size,
                                cfg.shared_prefix_len)
        times = self._proc.iter_times(
            np.random.default_rng([cfg.seed, _NS_ARRIVAL]), chunk=self.chunk)
        rng_plen = np.random.default_rng([cfg.seed, _NS_PROMPT_LEN])
        rng_olen = np.random.default_rng([cfg.seed, _NS_OUTPUT_LEN])
        rng_body = np.random.default_rng([cfg.seed, _NS_BODY])
        emitted = 0
        while emitted < cfg.num_requests:
            m = min(self.chunk, cfg.num_requests - emitted)
            plens = lognormal_lengths(rng_plen, m, cfg.prompt_len_mean,
                                      cfg.prompt_len_sigma,
                                      cfg.min_prompt_len, cfg.max_prompt_len)
            olens = lognormal_lengths(rng_olen, m, cfg.output_len_mean,
                                      cfg.output_len_sigma,
                                      cfg.min_output_len, cfg.max_output_len)
            for i in range(m):
                body_len = max(int(plens[i]) - len(shared), 1)
                body = rng_body.integers(1, cfg.vocab_size,
                                         size=body_len).tolist()
                yield Request(
                    prompt_tokens=shared + body,
                    max_new_tokens=int(olens[i]),
                    arrival_time=float(next(times)),
                )
            emitted += m

    def __len__(self) -> int:
        return self.expected


class StreamingSessionWorkload:
    """Closed-loop sessions with per-live-session materialization.

    Same observable contract as :class:`SessionWorkload` — an initial
    arrival-sorted stream of turn-0 requests plus the ``follow_up`` release
    rule — but token bodies exist only for sessions currently in flight.
    The shape pre-pass (turn counts, honoring the ``max_context_len``
    early-stop, without drawing a single token) runs once at construction:
    O(num_sessions) time, O(num_sessions) *ints* of memory (the turn-count
    table), never O(total tokens).

    Thread-safe: completion contexts on all backends may call ``follow_up``
    concurrently; the live-session cache is lock-protected.  Re-iterable:
    each ``initial_stream()`` replays the identical stream, so one object
    drives an emulator run and a DES run back to back.
    """

    def __init__(self, cfg: SessionConfig,
                 arrival: Optional[ArrivalProcess] = None, chunk: int = 256):
        self.cfg = cfg
        self.chunk = int(chunk)
        self._proc = arrival or make_arrival(cfg.arrival, cfg.qps,
                                             **(cfg.arrival_kwargs or {}))
        self._shared = _shared_prefix(cfg.seed, cfg.vocab_size,
                                      cfg.shared_prefix_len)
        self._lock = threading.Lock()
        self._live: Dict[int, List[TurnSpec]] = {}
        # shape-only pre-pass: exact turn counts, zero token draws
        counts = array("i")
        total = 0
        alive = 0
        for sid in range(cfg.num_sessions):
            n = len(self._shape(sid))
            counts.append(n)
            total += n
            alive += int(n > 0)
        self._turn_counts = counts
        self.total_requests = total
        self.num_sessions = alive
        self.expected = total

    # ------------------------------------------------------------- shapes --
    def _shape(self, sid: int):
        """(body_len, max_new_tokens, think_time) per surviving turn —
        the context-cap early-stop applied without materializing tokens."""
        cfg = self.cfg
        rng = np.random.default_rng([cfg.seed, _NS_SHAPE, sid])
        n_turns = int(min(cfg.max_turns,
                          rng.geometric(min(1.0, 1.0 / cfg.turns_mean))))
        first_len = int(lognormal_lengths(
            rng, 1, cfg.prompt_len_mean, cfg.prompt_len_sigma,
            cfg.min_prompt_len, cfg.max_context_len)[0])
        follow_lens = lognormal_lengths(
            rng, n_turns, cfg.followup_len_mean, cfg.followup_len_sigma,
            1, cfg.max_context_len)
        out_lens = lognormal_lengths(
            rng, n_turns, cfg.output_len_mean, cfg.output_len_sigma,
            cfg.min_output_len, cfg.max_output_len)
        thinks = rng.exponential(cfg.think_time_mean, size=n_turns)
        shape = []
        ctx = len(self._shared)
        for t in range(n_turns):
            body_len = (max(first_len - len(self._shared), 1) if t == 0
                        else int(follow_lens[t]))
            if ctx + body_len > cfg.max_context_len:
                break                     # context full: session ends early
            out = int(out_lens[t])
            shape.append((body_len, out,
                          0.0 if t == 0 else float(thinks[t])))
            ctx += body_len + out
        return shape

    def _materialize(self, sid: int) -> List[TurnSpec]:
        cfg = self.cfg
        rng = np.random.default_rng([cfg.seed, _NS_BODY, sid])
        context: List[int] = list(self._shared)
        specs: List[TurnSpec] = []
        for body_len, out, think in self._shape(sid):
            body = rng.integers(1, cfg.vocab_size, size=body_len).tolist()
            prompt = context + body
            specs.append(TurnSpec(prompt_tokens=prompt,
                                  max_new_tokens=out, think_time=think))
            context = prompt + [_DUMMY] * out
        return specs

    def session_turns(self, session_id: int) -> int:
        return self._turn_counts[session_id]

    # ------------------------------------------------------------- release --
    def _request(self, sid: int, turn: int, arrival: float) -> Request:
        with self._lock:
            specs = self._live.get(sid)
            if specs is None:
                specs = self._live[sid] = self._materialize(sid)
        spec = specs[turn]
        return Request(
            prompt_tokens=list(spec.prompt_tokens),
            max_new_tokens=spec.max_new_tokens,
            arrival_time=arrival,
            session_id=sid,
            turn_index=turn,
        )

    def initial_stream(self) -> Iterator[Request]:
        """Turn 0 of every session, arrival-sorted, lazily materialized."""
        cfg = self.cfg
        times = self._proc.iter_times(
            np.random.default_rng([cfg.seed, _NS_ARRIVAL]), chunk=self.chunk)
        for sid in range(cfg.num_sessions):
            t = next(times)               # every session consumes its slot
            if self._turn_counts[sid] == 0:
                continue                  # first turn never fit the context
            yield self._request(sid, 0, float(t))

    def follow_up(self, finished) -> Optional[Request]:
        """Closed-loop release rule (same contract as
        :meth:`SessionWorkload.follow_up`); additionally *evicts* the
        session's materialized turns once its last turn has finished."""
        sid = getattr(finished, "session_id", None)
        if sid is None:
            return None
        turn = finished.turn_index + 1
        if turn >= self._turn_counts[sid]:
            with self._lock:
                self._live.pop(sid, None)     # session over: free its tokens
            return None
        assert finished.finish_time is not None, "follow_up needs finish_time"
        with self._lock:
            specs = self._live.get(sid)
            if specs is None:
                specs = self._live[sid] = self._materialize(sid)
        think = specs[turn].think_time
        return self._request(sid, turn, finished.finish_time + think)

    @property
    def live_sessions(self) -> int:
        """Sessions currently holding materialized token arrays."""
        with self._lock:
            return len(self._live)


class replay_trace_stream:
    """Streaming trace replay: the lazy counterpart of
    :func:`repro.workload.synth.replay_trace`.

    Accepts arbitrary iterables (lists, generators, file readers) for the
    arrival/length columns and yields requests one at a time; token bodies
    are drawn per request from a seeded generator, so nothing is
    materialized beyond the request in flight.  Re-iterable only when the
    input columns are (pass lists/tuples, or re-create the object).

    ``expected`` is taken from ``len(arrivals)`` when the column is sized,
    else it must be passed explicitly — the runner refuses to guess.
    """

    def __init__(self, arrivals: Iterable[float],
                 prompt_lens: Iterable[int], output_lens: Iterable[int], *,
                 vocab_size: int = 32000, seed: int = 0,
                 expected: Optional[int] = None):
        self._arrivals = arrivals
        self._prompt_lens = prompt_lens
        self._output_lens = output_lens
        self.vocab_size = vocab_size
        self.seed = seed
        if expected is None and hasattr(arrivals, "__len__"):
            expected = len(arrivals)  # type: ignore[arg-type]
        self.expected = expected

    @property
    def total_requests(self) -> Optional[int]:
        return self.expected

    def __iter__(self) -> Iterator[Request]:
        rng = np.random.default_rng([self.seed, _NS_BODY])
        for a, p, o in zip(self._arrivals, self._prompt_lens,
                           self._output_lens):
            yield Request(
                prompt_tokens=rng.integers(1, self.vocab_size,
                                           size=int(p)).tolist(),
                max_new_tokens=int(o),
                arrival_time=float(a),
            )
