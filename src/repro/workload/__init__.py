"""Workload subsystem: open-loop streams, arrival processes, chat sessions.

- :mod:`repro.workload.arrival` — pluggable arrival processes (Poisson,
  gamma/bursty, on/off spikes, diurnal rate-trace replay).
- :mod:`repro.workload.synth` — open-loop request synthesis + trace replay
  (the former ``repro.serving.workload``; the compat shim is gone).
- :mod:`repro.workload.session` — closed-loop multi-turn sessions whose
  follow-ups carry the prior turn's tokens (drives the emulator *and* the
  DES through one object).
- :mod:`repro.workload.streaming` — lazy million-request forms of the
  above: bounded look-ahead streams for the flat-memory scale path.
"""

from .arrival import (ARRIVAL_PROCESSES, ArrivalProcess, GammaArrivals,
                      OnOffArrivals, PoissonArrivals, RateTraceArrivals,
                      UniformArrivals, make_arrival)
from .session import Session, SessionConfig, SessionWorkload, TurnSpec
from .streaming import (StreamingSessionWorkload, StreamingWorkload,
                        replay_trace_stream)
from .synth import WorkloadConfig, replay_trace, synthesize

__all__ = [
    "ARRIVAL_PROCESSES",
    "ArrivalProcess",
    "UniformArrivals",
    "PoissonArrivals",
    "GammaArrivals",
    "OnOffArrivals",
    "RateTraceArrivals",
    "make_arrival",
    "WorkloadConfig",
    "synthesize",
    "replay_trace",
    "SessionConfig",
    "SessionWorkload",
    "Session",
    "TurnSpec",
    "StreamingWorkload",
    "StreamingSessionWorkload",
    "replay_trace_stream",
]
