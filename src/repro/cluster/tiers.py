"""Hardware tiers for heterogeneous replica pools.

A *tier* is a hardware flavour a replica can run on — a chip name from
:mod:`repro.core.hardware` (``"h100"``, ``"a100"``, ``"l4"``, …) plus the
derived quantities the control plane needs to reason about mixed pools:

* ``cost_per_replica_s`` — $/replica-second (chip $/s × chips per replica),
  the unit :meth:`Cluster.replica_cost` and the benchmark cost metric accrue;
* ``throughput_factor`` — decode tokens/s on a canonical probe batch, used by
  tier-aware routing (``least_outstanding_tokens`` divides a replica's
  backlog by it, turning "fewest tokens" into "shortest drain time");
* ``projected_ttft_s`` — service-time estimate for a fresh request (one
  prefill step + one decode step), what the tier-selecting autoscaler checks
  against the TTFT SLO to pick the cheapest chip that can still answer fast
  enough.

All three are computed **from the tier's runtime predictor** — the same
object that sizes the emulator's virtual-time jumps and the DES baseline's
event durations — so the emulated cluster and the DES derive identical tier
arithmetic by construction (the §2.3 parity argument extended to
heterogeneous pools).  Probe maths are pure:

>>> from repro.core.predictor import StaticPredictor
>>> probe_throughput(StaticPredictor(0.01), batch=8)
800.0
>>> probe_ttft(StaticPredictor(0.01))
0.02

Invariant: a tier's :class:`TierSpec` is immutable and predictor-derived —
never edited per run — so any two components handed the same tier name and
predictors (Cluster, Autoscaler, DiscreteEventSimulator) agree on every
weight, cost, and feasibility decision.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Mapping, Optional, Sequence

from repro.core.hardware import get_chip
from repro.core.predictor import BatchSpec, RuntimePredictor, SeqSpec

__all__ = [
    "TierSpec",
    "probe_throughput",
    "probe_ttft",
    "tier_engine_cfg",
    "make_tier_spec",
    "make_tier_specs",
]

# Canonical probe shapes: a mid-size decode batch and a mid-size prompt.
# Arbitrary but *fixed* — every component must probe identically for the
# derived weights to agree across emulator and DES.
PROBE_DECODE_BATCH = 8
PROBE_CONTEXT = 256
PROBE_PROMPT = 256

# Fraction of (HBM − weights) given to the KV pool when sizing a tier's
# block count (the rest is activations / workspace).
KV_MEMORY_FRACTION = 0.9


@dataclass(frozen=True)
class TierSpec:
    """One hardware tier's control-plane summary (see module docstring)."""

    name: str                    # tier name as configured (alias allowed)
    chip: str                    # canonical chip name
    cost_per_replica_s: float    # $/replica-second (all chips of the replica)
    throughput_factor: float     # probe decode tokens/s (relative weight)
    projected_ttft_s: float      # prefill + first decode step on the probe


def probe_throughput(predictor: RuntimePredictor, *,
                     batch: int = PROBE_DECODE_BATCH,
                     context: int = PROBE_CONTEXT) -> float:
    """Decode tokens/s on the canonical probe batch (pure, deterministic).

    >>> from repro.core.predictor import StaticPredictor
    >>> probe_throughput(StaticPredictor(0.02), batch=4)
    200.0
    """
    spec = BatchSpec.make([SeqSpec(1, context)] * batch)
    step = predictor.predict_step(spec).total
    return batch / step


def probe_ttft(predictor: RuntimePredictor, *,
               prompt: int = PROBE_PROMPT) -> float:
    """Service-time TTFT estimate: one full prefill + one decode step.

    Queueing excluded on purpose: this is "how fast can this tier answer an
    unloaded request", the feasibility question tier selection asks.
    """
    prefill = predictor.predict_step(BatchSpec.make([SeqSpec(prompt, prompt)]))
    decode = predictor.predict_step(BatchSpec.make([SeqSpec(1, prompt + 1)]))
    return prefill.total + decode.total


def tier_engine_cfg(base, tier: str, model_cfg=None):
    """Clone an :class:`~repro.serving.scheduler.EngineConfig` onto a tier.

    Sets ``chip`` to the tier and, when ``model_cfg`` is given, re-derives
    the KV pool so capacity reflects the chip: the block count is capped at
    what fits in ``KV_MEMORY_FRACTION`` of the tier's HBM after weights
    (never *raised* above the base config — the base stays the configured
    ceiling, small chips shrink below it).  Raises if the model's weights
    alone exceed the tier's memory.
    """
    chip = get_chip(tier)
    cfg = replace(base, chip=tier)
    if model_cfg is None:
        return cfg
    n_dev = cfg.tp * cfg.pp
    weights = model_cfg.param_count() * model_cfg.dtype_bytes
    free = chip.hbm_capacity * n_dev - weights
    if free <= 0:
        raise ValueError(
            f"model weights ({weights / 1e9:.1f} GB) do not fit on tier "
            f"{tier!r} ({n_dev} × {chip.hbm_capacity / 1e9:.0f} GB)")
    budget = free * KV_MEMORY_FRACTION
    fit = int(budget // (cfg.block_size * model_cfg.kv_bytes_per_token()))
    return replace(cfg, num_blocks=max(1, min(base.num_blocks, fit)))


def make_tier_spec(tier: str, engine_cfg, *,
                   predictor: RuntimePredictor) -> TierSpec:
    """Build a tier's spec from its (tier-resolved) config and predictor."""
    chip = get_chip(tier)
    n_dev = engine_cfg.tp * engine_cfg.pp
    return TierSpec(
        name=tier,
        chip=chip.name,
        cost_per_replica_s=chip.cost_per_second * n_dev,
        throughput_factor=probe_throughput(predictor),
        projected_ttft_s=probe_ttft(predictor),
    )


def make_tier_specs(
    model_cfg,
    base_engine_cfg,
    tiers: Sequence[str],
    *,
    tier_predictors: Optional[Mapping[str, RuntimePredictor]] = None,
) -> Dict[str, TierSpec]:
    """Specs for a set of tiers, sharing one probe convention.

    ``tier_predictors`` overrides the per-tier predictor (benchmarks inject
    :class:`~repro.core.predictor.StaticPredictor` here); tiers without an
    entry get the default analytical predictor for their chip.  Build the
    dict **once** per experiment and hand the same mapping to
    :func:`~repro.cluster.cluster.build_cluster` and to
    :class:`~repro.des.simulator.DiscreteEventSimulator` so both sides share
    tier arithmetic exactly.
    """
    from repro.serving.stack import default_predictor

    out: Dict[str, TierSpec] = {}
    for tier in dict.fromkeys(tiers):         # de-dup, order-preserving
        cfg = tier_engine_cfg(base_engine_cfg, tier, model_cfg)
        pred = (tier_predictors or {}).get(tier) \
            or default_predictor(model_cfg, cfg)
        out[tier] = make_tier_spec(tier, cfg, predictor=pred)
    return out
