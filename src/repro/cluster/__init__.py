"""Multi-replica cluster emulation layer (data-parallel serving, PD pools).

Public surface::

    from repro.cluster import Cluster, build_cluster, make_router

See ``cluster.py`` for the replica/timeline architecture and ``router.py``
for the pluggable routing policies.
"""

from .cluster import Cluster, ClusterConfig, build_cluster
from .router import (LeastOutstandingTokensRouter, PDPoolRouter,
                     PrefixAffinityRouter, ReplicaView, RoundRobinRouter,
                     Router, ROUTER_POLICIES, make_router)

__all__ = [
    "Cluster",
    "ClusterConfig",
    "build_cluster",
    "Router",
    "ReplicaView",
    "RoundRobinRouter",
    "LeastOutstandingTokensRouter",
    "PrefixAffinityRouter",
    "PDPoolRouter",
    "ROUTER_POLICIES",
    "make_router",
]
