"""Multi-replica cluster emulation layer (data-parallel serving, PD pools,
elastic membership, heterogeneous tiers + SLO-driven autoscaling).

Public surface::

    from repro.cluster import Cluster, build_cluster, make_router
    from repro.cluster import Autoscaler, make_autoscaler_policy
    from repro.cluster import TierSpec, make_tier_specs

See ``cluster.py`` for the replica/timeline architecture, ``router.py`` for
the pluggable routing policies, ``autoscaler.py`` for the virtual-time
scaling control loop, and ``tiers.py`` for the hardware-tier arithmetic
behind heterogeneous pools.
"""

from .autoscaler import (AUTOSCALER_POLICIES, Autoscaler, AutoscalerConfig,
                         AutoscalerPolicy, QueueDepthPolicy, SchedulePolicy,
                         TTFTSLOPolicy, make_autoscaler_policy,
                         provision_delay)
from .cluster import Cluster, ClusterConfig, build_cluster
from .router import (CostNormalizedLoadRouter, LeastOutstandingTokensRouter,
                     PDPoolRouter, PrefixAffinityRouter, ReplicaView,
                     RoundRobinRouter, Router, ROUTER_POLICIES, make_router)
from .tiers import (TierSpec, make_tier_spec, make_tier_specs,
                    probe_throughput, probe_ttft, tier_engine_cfg)

__all__ = [
    "Cluster",
    "ClusterConfig",
    "build_cluster",
    "Router",
    "ReplicaView",
    "RoundRobinRouter",
    "LeastOutstandingTokensRouter",
    "CostNormalizedLoadRouter",
    "PrefixAffinityRouter",
    "PDPoolRouter",
    "ROUTER_POLICIES",
    "make_router",
    "TierSpec",
    "make_tier_spec",
    "make_tier_specs",
    "probe_throughput",
    "probe_ttft",
    "tier_engine_cfg",
    "provision_delay",
    "Autoscaler",
    "AutoscalerConfig",
    "AutoscalerPolicy",
    "QueueDepthPolicy",
    "TTFTSLOPolicy",
    "SchedulePolicy",
    "AUTOSCALER_POLICIES",
    "make_autoscaler_policy",
]
