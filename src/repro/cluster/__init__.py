"""Multi-replica cluster emulation layer (data-parallel serving, PD pools,
elastic membership + SLO-driven autoscaling).

Public surface::

    from repro.cluster import Cluster, build_cluster, make_router
    from repro.cluster import Autoscaler, make_autoscaler_policy

See ``cluster.py`` for the replica/timeline architecture, ``router.py`` for
the pluggable routing policies, and ``autoscaler.py`` for the virtual-time
scaling control loop.
"""

from .autoscaler import (AUTOSCALER_POLICIES, Autoscaler, AutoscalerConfig,
                         AutoscalerPolicy, QueueDepthPolicy, SchedulePolicy,
                         TTFTSLOPolicy, make_autoscaler_policy)
from .cluster import Cluster, ClusterConfig, build_cluster
from .router import (LeastOutstandingTokensRouter, PDPoolRouter,
                     PrefixAffinityRouter, ReplicaView, RoundRobinRouter,
                     Router, ROUTER_POLICIES, make_router)

__all__ = [
    "Cluster",
    "ClusterConfig",
    "build_cluster",
    "Router",
    "ReplicaView",
    "RoundRobinRouter",
    "LeastOutstandingTokensRouter",
    "PrefixAffinityRouter",
    "PDPoolRouter",
    "ROUTER_POLICIES",
    "make_router",
    "Autoscaler",
    "AutoscalerConfig",
    "AutoscalerPolicy",
    "QueueDepthPolicy",
    "TTFTSLOPolicy",
    "SchedulePolicy",
    "AUTOSCALER_POLICIES",
    "make_autoscaler_policy",
]
