"""Multi-replica cluster emulation layer (data-parallel serving, PD pools,
elastic membership, heterogeneous tiers + SLO-driven autoscaling, replicas
as threads or OS processes).

Public surface::

    from repro.cluster import Cluster, build_cluster, make_router
    from repro.cluster import Autoscaler, make_autoscaler_policy
    from repro.cluster import TierSpec, make_tier_specs

    build_cluster(..., backend="process")   # replicas as OS processes

See ``cluster.py`` for the replica/timeline architecture and the pluggable
backend split, ``process_backend.py`` for the multi-process runtime over
the time-warp socket transport, ``router.py`` for the pluggable routing
policies, ``autoscaler.py`` for the virtual-time scaling control loop, and
``tiers.py`` for the hardware-tier arithmetic behind heterogeneous pools.
"""

from .autoscaler import (AUTOSCALER_POLICIES, Autoscaler, AutoscalerConfig,
                         AutoscalerPolicy, QueueDepthPolicy, SchedulePolicy,
                         TTFTSLOPolicy, drain_victim, make_autoscaler_policy,
                         provision_delay)
from .cluster import Cluster, ClusterBase, ClusterConfig, build_cluster
from .faults import (FAULT_KINDS, FaultInjector, FaultSpec, ON_CRASH_POLICIES,
                     SlowdownPredictor)
from .process_backend import ProcessCluster, ProcessReplicaHandle
from .router import (AdapterAffinityRouter, CostNormalizedLoadRouter,
                     LeastOutstandingTokensRouter, PDPoolRouter,
                     PrefixAffinityRouter, ReplicaView, RoundRobinRouter,
                     Router, ROUTER_POLICIES, make_router)
from .tiers import (TierSpec, make_tier_spec, make_tier_specs,
                    probe_throughput, probe_ttft, tier_engine_cfg)

__all__ = [
    "Cluster",
    "ClusterBase",
    "ClusterConfig",
    "ProcessCluster",
    "ProcessReplicaHandle",
    "build_cluster",
    "drain_victim",
    "Router",
    "ReplicaView",
    "RoundRobinRouter",
    "LeastOutstandingTokensRouter",
    "CostNormalizedLoadRouter",
    "PrefixAffinityRouter",
    "AdapterAffinityRouter",
    "PDPoolRouter",
    "ROUTER_POLICIES",
    "make_router",
    "TierSpec",
    "make_tier_spec",
    "make_tier_specs",
    "probe_throughput",
    "probe_ttft",
    "tier_engine_cfg",
    "provision_delay",
    "Autoscaler",
    "AutoscalerConfig",
    "AutoscalerPolicy",
    "QueueDepthPolicy",
    "TTFTSLOPolicy",
    "SchedulePolicy",
    "AUTOSCALER_POLICIES",
    "make_autoscaler_policy",
    "FaultSpec",
    "FaultInjector",
    "SlowdownPredictor",
    "FAULT_KINDS",
    "ON_CRASH_POLICIES",
]
