"""SLO-driven autoscaling over the elastic cluster membership.

The :class:`Autoscaler` is an **Actor** on the shared virtual timeline: it
jumps from tick to tick (``interval_s`` of *virtual* time), evaluates a
pluggable :class:`AutoscalerPolicy` against a cheap cluster view, and applies
the decision through :meth:`Cluster.add_replica` /
:meth:`Cluster.drain_replica`.  Scale-up is not instantaneous: each new
replica is brought up by a *provisioner* actor that first jumps
``provision_delay_s`` of virtual time (node allocation + weight loading,
modeled, not slept) and only then joins the routing set.  Scale-down picks
its victim through the shared :func:`drain_victim` rule — most expensive
idle tier first, replica index as the deterministic tie-break — which the
DES baseline calls verbatim, so the emulator and the DES drain the *same*
replica under the same policy decisions (parity under elasticity, now
tier-aware: giving back a quiet H100 saves more than a quiet L4).

Policies see replicas only through the small :class:`AutoscalerView`
protocol, so identical policy objects drive the emulator's real engines and
the DES baseline's event-loop replicas — extending the paper's §2.3
"same control code everywhere" argument to the scaling control loop.

**Tier-selecting scale-up** (heterogeneous pools): when
:attr:`AutoscalerConfig.tiers` names candidate hardware tiers, every
scale-up first asks the policy :meth:`AutoscalerPolicy.select_tier` which
chip to provision.  The default rule picks the cheapest candidate;
:class:`TTFTSLOPolicy` picks the *cheapest tier whose projected service
TTFT still fits inside the SLO* (falling back to the fastest when none
does) — scaling into cheaper chips exactly when they are fast enough.
Per-tier provisioning delays come from
:attr:`AutoscalerConfig.provision_delay_by_tier`.  Tier selection happens
at tick time (deterministically, from immutable
:class:`~repro.cluster.tiers.TierSpec` data), so the DES mirror makes the
identical choice at the identical virtual time.
"""

from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass
from typing import List, Mapping, Optional, Protocol, Sequence, Tuple

from repro.core.client import TimeJumpClient

from .tiers import TierSpec

__all__ = [
    "AutoscalerConfig",
    "AutoscalerView",
    "AutoscalerPolicy",
    "QueueDepthPolicy",
    "TTFTSLOPolicy",
    "SchedulePolicy",
    "AUTOSCALER_POLICIES",
    "make_autoscaler_policy",
    "provision_delay",
    "drain_victim",
    "Autoscaler",
]


def drain_victim(active, *, idle_of, cost_of) -> Optional[int]:
    """Scale-down victim rule, shared verbatim by the emulator's
    :class:`Autoscaler` and the DES mirror so both drain the *same* replica
    under the same policy decisions (parity under elasticity).

    Most expensive **idle** tier first — shedding a quiet H100 saves more
    dollars than shedding a quiet L4 — with the replica index as the
    deterministic tie-break (highest wins, preserving the historical
    last-in-first-out shape on homogeneous pools).  When no active replica
    is idle, the same (cost, index) ordering applies to the busy ones:
    the drain then runs out its in-flight work before finalising.

    ``idle_of(i)`` / ``cost_of(i)`` are the per-replica probes (emulator:
    live engine counters + TierSpec rates; DES: event-loop state + the same
    TierSpec dict).  Returns None when draining is impossible (<=1 active).

    >>> drain_victim([0, 1, 2], idle_of=lambda i: i != 1,
    ...              cost_of=lambda i: [3.0, 9.0, 1.0][i])
    0
    >>> drain_victim([0, 1, 2], idle_of=lambda i: False,
    ...              cost_of=lambda i: [3.0, 9.0, 1.0][i])
    1
    >>> drain_victim([0, 1], idle_of=lambda i: True, cost_of=lambda i: 0.0)
    1
    >>> drain_victim([0], idle_of=lambda i: True, cost_of=lambda i: 0.0)
    """
    active = list(active)
    if len(active) <= 1:
        return None
    idle = [i for i in active if idle_of(i)]
    pool = idle if idle else active
    return max(pool, key=lambda i: (cost_of(i), i))


@dataclass(frozen=True)
class AutoscalerConfig:
    interval_s: float = 0.25          # virtual seconds between policy ticks
    provision_delay_s: float = 1.0    # scale-up latency (virtual-time jump)
    min_replicas: int = 1
    max_replicas: int = 8
    # Heterogeneous scale-up: candidate tier names the policy may provision
    # (empty = homogeneous, clone the last replica's tier) and optional
    # per-tier provisioning delays (cheaper chips are usually easier to get;
    # tiers absent from the mapping fall back to provision_delay_s).
    tiers: Tuple[str, ...] = ()
    provision_delay_by_tier: Optional[Mapping[str, float]] = None


def provision_delay(cfg: AutoscalerConfig, tier: Optional[str]) -> float:
    """Scale-up latency for ``tier`` under ``cfg`` (shared with the DES
    mirror so both sides provision at identical virtual times).

    >>> cfg = AutoscalerConfig(provision_delay_s=2.0,
    ...                        provision_delay_by_tier={"l4": 0.5})
    >>> provision_delay(cfg, "l4")
    0.5
    >>> provision_delay(cfg, "h100")
    2.0
    >>> provision_delay(cfg, None)
    2.0
    """
    if tier is not None and cfg.provision_delay_by_tier:
        return cfg.provision_delay_by_tier.get(tier, cfg.provision_delay_s)
    return cfg.provision_delay_s


class AutoscalerView(Protocol):
    """What a policy may observe.  Implementations are racy, non-blocking
    reads (emulator: engine counters; DES: event-loop state)."""

    def now(self) -> float: ...

    def active_count(self) -> int: ...

    def queue_depths(self) -> List[int]:
        """Outstanding (submitted-but-unfinished) requests per active replica."""
        ...

    def recent_ttfts(self, window_s: float) -> List[float]:
        """TTFTs of requests that finished within the trailing window."""
        ...


class AutoscalerPolicy:
    """Maps a view to a desired replica delta (+k up, -k down, 0 hold).

    Policies are stateful (tick history); build a fresh one per run — same
    convention as Router objects.

    On heterogeneous pools a policy also answers :meth:`select_tier` — which
    hardware tier each scale-up should provision.  The base rule is
    "cheapest candidate" (deterministic: cost, then name); selection must be
    a pure function of the immutable specs (+ at most the view), never of
    wall time or randomness, so the DES mirror reproduces it exactly.

    >>> specs = [TierSpec("h100", "h100-sxm", 5.5 / 3600, 800.0, 0.02),
    ...          TierSpec("l4", "l4", 0.8 / 3600, 200.0, 0.08)]
    >>> AutoscalerPolicy().select_tier(None, specs).name
    'l4'
    """

    name = "?"

    def decide(self, view: AutoscalerView) -> int:
        raise NotImplementedError

    def set_origin(self, t0: float) -> None:
        """Anchor time-scripted policies to the run's virtual start.

        Called once by the control loop before the first tick (the
        emulator's :class:`Autoscaler` passes ``clock.now()``; the DES
        passes ``0.0``, its event-loop origin).  Virtual time's absolute
        value depends on the wall source — a ManualWallSource starts near
        0, the process backend's host-shared ``time.time`` starts at the
        unix epoch — so policies must never interpret wall-derived
        absolutes.  Stateless policies ignore it."""

    def select_tier(self, view: Optional[AutoscalerView],
                    tiers: Sequence[TierSpec]) -> TierSpec:
        assert tiers, "select_tier needs at least one candidate"
        return min(tiers, key=lambda t: (t.cost_per_replica_s, t.name))


class QueueDepthPolicy(AutoscalerPolicy):
    """Classic queue-depth target: scale up when the mean per-replica backlog
    exceeds ``target_depth`` requests, down when it falls below
    ``low_watermark`` (hysteresis gap avoids flapping)."""

    name = "queue_depth"

    def __init__(self, target_depth: float = 4.0, low_watermark: float = 1.0):
        assert low_watermark < target_depth
        self.target_depth = target_depth
        self.low_watermark = low_watermark

    def decide(self, view: AutoscalerView) -> int:
        depths = view.queue_depths()
        if not depths:
            return 0
        mean = sum(depths) / len(depths)
        if mean > self.target_depth:
            return 1
        if mean < self.low_watermark:
            return -1
        return 0


class TTFTSLOPolicy(AutoscalerPolicy):
    """SLO-attainment feedback: scale up while the trailing window's TTFT
    attainment sits below ``target_attainment``; scale down only when
    attainment is met AND the backlog is nearly empty (capacity is provably
    surplus, so shrinking cannot immediately re-breach the SLO)."""

    name = "ttft_slo"

    def __init__(self, slo_ttft_s: float = 0.5,
                 target_attainment: float = 0.95,
                 window_s: float = 2.0,
                 idle_depth: float = 0.5,
                 tier_headroom: float = 0.5):
        self.slo_ttft_s = slo_ttft_s
        self.target_attainment = target_attainment
        self.window_s = window_s
        self.idle_depth = idle_depth
        self.tier_headroom = tier_headroom

    def select_tier(self, view, tiers: Sequence[TierSpec]) -> TierSpec:
        """Cheapest tier that *projects* to meet the TTFT SLO: its unloaded
        service TTFT (prefill + first decode step, from the tier's own
        predictor) must fit within ``tier_headroom`` of the SLO, the rest
        being queueing budget.  No tier feasible → provision the fastest
        (min projected TTFT) and let the next ticks keep scaling.

        >>> fast = TierSpec("h100", "h100-sxm", 5.5 / 3600, 800.0, 0.02)
        >>> slow = TierSpec("l4", "l4", 0.8 / 3600, 200.0, 0.08)
        >>> TTFTSLOPolicy(slo_ttft_s=0.5).select_tier(None, [fast, slow]).name
        'l4'
        >>> TTFTSLOPolicy(slo_ttft_s=0.1).select_tier(None, [fast, slow]).name
        'h100'
        """
        assert tiers, "select_tier needs at least one candidate"
        budget = self.tier_headroom * self.slo_ttft_s
        feasible = [t for t in tiers if t.projected_ttft_s <= budget]
        if feasible:
            return min(feasible,
                       key=lambda t: (t.cost_per_replica_s, t.name))
        return min(tiers, key=lambda t: (t.projected_ttft_s, t.name))

    def decide(self, view: AutoscalerView) -> int:
        ttfts = view.recent_ttfts(self.window_s)
        depths = view.queue_depths()
        mean_depth = sum(depths) / len(depths) if depths else 0.0
        if ttfts:
            attainment = (sum(1 for t in ttfts if t <= self.slo_ttft_s)
                          / len(ttfts))
            if attainment < self.target_attainment:
                return 1
        if mean_depth < self.idle_depth:
            return -1
        return 0


class SchedulePolicy(AutoscalerPolicy):
    """Scripted membership changes: ``events`` is a list of
    ``(virtual_time, delta)`` pairs applied at the first tick at-or-after
    each time, where times are measured **from the run's virtual start**
    (the :meth:`set_origin` anchor — this is what keeps one schedule
    meaningful across wall sources: a ManualWallSource timeline starts
    near 0, the process backend's at the unix epoch).  Deterministic by
    construction — the elastic emulator-vs-DES and thread-vs-process
    parity scenarios use it so all sides scale at identical virtual
    times regardless of load-probe raciness.

    >>> from types import SimpleNamespace
    >>> p = SchedulePolicy([(1.0, +1), (2.0, -1)])
    >>> p.decide(SimpleNamespace(now=lambda: 0.5))
    0
    >>> p.decide(SimpleNamespace(now=lambda: 1.5))
    1
    >>> p.decide(SimpleNamespace(now=lambda: 1.6))   # event already consumed
    0
    >>> p2 = SchedulePolicy([(1.0, +1)])
    >>> p2.set_origin(100.0)                         # run started at t=100
    >>> p2.decide(SimpleNamespace(now=lambda: 100.5))
    0
    >>> p2.decide(SimpleNamespace(now=lambda: 101.5))
    1
    """

    name = "schedule"

    def __init__(self, events: Sequence[Tuple[float, int]]):
        self._events = sorted(events)
        self._cursor = 0
        self._origin = 0.0

    def set_origin(self, t0: float) -> None:
        self._origin = t0

    def decide(self, view: AutoscalerView) -> int:
        now = view.now() - self._origin
        delta = 0
        while (self._cursor < len(self._events)
               and self._events[self._cursor][0] <= now):
            delta += self._events[self._cursor][1]
            self._cursor += 1
        return delta


AUTOSCALER_POLICIES = {
    cls.name: cls
    for cls in (QueueDepthPolicy, TTFTSLOPolicy, SchedulePolicy)
}


def make_autoscaler_policy(name: str, **kwargs) -> AutoscalerPolicy:
    try:
        cls = AUTOSCALER_POLICIES[name]
    except KeyError:
        raise ValueError(
            f"unknown autoscaler policy {name!r}; "
            f"choose from {sorted(AUTOSCALER_POLICIES)}") from None
    return cls(**kwargs)


class _ClusterView:
    """AutoscalerView over a live emulated Cluster (racy counter reads)."""

    def __init__(self, cluster):
        self._c = cluster

    def now(self) -> float:
        return self._c.clock.now()

    def active_count(self) -> int:
        return self._c.num_active()

    def queue_depths(self) -> List[int]:
        with self._c._membership_lock:
            active = list(self._c.active)
        return [self._c.engines[i].num_outstanding() for i in active]

    def recent_ttfts(self, window_s: float) -> List[float]:
        horizon = self.now() - window_s
        out: List[float] = []
        with self._c._finish_cond:
            # scan from the tail; finished is finish-ordered per replica and
            # near-ordered globally, so stop after a safety margin
            for r in reversed(self._c.finished):
                if r.finish_time is not None and r.finish_time < horizon:
                    break
                t = r.ttft()
                if t is not None:
                    out.append(t)
        return out


class Autoscaler:
    """Virtual-time control loop gluing a policy onto a Cluster.

    Lifecycle mirrors the engines: ``start()`` spawns the tick thread (an
    Actor when the cluster has a Timekeeper transport; wall-clock ticks
    otherwise, the sleep-mode degradation), ``stop()`` deregisters it.
    ``decision_log`` records ``(tick_time, delta_applied, active_after)`` for
    benchmarks and tests; ``scaleups`` additionally records
    ``(tick_time, tier_name)`` per provisioned replica (tier None =
    homogeneous clone).
    """

    def __init__(self, cluster, policy: AutoscalerPolicy,
                 cfg: Optional[AutoscalerConfig] = None, *,
                 name: str = "autoscaler"):
        self.cluster = cluster
        self.policy = policy
        self.cfg = cfg or AutoscalerConfig()
        self.name = name
        self.view: AutoscalerView = _ClusterView(cluster)
        self.decision_log: List[tuple] = []
        self.scaleups: List[Tuple[float, Optional[str]]] = []
        # candidate TierSpecs for tier-selecting scale-up, resolved through
        # the cluster's spec cache/factory so router weights, cost
        # accounting, and selection all share one arithmetic
        self.tier_candidates: List[TierSpec] = [
            cluster.tier_spec(t) for t in self.cfg.tiers]
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._client: Optional[TimeJumpClient] = None
        self._provisioning = 0            # scale-ups in flight (delay jump)
        self._prov_lock = threading.Lock()
        self._prov_ids = itertools.count()
        self._prov_threads: List[threading.Thread] = []

    # ---------------------------------------------------------- lifecycle --
    def start(self) -> "Autoscaler":
        assert self._thread is None, "autoscaler already started"
        # Anchor time-scripted policies to the run's virtual start (the DES
        # mirror anchors at its event-loop origin, 0.0).
        self.policy.set_origin(self.cluster.clock.now())
        if self.cluster.transport is not None:
            self._client = TimeJumpClient(
                self.cluster.transport, f"{self.name}-tick")
        self._thread = threading.Thread(
            target=self._loop, name=f"{self.name}-loop", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        # Deregistering the tick actor from here unwedges a thread blocked
        # mid-jump (the Timekeeper bumps the clock epoch on deregistration);
        # its next re-request raises KeyError, which the loop treats as stop.
        if self._client is not None:
            self._client.deregister()
        if self._thread is not None:
            self._thread.join(timeout=10)
        for t in self._prov_threads:
            t.join(timeout=10)

    # --------------------------------------------------------------- loop --
    def _loop(self) -> None:
        clock = self.cluster.clock
        next_t = clock.now() + self.cfg.interval_s
        while not self._stop.is_set():
            try:
                if self._client is not None:
                    self._client.jump_to(next_t)
                else:
                    dt = next_t - clock.now()
                    if dt > 0:
                        clock.wall.sleep(dt)
            except (KeyError, RuntimeError):
                break                     # deregistered / timekeeper closed
            if self._stop.is_set():
                break
            self._tick()
            next_t += self.cfg.interval_s

    def _tick(self) -> None:
        delta = self.policy.decide(self.view)
        applied = self._apply(delta)
        self.decision_log.append(
            (self.view.now(), applied, self.cluster.num_active()))

    def _apply(self, delta: int) -> int:
        cfg = self.cfg
        with self._prov_lock:
            committed = self.cluster.num_active() + self._provisioning
            if delta > 0:
                delta = min(delta, cfg.max_replicas - committed)
                for _ in range(max(0, delta)):
                    tier = None
                    if self.tier_candidates:
                        tier = self.policy.select_tier(
                            self.view, self.tier_candidates).name
                    self.scaleups.append((self.view.now(), tier))
                    self._provisioning += 1
                    self._spawn_provisioner(tier)
                return max(0, delta)
            if delta < 0:
                # never drain below min, and count in-flight provisions as
                # capacity already committed
                allowed = max(0, committed - cfg.min_replicas)
                delta = -min(-delta, allowed)
                drained = 0
                for _ in range(-delta):
                    victim = self._pick_victim()
                    if victim is None:
                        break
                    self.cluster.drain_replica(victim)
                    drained += 1
                return -drained
        return 0

    def _pick_victim(self) -> Optional[int]:
        """Tier-aware rule via :func:`drain_victim`: most expensive idle
        tier first, index as the deterministic tie-break — identical code
        to the DES mirror.  Idleness is a racy engine probe, but drains
        only ever fire on quiet clusters (policy hysteresis), where the
        probe is stable on both sides."""
        with self.cluster._membership_lock:
            active = list(self.cluster.active)
        return drain_victim(
            active,
            idle_of=lambda i: self.cluster.replicas[i].num_outstanding() == 0,
            cost_of=self.cluster.replica_cost_rate)

    def _spawn_provisioner(self, tier: Optional[str] = None) -> None:
        """Model the scale-up latency as a virtual-time jump.

        The provisioner's actor is registered *here*, in the tick thread —
        an Actor between jumps — so the barrier cannot advance past the
        provisioning interval before the jump request lands (§4.3 trick,
        same as the PD KV movers).  ``tier`` is the policy's tier choice
        (made at tick time; the provisioner only pays that tier's delay and
        joins the replica)."""
        client = None
        if self.cluster.transport is not None:
            client = TimeJumpClient(
                self.cluster.transport,
                f"{self.name}-prov-{next(self._prov_ids)}")
        t = threading.Thread(target=self._provision, args=(client, tier),
                             name=f"{self.name}-prov", daemon=True)
        t.start()
        self._prov_threads.append(t)

    def _provision(self, client: Optional[TimeJumpClient],
                   tier: Optional[str] = None) -> None:
        try:
            delay = provision_delay(self.cfg, tier)
            try:
                if client is not None:
                    client.time_jump(delay)
                else:
                    self.cluster.clock.wall.sleep(delay)
            except (KeyError, RuntimeError):
                return                    # torn down mid-provision
            if not self._stop.is_set():
                self.cluster.add_replica(tier=tier)
        finally:
            if client is not None:
                client.deregister()
            with self._prov_lock:
                self._provisioning -= 1
