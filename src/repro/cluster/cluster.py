"""Multi-replica cluster emulation: N engines, one virtual timeline.

The cluster runtime is split into a backend-agnostic control plane
(:class:`ClusterBase`: routing, elastic membership, completion fan-out,
cost accounting) and two pluggable **backends** that decide where replica
engines physically run:

* **thread backend** (:class:`Cluster`, this module) — every replica is an
  in-process :class:`~repro.serving.engine.LLMEngine` sharing one
  :class:`~repro.core.clock.VirtualClock` object; Timekeeper fan-in is a
  function call (:class:`~repro.core.client.LocalTransport`).
* **process backend** (:class:`~repro.cluster.process_backend.ProcessCluster`)
  — every replica's engine runs in its own OS process wired to a
  :class:`~repro.core.transport.TimekeeperServer` over the framed-TCP
  protocol, holding a broadcast-driven *replica* clock.  Same engine code,
  same router objects, same runner — only the transport changes.

Each replica is an independent continuous-batching engine (own scheduler,
block pool, radix cache, model runner); the cluster adds the data-parallel
control plane the paper's config-sweep story needs at scale:

* **Routing** — a pluggable :class:`~repro.cluster.router.Router` policy
  places each request (round-robin, least-outstanding-tokens,
  prefix-affinity, or a prefill/decode pool split).
* **One coordinated timeline** — all replicas' actors share one Timekeeper;
  idle replicas *park* (leave the barrier but stay known) so the busy
  subset plus the dispatcher advance the single offset at full speed.
  Causality across replicas is the Timekeeper's minimum-target rule —
  virtual time can never jump past an event another replica still has to
  produce, so cluster-level TTFT/goodput percentiles are exact.
* **PD pools** — with the ``pd_pool`` policy the cluster reuses the
  emulated KV channel from ``repro.core.emulation`` to migrate completed
  prefills into the decode pool, unifying ``repro.serving.disagg`` behind
  the Router interface (thread backend only).

* **Heterogeneous pools** — each replica may run on a different hardware
  *tier* (chip name from ``repro.core.hardware``): its predictor, KV-cache
  capacity, and $/replica-second follow the chip, routing policies see
  per-replica throughput weights and costs, and
  :meth:`ClusterBase.add_replica` accepts a tier so the autoscaler can
  scale into cheaper chips (see ``repro.cluster.tiers``).

The cluster exposes the same non-blocking ``submit`` / ``poll`` /
``wait_until_complete`` surface as a single engine, so
``repro.serving.benchmark.BenchmarkRunner`` drives a 1-replica engine, an
N-replica thread cluster, and an N-process cluster through one code path
(Workload → Cluster → Metrics).

Replica handle protocol (what a backend's replicas must expose)::

    submit(req)                  enqueue; the replica's actors are
                                 registered with the Timekeeper by return
    num_outstanding() -> int     \
    outstanding_tokens() -> int   } ReplicaView probes (router placement)
    prefix_match_len(toks)->int  /
    in_flight_ids() -> set       drain bookkeeping snapshot
    retire()                     leave the Timekeeper permanently (drain)
    start() / stop()             engine lifecycle
    stats() -> dict              per-replica counters
    step_log -> List[StepRecord] step accounting

An in-process :class:`LLMEngine` satisfies it directly; the process backend
satisfies it with an RPC proxy per child process.

Listener invariant (closed-loop workloads build on this): completion
listeners run *before the finishing replica re-enters the barrier* — in the
finishing replica's step thread (thread backend) or in the parent's
completion-frame handler while the child engine blocks on the ack (process
backend) — so any actor a listener registers with the Timekeeper exists
before the next barrier round; virtual time can never jump past work a
completion is about to schedule (§4.3).
"""

from __future__ import annotations

import itertools
import pickle
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core.client import LocalTransport, TimeJumpClient
from repro.core.clock import VirtualClock, WallSource
from repro.core.emulation import EmulatedChannel, VirtualDeviceContext
from repro.core.hardware import get_chip
from repro.core.predictor import RuntimePredictor
from repro.core.timekeeper import Timekeeper
from repro.models.config import ModelConfig
from repro.serving.engine import LLMEngine, StepRecord
from repro.serving.model_runner import SleepModelRunner, TimeWarpModelRunner
from repro.serving.request import Request, RequestState
from repro.serving.scheduler import EngineConfig

from .router import PDPoolRouter, Router, make_router
from .tiers import TierSpec, make_tier_spec, tier_engine_cfg

__all__ = ["ClusterConfig", "ClusterBase", "Cluster", "build_cluster"]


@dataclass
class ClusterConfig:
    kv_link_bandwidth: float = 50e9   # PD pools: inter-replica KV fabric (B/s)
    # Per-replica hardware tiers (chip names); None = homogeneous/untiered.
    # Carried through build_cluster so stats/cost accounting can report the
    # mix; the authoritative per-replica record is ClusterBase.replica_tiers
    # (which keeps growing as the autoscaler adds replicas).
    tiers: Optional[List[Optional[str]]] = None


class ClusterBase:
    """Backend-agnostic cluster control plane over replica handles.

    Subclasses provide replica construction/placement (thread engines or
    process proxies) through :meth:`_new_replica` / :meth:`_attach_replica`;
    everything else — routing, elastic membership, drain bookkeeping,
    completion fan-out, replica-seconds/cost accounting — lives here and is
    byte-identical across backends.
    """

    #: human-readable backend tag (stats/benchmark rows)
    backend = "?"

    def __init__(
        self,
        replicas: Sequence,
        router: Router,
        *,
        clock: VirtualClock,
        transport=None,
        timekeeper: Optional[Timekeeper] = None,
        model_cfg: Optional[ModelConfig] = None,
        cfg: Optional[ClusterConfig] = None,
        replica_factory=None,
        tier_specs: Optional[Dict[str, TierSpec]] = None,
        tier_spec_factory=None,
    ):
        assert replicas, "a cluster needs at least one replica"
        assert router.num_replicas == len(replicas), \
            f"router sized for {router.num_replicas} replicas, got {len(replicas)}"
        self.replicas = list(replicas)
        self.router = router
        self.transport = transport
        self.timekeeper = timekeeper
        self.model_cfg = model_cfg
        # default constructed per-instance: a shared mutable class-level
        # default would alias config state across all clusters
        self.cfg = cfg if cfg is not None else ClusterConfig()
        self.clock: VirtualClock = clock

        self.finished: List[Request] = []
        self._finish_cond = threading.Condition()
        self._poll_cursor = 0
        self._started = False
        # Counter-backed completion accounting: wait_until_complete/stats
        # read this, so audit modes can drop the retained lists without
        # breaking the wait/progress surface.
        self._finished_count = 0
        # Terminally failed requests (crash with on_crash="fail"): they count
        # toward wait_until_complete's expected total but never join
        # ``finished`` — metrics exclude them, the wait surface does not.
        self.failed: List[Request] = []
        self._failed_count = 0
        self._audit = "full"
        self.retain_finished = True
        self.retain_placements = True

        # ---- elastic membership (autoscaling) ----
        # ``active`` = replicas the router may place fresh requests on.
        # ``_membership[i]`` records (added_at, drain_started, drained_at)
        # virtual times; None added_at means "member since cluster start".
        self._replica_factory = replica_factory
        self._membership_lock = threading.RLock()
        # ---- heterogeneous tiers ----
        # replica_tiers[i] is replica i's tier name (None = untiered);
        # tier_specs caches TierSpec per tier name, lazily extended through
        # tier_spec_factory when the autoscaler scales into a new tier.
        self.replica_tiers: List[Optional[str]] = list(
            (self.cfg.tiers or [None] * len(self.replicas)))
        assert len(self.replica_tiers) == len(self.replicas), \
            "need one tier entry per replica"
        self._tier_specs: Dict[str, TierSpec] = dict(tier_specs or {})
        self._tier_spec_factory = tier_spec_factory
        for i, t in enumerate(self.replica_tiers):
            if t is not None:
                spec = self.tier_spec(t)
                self.router.set_tier(i, weight=spec.throughput_factor,
                                     cost=spec.cost_per_replica_s)
        self.active: List[int] = list(range(len(self.replicas)))
        self._membership: Dict[int, dict] = {
            i: {"added": None, "drain_started": None, "drained": None}
            for i in range(len(self.replicas))
        }
        self._draining: Dict[int, set] = {}   # idx -> in-flight request ids
        self._submit_lock = threading.Lock()  # serialises route+submit
        # Placement audit log (parity benchmarks compare it across backends):
        # one (session_id, turn_index, request_id, replica) row per submit,
        # in submit order.
        self.placements: List[Tuple] = []
        # Completion subscribers (closed-loop workloads, autoscaler views);
        # called synchronously before the finishing replica's next barrier
        # participation.
        self.completion_listeners: List = []

    # ------------------------------------------------------------ backend --
    @property
    def engines(self) -> List:
        """The replica handles (in-process engines on the thread backend,
        RPC proxies on the process backend) — same objects as
        :attr:`replicas`, kept under the historical name."""
        return self.replicas

    def _new_replica(self, idx: int, tier: Optional[str]):
        """Build (or activate) replica ``idx`` on ``tier``; backend hook."""
        raise NotImplementedError

    def _attach_replica(self, replica) -> None:
        """Wire the backend's completion path into ``replica``; hook."""
        raise NotImplementedError

    # ------------------------------------------------------------- intake --
    def submit(self, req: Request) -> int:
        """Route and enqueue one request; returns the chosen replica index.

        Non-blocking on the engine side: routing reads racy load/affinity
        probes, the replica submit returns once the request is enqueued AND
        the replica's actors are registered with the Timekeeper (thread
        backend: a synchronous unpark; process backend: the child's
        submit-ack).  Callers may be the benchmark dispatcher *and*
        closed-loop think-time actors, so the route+enqueue pair is
        serialised (router state is not thread-safe)."""
        with self._submit_lock:
            return self._submit_locked(req)

    def _submit_locked(self, req: Request) -> int:
        """Route + enqueue; caller holds ``_submit_lock`` (crash requeues
        reuse this so a batch of re-routes is one atomic decision run)."""
        idx = self.router.route(req, self.replicas, active=self.active)
        if self.retain_placements:
            self.placements.append(
                (req.session_id, req.turn_index, req.request_id, idx))
        self.replicas[idx].submit(req)
        return idx

    def submit_many(self, reqs: Sequence[Request]) -> List[int]:
        return [self.submit(r) for r in reqs]

    # -------------------------------------------------------------- hooks --
    def add_completion_listener(self, fn) -> None:
        """Subscribe ``fn(finished: List[Request])``; runs BEFORE the
        finishing replica's next barrier participation — safe to register
        think-time actors from (closed-loop session re-injection)."""
        self.completion_listeners.append(fn)

    def remove_completion_listener(self, fn) -> None:
        if fn in self.completion_listeners:
            self.completion_listeners.remove(fn)

    # ------------------------------------------------------------- audit --
    @property
    def finished_count(self) -> int:
        """Completions seen so far — valid in every audit mode (the
        ``finished`` list itself is empty under ``sampled``/``off``)."""
        with self._finish_cond:
            return self._finished_count

    def set_audit(self, audit: str) -> None:
        """Select what the cluster retains per request.

        ``"full"`` keeps everything (historical behaviour); ``"sampled"``
        and ``"off"`` drop the per-request ``finished``/``placements``
        lists, the router's decision log, and each replica's step log so
        memory stays flat at million-session scale.  Counter-backed
        accounting (``finished_count``, ``stats()``) keeps working.
        """
        retain = audit == "full"
        self._audit = audit
        self.retain_finished = retain
        self.retain_placements = retain
        if hasattr(self.router, "record_decisions"):
            self.router.record_decisions = retain
        for r in self.replicas:
            if hasattr(r, "set_audit"):
                r.set_audit(audit)

    def _complete(self, finished: List[Request]) -> None:
        """Completion fan-out; the finishing replica is still barred from
        its next barrier round while this runs (step thread on the thread
        backend, pre-ack on the process backend)."""
        with self._finish_cond:
            self._finished_count += len(finished)
            if self.retain_finished:
                self.finished.extend(finished)
            self._finish_cond.notify_all()
        # Unconditional (serialised on _membership_lock inside): an unlocked
        # emptiness pre-check here could race drain_replica's in-flight
        # snapshot and leave a drain that never finalises.
        self._drain_progress(finished)
        for fn in list(self.completion_listeners):
            fn(finished)

    # ------------------------------------------------------------- tiers --
    def tier_spec(self, tier: str) -> TierSpec:
        """The :class:`TierSpec` for ``tier``, computed lazily (and cached)
        through the factory ``build_cluster`` wires — so scaling into a tier
        the initial pool never used still gets consistent weights/costs."""
        spec = self._tier_specs.get(tier)
        if spec is None:
            assert self._tier_spec_factory is not None, \
                f"no spec for tier {tier!r} and no tier_spec_factory"
            spec = self._tier_spec_factory(tier)
            self._tier_specs[tier] = spec
        return spec

    def replica_cost_rate(self, idx: int) -> float:
        """Replica ``idx``'s $/replica-second (0.0 when untiered) — the
        drain-victim rule ranks candidates by it."""
        tier = self.replica_tiers[idx]
        return 0.0 if tier is None else self.tier_spec(tier).cost_per_replica_s

    # --------------------------------------------------- elastic membership --
    def add_replica(self, engine=None, tier: Optional[str] = None) -> int:
        """Scale up: join a new replica to the routing set.

        ``engine`` defaults to one built by the backend (thread: the replica
        factory clones the last replica's config onto the shared
        Timekeeper/transport; process: a warm child process is activated).
        ``tier`` picks the hardware tier of the backend-built replica
        (tier-selecting autoscaling); omitted, the new replica clones the
        last replica's tier.  The join is immediate — provisioning delay is
        the *caller's* job (the Autoscaler models it as a virtual-time jump
        before calling this).  Returns the new index.
        """
        with self._submit_lock, self._membership_lock:
            idx = len(self.replicas)
            if tier is None:
                tier = self.replica_tiers[-1] if engine is None else None
            if engine is None:
                engine = self._new_replica(idx, tier)
            self._attach_replica(engine)
            self.replicas.append(engine)
            self.replica_tiers.append(tier)
            if tier is not None:
                spec = self.tier_spec(tier)
                self.router.grow(idx + 1, weight=spec.throughput_factor,
                                 cost=spec.cost_per_replica_s)
            else:
                self.router.grow(idx + 1)
            self.active.append(idx)
            self._membership[idx] = {"added": self.clock.now(),
                                     "drain_started": None, "drained": None}
            if self._audit != "full" and hasattr(engine, "set_audit"):
                engine.set_audit(self._audit)
            if self._started:
                engine.start()
            return idx

    def drain_replica(self, idx: int) -> None:
        """Scale down: stop routing to replica ``idx``, let its in-flight
        requests finish, then retire it from the Timekeeper (full
        deregistration with an epoch bump — on the process backend this goes
        out as a ``deregister`` frame after the last completion frame).  The
        replica's engine keeps running (parked/retired actors cost nothing
        on the barrier); ``stop()`` reaps it with the rest of the cluster."""
        # _submit_lock first: a concurrent submit must either fully enqueue
        # (and show up in the in-flight snapshot) or route after the removal.
        with self._submit_lock, self._membership_lock:
            if idx not in self.active:
                raise ValueError(f"replica {idx} is not active")
            assert len(self.active) > 1, "cannot drain the last replica"
            self.active.remove(idx)
            self._membership[idx]["drain_started"] = self.clock.now()
            in_flight = set(self.replicas[idx].in_flight_ids())
            if in_flight:
                self._draining[idx] = in_flight
            else:
                self._finalize_drain(idx)

    def _drain_progress(self, finished: List[Request]) -> None:
        """Called from ``_complete`` while drains are open."""
        done_ids = {r.request_id for r in finished}
        with self._membership_lock:
            for idx in list(self._draining):
                self._draining[idx] -= done_ids
                if not self._draining[idx]:
                    del self._draining[idx]
                    self._finalize_drain(idx)

    def _finalize_drain(self, idx: int) -> None:
        """In-flight work done: stamp the membership end and retire the
        replica's worker actors so the Timekeeper forgets them entirely
        (they would otherwise merely park).  Caller holds
        ``_membership_lock``."""
        self._membership[idx]["drained"] = self.clock.now()
        self.replicas[idx].retire()

    # ------------------------------------------------------ fault injection --
    def crash_replica(self, idx: int, *, on_crash: str = "requeue") -> dict:
        """Kill replica ``idx`` *now* (fault injection): KV/prefix state is
        lost, the replica leaves the routing set immediately (not via the
        drain ledger — a dead replica must be invisible to the router and
        the autoscaler's drain-victim rule at once), its cost window closes
        at the crash instant, and every in-flight request is either
        re-routed (``on_crash="requeue"``, progress zeroed, original
        arrival time kept) or terminally failed (``on_crash="fail"``).

        A replica crashing *while already draining* is removed from the
        drain ledger first so it can never be double-finalized nor
        re-picked as a victim; its ``drained`` stamp is the crash time, so
        ``replica_seconds``/``cost_dollars`` bill it exactly once.  The
        last active replica refuses to crash (``crashed=False``) — a
        cluster with no capacity could never finish the run, mirroring the
        drain-side ``len(active) > 1`` invariant.

        Returns ``{"crashed", "requeued", "failed", "tier"}``.
        """
        assert on_crash in ("requeue", "fail"), on_crash
        with self._submit_lock, self._membership_lock:
            tier = self.replica_tiers[idx]
            m = self._membership[idx]
            if m["drained"] is not None:          # already fully gone
                return {"crashed": False, "requeued": 0, "failed": 0,
                        "tier": tier}
            if idx in self.active:
                if len(self.active) <= 1:
                    return {"crashed": False, "requeued": 0, "failed": 0,
                            "tier": tier}
                self.active.remove(idx)
            self._draining.pop(idx, None)         # never finalized twice
            if m["drain_started"] is None:
                m["drain_started"] = self.clock.now()
            m["drained"] = self.clock.now()
        # Kill OUTSIDE the cluster locks: the victim's step thread may be
        # delivering a completion that co-resolved with the fault's barrier
        # round, and that path takes _membership_lock (drain progress) —
        # holding it through the join would deadlock.
        victims = list(self._force_kill(idx))
        victims.sort(key=lambda r: (r.arrival_time, r.request_id))
        requeued = failed = 0
        if on_crash == "requeue":
            with self._submit_lock:
                for req in victims:
                    req.reset_for_requeue()
                    self._submit_locked(req)
            requeued = len(victims)
        else:
            with self._finish_cond:
                self.failed.extend(victims)
                self._failed_count += len(victims)
                self._finish_cond.notify_all()
            failed = len(victims)
        return {"crashed": True, "requeued": requeued, "failed": failed,
                "tier": tier}

    def set_replica_slowdown(self, idx: int, factor: Optional[float]) -> bool:
        """Straggler injection: scale replica ``idx``'s predicted step times
        by ``factor`` (``None`` restores full speed).  Steps whose duration
        was computed before the change keep it — identical semantics to the
        DES, whose in-flight STEP_DONE events are already on the heap."""
        if idx >= len(self.replicas):
            return False
        return self._set_slowdown(idx, factor)

    def _force_kill(self, idx: int) -> List[Request]:
        """Backend hook: destroy replica ``idx`` immediately and return its
        in-flight requests (un-reset)."""
        raise NotImplementedError

    def _set_slowdown(self, idx: int, factor: Optional[float]) -> bool:
        """Backend hook for :meth:`set_replica_slowdown`."""
        raise NotImplementedError

    @property
    def failed_count(self) -> int:
        with self._finish_cond:
            return self._failed_count

    def num_active(self) -> int:
        with self._membership_lock:
            return len(self.active)

    def _membership_windows(self, t_start: float, t_end: float) -> List[float]:
        """Per-replica on-seconds overlapping [t_start, t_end].  A drained
        replica stops accruing at the finish of its last in-flight request;
        an added one starts at its (post-provisioning-delay) join time.
        Caller holds ``_membership_lock``."""
        out = []
        for idx in range(len(self.replicas)):
            m = self._membership[idx]
            a = t_start if m["added"] is None else max(t_start, m["added"])
            drained = m["drained"]
            if drained is None and idx in self._draining:
                drained = t_end          # still draining at window end
            b = t_end if drained is None else min(t_end, drained)
            out.append(max(0.0, b - a))
        return out

    def replica_seconds(self, t_start: float, t_end: float) -> float:
        """Capacity proxy: total replica-on time (virtual seconds)
        overlapping the window [t_start, t_end]."""
        with self._membership_lock:
            return sum(self._membership_windows(t_start, t_end))

    def tier_seconds(self, t_start: float, t_end: float) -> Dict[str, float]:
        """Replica-on seconds per tier name over the window (untiered
        replicas accrue under the key ``None``)."""
        with self._membership_lock:
            windows = self._membership_windows(t_start, t_end)
            out: Dict[str, float] = {}
            for tier, w in zip(self.replica_tiers, windows):
                out[tier] = out.get(tier, 0.0) + w
            return out

    def replica_cost(self, t_start: float, t_end: float) -> float:
        """Dollar cost of the window: each replica's on-seconds × its tier's
        $/replica-second.  Untiered replicas cost $0 (no tier, no price) —
        a fully untiered cluster reports 0.0 and ``replica_seconds`` stays
        the cost proxy."""
        with self._membership_lock:
            windows = self._membership_windows(t_start, t_end)
            total = 0.0
            for tier, w in zip(self.replica_tiers, windows):
                if tier is not None:
                    total += w * self.tier_spec(tier).cost_per_replica_s
            return total

    def membership_events(self) -> List[dict]:
        with self._membership_lock:
            return [{"replica": i, **dict(self._membership[i])}
                    for i in sorted(self._membership)]

    # ---------------------------------------------------------- lifecycle --
    def start(self):
        for r in self.replicas:
            r.start()
        self._started = True
        return self

    def stop(self) -> None:
        for r in self.replicas:
            r.stop()
        self._started = False

    def shutdown(self) -> None:
        self.stop()
        if self.timekeeper is not None:
            self.timekeeper.close()

    @property
    def is_running(self) -> bool:
        return self._started

    # ------------------------------------------------------------ outtake --
    def poll(self) -> List[Request]:
        """Drain cluster-level completions since the previous poll."""
        with self._finish_cond:
            new = self.finished[self._poll_cursor:]
            self._poll_cursor = len(self.finished)
        return list(new)

    def wait_until_complete(self, expected: int, timeout: float = 600.0) -> bool:
        import time as _time
        deadline = _time.monotonic() + timeout
        with self._finish_cond:
            while self._finished_count + self._failed_count < expected:
                remaining = deadline - _time.monotonic()
                if remaining <= 0:
                    return False
                self._finish_cond.wait(timeout=min(remaining, 1.0))
        return True

    # --------------------------------------------------------- aggregates --
    @property
    def step_log(self) -> List[StepRecord]:
        """All replicas' step records (benchmark overhead accounting)."""
        log: List[StepRecord] = []
        for r in self.replicas:
            log.extend(r.step_log)
        return log

    def num_outstanding(self) -> int:
        return sum(r.num_outstanding() for r in self.replicas)

    def outstanding_tokens(self) -> int:
        return sum(r.outstanding_tokens() for r in self.replicas)

    def stats(self) -> dict:
        """Aggregate of per-replica ``stats()`` snapshots."""
        per_replica = [r.stats() for r in self.replicas]
        agg = {
            "backend": self.backend,
            "num_replicas": len(self.replicas),
            "num_active": self.num_active(),
            "membership": self.membership_events(),
            "tiers": list(self.replica_tiers),
            "policy": getattr(self.router, "policy", "?"),
            "finished": self._finished_count,
            "failed": self._failed_count,
            "steps": sum(r["steps"] for r in per_replica),
            "device_time_s": sum(r["device_time_s"] for r in per_replica),
            "cpu_overhead_s": sum(r["cpu_overhead_s"] for r in per_replica),
            "num_preemptions": sum(r["num_preemptions"] for r in per_replica),
            "replicas": per_replica,
            "routing_decisions": list(self.router.decisions),
        }
        if self.timekeeper is not None:
            agg["timekeeper"] = self.timekeeper.stats.as_dict()
        return agg


class Cluster(ClusterBase):
    """Thread backend: N in-process engine replicas sharing one clock object."""

    backend = "thread"

    def __init__(
        self,
        engines: Sequence[LLMEngine],
        router: Router,
        *,
        transport: Optional[LocalTransport] = None,
        timekeeper: Optional[Timekeeper] = None,
        model_cfg: Optional[ModelConfig] = None,
        cfg: Optional[ClusterConfig] = None,
        replica_factory=None,
        tier_specs: Optional[Dict[str, TierSpec]] = None,
        tier_spec_factory=None,
    ):
        assert engines, "a cluster needs at least one replica"
        clock = engines[0].clock
        for e in engines:
            assert e.clock is clock, \
                "all replicas must share one VirtualClock (one timeline)"
        super().__init__(
            engines, router, clock=clock, transport=transport,
            timekeeper=timekeeper, model_cfg=model_cfg, cfg=cfg,
            replica_factory=replica_factory, tier_specs=tier_specs,
            tier_spec_factory=tier_spec_factory)

        self._pd = isinstance(router, PDPoolRouter)
        if self._pd:
            assert model_cfg is not None, \
                "pd_pool routing needs model_cfg for KV-transfer sizing"
            self.channel = EmulatedChannel(self.cfg.kv_link_bandwidth,
                                           name="kv-transfer")
            self._mover_ids = itertools.count()
            self._movers: List[threading.Thread] = []
            for i in router.prefill_indices:
                self.replicas[i].on_finish = self._pd_handoff
            for i in router.decode_indices:
                self.replicas[i].on_finish = self._complete
        else:
            for e in self.replicas:
                e.on_finish = self._complete

    # ------------------------------------------------------------ backend --
    def _new_replica(self, idx: int, tier: Optional[str]) -> LLMEngine:
        assert self._replica_factory is not None, \
            "no replica factory: pass an engine explicitly"
        # factory contract: (index, tier) -> LLMEngine, tier None
        # meaning "whatever the config declares for this index"
        engine = self._replica_factory(idx, tier)
        return engine

    def _attach_replica(self, engine: LLMEngine) -> None:
        assert engine.clock is self.clock, \
            "new replica must share the cluster's clock"
        engine.on_finish = self._complete

    def _force_kill(self, idx: int) -> List[Request]:
        return self.replicas[idx].force_kill()

    def _set_slowdown(self, idx: int, factor: Optional[float]) -> bool:
        from repro.cluster.faults import SlowdownPredictor
        runner = self.replicas[idx].runner
        base = SlowdownPredictor.unwrap(runner.predictor)
        if factor is None:
            runner.predictor = base
        else:
            runner.predictor = SlowdownPredictor(base, factor)
        return True

    def crash_replica(self, idx: int, *, on_crash: str = "requeue") -> dict:
        assert not self._pd, "fault injection is not supported for pd_pool"
        return super().crash_replica(idx, on_crash=on_crash)

    # ------------------------------------------------------------- intake --
    def submit(self, req: Request) -> int:
        if self._pd:
            req._disagg_total_new = req.max_new_tokens      # stash for decode
            req.max_new_tokens = 1
        return super().submit(req)

    # --------------------------------------------------- elastic membership --
    def add_replica(self, engine: Optional[LLMEngine] = None,
                    tier: Optional[str] = None) -> int:
        assert not self._pd, "elastic membership is not supported for pd_pool"
        return super().add_replica(engine, tier)

    def drain_replica(self, idx: int) -> None:
        assert not self._pd, "elastic membership is not supported for pd_pool"
        super().drain_replica(idx)

    # ----------------------------------------------------------- pd pools --
    def _pd_handoff(self, finished: List[Request]) -> None:
        """Prefill completed: emulate the KV migration, then place the
        request in the decode pool.  Runs synchronously in the prefill
        replica's step thread — the KV-mover actor registers with the
        Timekeeper *before* that replica can re-enter the barrier, so
        virtual time cannot advance past the transfer's arrival (§4.3)."""
        now = self.clock.now()
        for req in finished:
            kv_bytes = req.context_len * self.model_cfg.kv_bytes_per_token()
            t_visible = self.channel.send(req, now, kv_bytes)
            mover: Optional[TimeJumpClient] = None
            if self.transport is not None:
                mover = TimeJumpClient(
                    self.transport, f"kv-mover-{next(self._mover_ids)}")
            t = threading.Thread(
                target=self._pd_transfer, args=(req, t_visible, mover),
                name="kv-mover", daemon=True)
            t.start()
            self._movers.append(t)

    def _pd_transfer(self, req: Request, t_visible: float,
                     mover: Optional[TimeJumpClient]) -> None:
        try:
            if mover is not None:
                mover.jump_to(t_visible)        # occupy the transfer duration
            req.kv_transfer_time = (t_visible - req.finish_time
                                    if req.finish_time is not None else 0.0)
            # Re-arm for the decode stage: KV arrives whole; the first
            # generated token becomes the last prompt token.
            first_token = req.output_tokens[0] if req.output_tokens else 0
            req.max_new_tokens = max(req._disagg_total_new - 1, 1)
            req.prompt_tokens = list(req.prompt_tokens) + [first_token]
            req.output_tokens = []
            req.num_prefilled = 0
            req.cached_prefix_len = 0
            req.state = RequestState.WAITING
            req.finish_time = None
            req.kv_migrated = True
            with self._submit_lock:
                idx = self.router.route_decode(req, self.replicas,
                                               active=self.active)
                self.replicas[idx].submit(req)
        finally:
            if mover is not None:
                mover.deregister()

    # ---------------------------------------------------------- lifecycle --
    def stop(self) -> None:
        super().stop()
        if self._pd:
            for t in self._movers:
                t.join(timeout=5)

    # ---------------------------------------------------- fault tolerance --
    def snapshot(self) -> bytes:
        """Cluster checkpoint: every replica's deterministic between-steps
        snapshot plus the router's placement state.  (PD pools: requests
        inside an in-flight KV transfer belong to no replica and are not
        captured — checkpoint quiescent clusters or non-PD policies.)"""
        blobs = [e.snapshot() for e in self.replicas]
        router_state = {
            "policy": getattr(self.router, "policy", None),
            "decisions": list(self.router.decisions),
            "sticky": dict(getattr(self.router, "_sticky", {})),
        }
        return pickle.dumps({"replicas": blobs, "router": router_state})


# =========================================================================
# factory
# =========================================================================

def build_cluster(
    model_cfg: ModelConfig,
    engine_cfg: Union[EngineConfig, Sequence[EngineConfig]],
    num_replicas: int,
    *,
    policy: str = "round_robin",
    mode: str = "emulate",
    backend: str = "thread",
    predictor: Optional[RuntimePredictor] = None,
    tiers: Optional[Union[str, Sequence[str]]] = None,
    tier_predictors: Optional[Dict[str, RuntimePredictor]] = None,
    tier_specs: Optional[Dict[str, TierSpec]] = None,
    jitter_cooldown: float = 0.0,
    kv_link_bandwidth: float = 50e9,
    wall: Optional[WallSource] = None,
    router_kwargs: Optional[dict] = None,
    warm_replicas: Optional[int] = None,
    name: str = "cluster",
    transport: str = "tcp",
):
    """Wire N replica engines onto one shared Timekeeper + router.

    ``backend`` picks where replicas run: ``"thread"`` (default) keeps every
    engine in this process on a directly shared clock; ``"process"`` runs
    each replica engine in its own OS process wired to the parent's
    Timekeeper server (``warm_replicas`` pre-spawns standby processes the
    autoscaler can activate without paying process-start wall time mid-run;
    emulate mode only, and ``wall`` must stay host-shared, i.e. None).
    ``transport`` picks the process backend's wire — ``"tcp"`` (framed
    sockets) or ``"shm"`` (shared-memory rings + seqlock clock word,
    :mod:`repro.core.shm_transport`); the thread backend, which has no
    wire, ignores it.

    ``engine_cfg`` may be a single config (homogeneous replicas) or one per
    replica (heterogeneous — e.g. differently-sized prefill/decode pools).
    ``tiers`` makes the pool hardware-heterogeneous: a chip/tier name per
    replica (or one name for all) — each replica's config is re-derived for
    its chip (``chip`` field + KV capacity via
    :func:`~repro.cluster.tiers.tier_engine_cfg`), its predictor follows the
    chip, and routing/autoscaling see per-tier throughput weights and
    $/replica-second.  ``tier_predictors`` overrides the predictor per tier
    (benchmarks inject StaticPredictors); ``tier_specs`` injects
    pre-computed :class:`TierSpec` objects so an experiment can share the
    exact same tier arithmetic with the DES baseline.
    ``wall`` injects a deterministic wall source for reproducibility tests.
    ``mode`` is "emulate" (time-warp, the default) or "sleep" (strawman).
    """
    from repro.serving.stack import default_predictor

    cfgs = ([engine_cfg] * num_replicas
            if isinstance(engine_cfg, EngineConfig) else list(engine_cfg))
    assert len(cfgs) == num_replicas, \
        f"need {num_replicas} engine configs, got {len(cfgs)}"
    if isinstance(tiers, str):
        tiers = [tiers] * num_replicas
    tiers = list(tiers) if tiers is not None else None
    if tiers is not None:
        assert len(tiers) == num_replicas, \
            f"need {num_replicas} tier names, got {len(tiers)}"

    router = make_router(policy, num_replicas, **(router_kwargs or {}))

    def resolve_cfg(i: int, tier: Optional[str]) -> EngineConfig:
        # autoscale-added replicas (i >= num_replicas) clone the last
        # declared config; a tier re-derives chip + KV capacity
        cfg = cfgs[min(i, len(cfgs) - 1)]
        return cfg if tier is None else tier_engine_cfg(cfg, tier, model_cfg)

    def resolve_pred(cfg: EngineConfig,
                     tier: Optional[str]) -> RuntimePredictor:
        if tier is not None and tier_predictors and tier in tier_predictors:
            return tier_predictors[tier]
        return predictor or default_predictor(model_cfg, cfg)

    def default_tier(i: int) -> Optional[str]:
        return None if tiers is None else tiers[min(i, len(tiers) - 1)]

    def spec_factory(tier: str) -> TierSpec:
        # base config: the first replica declared on this tier (so a
        # heterogeneous engine_cfg list yields specs matching the replicas
        # that actually run the tier); unknown tiers — autoscaler candidates
        # the initial pool never used — clone the last declared config
        base = cfgs[-1]
        if tiers is not None and tier in tiers:
            base = cfgs[min(tiers.index(tier), len(cfgs) - 1)]
        cfg = tier_engine_cfg(base, tier, model_cfg)
        return make_tier_spec(tier, cfg, predictor=resolve_pred(cfg, tier))

    cluster_cfg = ClusterConfig(kv_link_bandwidth=kv_link_bandwidth,
                                tiers=tiers)

    if backend == "process":
        from .process_backend import build_process_cluster

        assert mode == "emulate", \
            "the process backend is emulate-only (sleep/real stay in-process)"
        assert wall is None, (
            "the process backend shares the host wall clock (time.time) "
            "across processes; a custom wall source cannot cross them")
        assert policy != "pd_pool", \
            "pd_pool routing is not supported on the process backend"
        return build_process_cluster(
            model_cfg=model_cfg, router=router, num_replicas=num_replicas,
            resolve_cfg=resolve_cfg, resolve_pred=resolve_pred,
            default_tier=default_tier, cluster_cfg=cluster_cfg,
            tier_specs=tier_specs, tier_spec_factory=spec_factory,
            jitter_cooldown=jitter_cooldown,
            warm_replicas=warm_replicas, name=name, transport=transport)

    assert backend == "thread", \
        f"unknown cluster backend {backend!r} (thread | process)"
    assert warm_replicas is None, \
        "warm_replicas only applies to the process backend"

    if mode == "emulate":
        tk = Timekeeper(clock=VirtualClock(wall), jitter_cooldown=jitter_cooldown)
        transport = LocalTransport(tk)

        def make_engine(i: int, tier: Optional[str] = None) -> LLMEngine:
            tier = tier if tier is not None else default_tier(i)
            cfg = resolve_cfg(i, tier)
            pred = resolve_pred(cfg, tier)
            chip = get_chip(cfg.chip)
            n_dev = cfg.tp * cfg.pp
            devices = VirtualDeviceContext(n_dev, chip)
            kv_pool = int(cfg.num_blocks * cfg.block_size
                          * model_cfg.kv_bytes_per_token())
            weights = model_cfg.param_count() * model_cfg.dtype_bytes
            client = TimeJumpClient(transport, f"{name}-r{i}-worker")
            runner = TimeWarpModelRunner(
                pred, client, devices=devices,
                weight_bytes=weights, kv_pool_bytes=kv_pool)
            return LLMEngine(cfg, runner, tk.clock, name=f"{name}-r{i}")

        engines = [make_engine(i) for i in range(num_replicas)]
        return Cluster(engines, router, transport=transport, timekeeper=tk,
                       model_cfg=model_cfg, cfg=cluster_cfg,
                       replica_factory=make_engine,
                       tier_specs=tier_specs, tier_spec_factory=spec_factory)

    if mode == "sleep":
        clock = VirtualClock(wall)

        def make_engine(i: int, tier: Optional[str] = None) -> LLMEngine:
            tier = tier if tier is not None else default_tier(i)
            cfg = resolve_cfg(i, tier)
            runner = SleepModelRunner(resolve_pred(cfg, tier), clock)
            return LLMEngine(cfg, runner, clock, name=f"{name}-r{i}")

        engines = [make_engine(i) for i in range(num_replicas)]
        return Cluster(engines, router, model_cfg=model_cfg, cfg=cluster_cfg,
                       replica_factory=make_engine,
                       tier_specs=tier_specs, tier_spec_factory=spec_factory)

    raise ValueError(f"unknown cluster mode {mode!r} (emulate | sleep)")
