"""Deterministic fault injection: failure as a first-class scenario event.

The paper's §4.2.1 guarantee is "never incorrect, only slower" — but a
guarantee exercised only on the happy path is a hypothesis, not a result.
This module makes failure part of the declarative scenario surface:

* :class:`FaultSpec` — one frozen, JSON-serializable fault event
  (``crash`` / ``straggler`` / ``spot_reclaim``) with a virtual-time
  timestamp, carried on ``Scenario.faults`` through the same strict codec
  as every other spec field;
* :class:`FaultInjector` — a Timekeeper **actor** that jumps virtual time
  to each fault's timestamp and applies it to a live cluster.  Because the
  barrier's minimum-target rule releases the injector's jump first, every
  other actor is either blocked mid-jump with a later target or between
  jumps (in which case the barrier cannot resolve at all), so cluster
  mutations made between the injector's jumps are race-free by
  construction — the same argument that makes the autoscaler's scripted
  membership changes deterministic;
* :class:`SlowdownPredictor` — a multiplicative wrapper over any runtime
  predictor, the straggler mechanism shared by the thread backend, the
  process backend (applied child-side via a control RPC), and the DES.

Every applied event is recorded in :attr:`FaultInjector.events` using the
fault's **nominal** spec time (not a clock read), so the log is
float-exactly comparable across backends; :mod:`repro.des.simulator`
mirrors each event kind (CRASH/STRAGGLE/RECLAIM/RESPAWN) and produces an
identical log, which ``repro.scenario.compare`` asserts.

Determinism caveat (documented in ``docs/scenarios.md``): fault times must
not coincide exactly with a step-completion or arrival instant — a
same-instant completion races the injector in the emulator while the DES
orders both by its event counter.  Presets keep fault times off the step
grid.
"""

from __future__ import annotations

import heapq
import itertools
import threading
from dataclasses import dataclass
from typing import List, Optional, Tuple

__all__ = ["FAULT_KINDS", "ON_CRASH_POLICIES", "FaultSpec",
           "SlowdownPredictor", "FaultInjector"]

#: Supported fault kinds (FaultSpec.kind).
FAULT_KINDS = ("crash", "straggler", "spot_reclaim")

#: What happens to a crashed replica's in-flight requests.
ON_CRASH_POLICIES = ("requeue", "fail")


@dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault, in virtual seconds from the run's start.

    ``kind="crash"`` — SIGKILL-equivalent loss of replica ``replica`` at
    ``time_s``: all KV/prefix state is lost and every in-flight request is
    either re-routed through the router (``on_crash="requeue"``, progress
    zeroed, original arrival time kept) or terminally failed
    (``on_crash="fail"``).  ``recover=True`` respawns one replacement
    replica (warm-pool activation on the process backend) after
    ``respawn_delay_s``.

    ``kind="straggler"`` — replica ``replica``'s predictor is wrapped so
    every step takes ``slowdown``× as long, starting with the first step
    *scheduled* at or after ``time_s`` (steps already in flight keep their
    computed duration — identical semantics in the emulator, where the
    duration was fixed before the injector's barrier round, and in the
    DES, where the STEP_DONE event is already on the heap).  If
    ``duration_s`` is set the slowdown is removed at ``time_s +
    duration_s``; otherwise it persists.

    ``kind="spot_reclaim"`` — every active replica of hardware tier
    ``tier`` receives a reclamation notice at ``time_s``: each is drained
    (no new placements, in-flight work continues) and any replica still
    not fully drained at ``time_s + notice_s`` is killed with ``crash``
    semantics.  Cost accounting (``replica_seconds`` / ``cost_dollars``)
    stops at the drain/kill boundary exactly as for autoscaler drains.
    With ``recover=True`` each killed replica respawns after
    ``respawn_delay_s`` on ``respawn_tier`` (default: its own tier).
    """

    kind: str = "crash"                 # crash | straggler | spot_reclaim
    time_s: float = 0.0                 # virtual seconds from run start
    replica: int = 0                    # victim index (crash / straggler)
    on_crash: str = "requeue"           # requeue | fail
    slowdown: float = 4.0               # straggler step-time multiplier
    duration_s: Optional[float] = None  # straggler window (None = forever)
    tier: Optional[str] = None          # spot_reclaim: the vanishing tier
    notice_s: float = 0.0               # spot_reclaim: drain notice window
    recover: bool = False               # respawn a replacement replica
    respawn_delay_s: float = 0.5        # modeled respawn/provision delay
    respawn_tier: Optional[str] = None  # tier of the replacement (None=same)

    def validate(self, *, path: str = "fault") -> None:
        from repro.scenario.spec import SpecError
        if self.kind not in FAULT_KINDS:
            raise SpecError(f"{path}.kind: invalid value {self.kind!r} "
                            f"(choose from {sorted(FAULT_KINDS)})")
        if self.time_s < 0:
            raise SpecError(f"{path}.time_s: must be >= 0")
        if self.on_crash not in ON_CRASH_POLICIES:
            raise SpecError(
                f"{path}.on_crash: invalid value {self.on_crash!r} "
                f"(choose from {sorted(ON_CRASH_POLICIES)})")
        if self.replica < 0:
            raise SpecError(f"{path}.replica: must be >= 0")
        if self.kind == "straggler":
            if self.slowdown <= 0:
                raise SpecError(f"{path}.slowdown: must be > 0")
            if self.duration_s is not None and self.duration_s <= 0:
                raise SpecError(f"{path}.duration_s: must be > 0 (or null)")
        if self.kind == "spot_reclaim":
            if self.tier is None:
                raise SpecError(f"{path}.tier: required for spot_reclaim")
            if self.notice_s < 0:
                raise SpecError(f"{path}.notice_s: must be >= 0")
        if self.recover and self.respawn_delay_s < 0:
            raise SpecError(f"{path}.respawn_delay_s: must be >= 0")


class SlowdownPredictor:
    """``predict_step`` of ``inner``, with every time component scaled by
    ``factor`` — the straggler mechanism (compute contention, thermal
    throttling, a noisy neighbor) applied at the predictor layer so the
    emulator's virtual timeline and the DES agree exactly."""

    def __init__(self, inner, factor: float):
        # collapse nested wraps so repeated apply/remove stays exact
        if isinstance(inner, SlowdownPredictor):
            inner = inner.inner
        self.inner = inner
        self.factor = float(factor)

    def predict_step(self, batch):
        est = self.inner.predict_step(batch)
        f = self.factor
        out = type(est)(total=est.total * f)
        for name in ("compute", "memory", "collective", "overhead"):
            setattr(out, name, getattr(est, name) * f)
        for name in ("flops", "hbm_bytes", "collective_bytes"):
            setattr(out, name, getattr(est, name))
        return out

    @staticmethod
    def unwrap(predictor):
        """The base predictor, whether or not it is currently wrapped."""
        if isinstance(predictor, SlowdownPredictor):
            return predictor.inner
        return predictor


# internal event actions (heap entries are (time, seq, action, payload))
_CRASH = "crash"
_STRAGGLE = "straggle"
_STRAGGLE_END = "straggle_end"
_RECLAIM = "reclaim"
_RECLAIM_KILL = "reclaim_kill"
_RESPAWN = "respawn"


def schedule_of(faults) -> list:
    """The static (time, seq, action, spec) heap a fault list expands to —
    shared with the DES so both sides process events in the same order.
    Dynamic follow-ups (reclaim kills with resolved victims, respawns) are
    pushed by the processor at apply time."""
    heap: list = []
    seq = itertools.count()
    for spec in faults:
        t = float(spec.time_s)
        if spec.kind == "crash":
            heapq.heappush(heap, (t, next(seq), _CRASH, spec))
        elif spec.kind == "straggler":
            heapq.heappush(heap, (t, next(seq), _STRAGGLE, spec))
            if spec.duration_s is not None:
                heapq.heappush(heap, (t + spec.duration_s, next(seq),
                                      _STRAGGLE_END, spec))
        elif spec.kind == "spot_reclaim":
            heapq.heappush(heap, (t, next(seq), _RECLAIM, spec))
        else:  # pragma: no cover - validated upstream
            raise ValueError(f"unknown fault kind {spec.kind!r}")
    return heap


class FaultInjector:
    """A Timekeeper actor that applies a fault schedule to a live cluster.

    Lifecycle mirrors :class:`~repro.cluster.autoscaler.Autoscaler`:
    :meth:`arm` registers the injector's TimeJump actor (call it before any
    other actor can advance virtual time, so the schedule anchors at the
    run's origin); :meth:`start` begins processing; :meth:`stop`
    deregisters the actor from outside — a jump blocked mid-barrier then
    raises ``KeyError`` client-side (the established force-departure
    mechanism) and the loop exits.

    After the run, :attr:`events` holds the applied fault log in nominal
    spec times (tuples of primitives, float-exactly comparable across
    backends), :attr:`requeued` / :attr:`failed` count affected requests,
    :attr:`recoveries` holds ``(fault_time, respawn_time)`` pairs, and
    :attr:`respawn_scaleups` holds ``(virtual_time, tier)`` entries to
    merge into the autoscaler's scale-up audit.
    """

    def __init__(self, cluster, faults, *, name: str = "chaos"):
        self.cluster = cluster
        self.faults = list(faults)
        self.name = name
        self.events: List[tuple] = []
        self.requeued = 0
        self.failed = 0
        self.recoveries: List[Tuple[float, float]] = []
        self.respawn_scaleups: List[Tuple[float, Optional[str]]] = []
        self._heap = schedule_of(self.faults)
        self._seq = itertools.count(len(self._heap) + len(self.faults))
        self._client = None
        self._origin: Optional[float] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ------------------------------------------------------------ lifecycle
    def arm(self) -> None:
        """Register the injector's actor (barrier membership) without
        processing yet.  Until :meth:`start`, the registered-but-idle actor
        pins the barrier, so no virtual time can pass before the schedule's
        origin is anchored."""
        if self._client is not None or not self._heap:
            return
        from repro.core.client import TimeJumpClient
        self._client = TimeJumpClient(self.cluster.transport,
                                      f"{self.name}-injector")
        self._origin = self.cluster.clock.now()

    def start(self) -> None:
        if not self._heap or self._thread is not None:
            return
        self.arm()
        self._thread = threading.Thread(
            target=self._loop, name=f"{self.name}-injector", daemon=True)
        self._thread.start()

    def join(self, timeout: float = 30.0) -> None:
        """Block until the schedule is fully processed (post-run drain).

        With the dispatcher deregistered and every idle engine parked, the
        injector's remaining jumps resolve against the barrier's surviving
        actors (or instantly, as the lone actor), so trailing faults — a
        ``straggle_end`` landing after the last completion, a late respawn —
        apply **deterministically** instead of racing :meth:`stop`.  The DES
        drains its event heap unconditionally; this is the emulator-side
        equivalent, and what keeps the fault logs comparable."""
        t = self._thread
        if t is not None:
            t.join(timeout=timeout)

    def stop(self) -> None:
        self._stop.set()
        if self._client is not None:
            try:
                self._client.deregister()   # unwedge a blocked jump
            except Exception:
                pass
        if self._thread is not None:
            self._thread.join(timeout=30)
            self._thread = None

    # ----------------------------------------------------------- processing
    def _loop(self) -> None:
        from repro.core.client import TransportClosed
        try:
            while self._heap and not self._stop.is_set():
                t, _, action, payload = heapq.heappop(self._heap)
                self._client.jump_to(self._origin + t)
                if self._stop.is_set():
                    break
                self._apply(t, action, payload)
        except (KeyError, RuntimeError, TransportClosed):
            pass                            # departed mid-jump (shutdown)
        finally:
            if self._client is not None:
                try:
                    self._client.deregister()
                except Exception:
                    pass

    def _apply(self, t: float, action: str, payload) -> None:
        if action == _CRASH:
            self._apply_crash(t, payload.replica, payload.on_crash,
                              log_kind="crash", recover=payload.recover,
                              respawn_delay=payload.respawn_delay_s,
                              respawn_tier=payload.respawn_tier)
        elif action == _STRAGGLE:
            self.cluster.set_replica_slowdown(payload.replica,
                                              payload.slowdown)
            self.events.append(("straggle", t, payload.replica,
                                payload.slowdown))
        elif action == _STRAGGLE_END:
            self.cluster.set_replica_slowdown(payload.replica, None)
            self.events.append(("straggle_end", t, payload.replica))
        elif action == _RECLAIM:
            self._apply_reclaim(t, payload)
        elif action == _RECLAIM_KILL:
            spec, victims = payload
            for idx in victims:
                self._apply_crash(t, idx, spec.on_crash,
                                  log_kind="reclaim_kill",
                                  recover=spec.recover,
                                  respawn_delay=spec.respawn_delay_s,
                                  respawn_tier=spec.respawn_tier)
        elif action == _RESPAWN:
            tier, fault_t = payload
            new_idx = self.cluster.add_replica(tier=tier)
            self.events.append(("respawn", t, tier, new_idx))
            self.recoveries.append((fault_t, t))
            self.respawn_scaleups.append((self.cluster.clock.now(), tier))

    def _apply_crash(self, t: float, idx: int, on_crash: str, *,
                     log_kind: str, recover: bool, respawn_delay: float,
                     respawn_tier: Optional[str]) -> None:
        if idx >= len(self.cluster.replicas):
            self.events.append((log_kind, t, idx, 0, 0, False))
            return
        res = self.cluster.crash_replica(idx, on_crash=on_crash)
        self.events.append((log_kind, t, idx,
                            res["requeued"], res["failed"], res["crashed"]))
        self.requeued += res["requeued"]
        self.failed += res["failed"]
        if recover and res["crashed"]:
            tier = respawn_tier if respawn_tier is not None else res["tier"]
            heapq.heappush(self._heap, (t + respawn_delay, next(self._seq),
                                        _RESPAWN, (tier, t)))

    def _apply_reclaim(self, t: float, spec: FaultSpec) -> None:
        cluster = self.cluster
        victims = [i for i in list(cluster.active)
                   if cluster.replica_tiers[i] == spec.tier]
        if victims and len(victims) >= len(cluster.active):
            victims = victims[1:]           # never reclaim the whole pool
        self.events.append(("reclaim", t, spec.tier, tuple(victims)))
        for idx in victims:
            cluster.drain_replica(idx)
        if victims:
            heapq.heappush(self._heap, (t + spec.notice_s, next(self._seq),
                                        _RECLAIM_KILL, (spec, victims)))
