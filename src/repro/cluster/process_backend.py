"""Process-mode cluster runtime: replicas as OS processes (paper §4–5).

This is the deployment shape the paper's coordination protocol exists for:
every replica's :class:`~repro.serving.engine.LLMEngine` runs in its **own
OS process**, its worker actor wired to the parent's Timekeeper server.
The engine, runner, and :class:`~repro.core.client.TimeJumpClient` code are
byte-identical to the in-process thread backend — only the
``ActorTransport`` underneath changes.

Two wire transports carry the same protocol (``transport=`` on
:func:`build_process_cluster`):

* ``"tcp"`` — framed TCP: :class:`~repro.core.transport.TimekeeperServer`
  + ``SocketTransport`` for the time plane, a pickle-framed socket per
  replica for the control plane.
* ``"shm"`` — shared memory (:mod:`repro.core.shm_transport`): a seqlock
  clock word makes every child clock read a zero-syscall load and epoch
  broadcast a single word write; per-replica SPSC rings carry the identical
  fan-in and control ops.

Both planes run over the same duck-typed channel surface
(``send_obj``/``recv_obj``/``mark_peer_dead``/``close`` —
:class:`SocketChannel` here, :class:`~repro.core.shm_transport.ShmChannel`
there), so ``ProcessReplicaHandle`` and ``_ReplicaServer`` run unchanged
protocol logic over either.

Topology (one parent, N children)::

    parent process                          child process i
    ──────────────                          ───────────────
    Timekeeper server ◄───tcp | shm─────►  ActorTransport ── TimeJumpClient
    LocalTransport (dispatcher, think        │                     │
      actors, autoscaler ticks)              │              TimeWarpModelRunner
    ProcessCluster                           │                     │
      └─ ProcessReplicaHandle ◄──control──► _ReplicaServer ─── LLMEngine
              (route/submit/probe/drain)       (command loop)

Control protocol (length-prefixed pickle frames, one channel per replica;
requests carry a ``rid`` echoed by the reply):

==================  =====================================================
``hello``           child → parent: announce replica index (handshake)
``start_engine``    activate a warm child: ship the pickled engine spec
                    (model/engine config + predictor); child builds and
                    starts the engine
``submit``          one pickled Request; the ack is sent only after
                    ``engine.submit`` returned, i.e. after the child's
                    worker actor re-registered with the Timekeeper — the
                    dispatcher's next TIMEJUMP cannot resolve a barrier
                    without the request's replica (same causality rule the
                    thread backend gets from its synchronous unpark)
``probe``           racy ReplicaView read: outstanding tokens/requests and
                    (optionally) the radix prefix-match length
``complete``        child → parent: pickled finished Requests.  The child's
                    engine blocks in ``on_finish`` until ``complete_ack``
                    comes back, so the parent runs every completion
                    listener — think-time actor registration included —
                    **before the finishing replica re-enters the barrier**
                    (§4.3 over the wire; closed-loop sessions build on it)
``retire``          drain final step (fire-and-forget): the child's worker
                    actor deregisters from the Timekeeper — park, then a
                    full departure with an epoch-bump broadcast
``stop_engine``     stop the engine loop (cluster stop)
``shutdown``        child exits
==================  =====================================================

Drain over the wire is therefore: stop routing (parent) → in-flight
completion frames drain the parent's bookkeeping → ``retire`` frame →
``deregister`` on the Timekeeper socket.

Children are spawned with the ``spawn`` start method (never ``fork``: the
parent runs engine/reader threads and may have JAX loaded).  Because a
process spawn costs real wall time — which, under Eq. 1, would leak into
virtual latencies mid-run — the cluster pre-spawns a **warm pool**
(``warm_replicas``): standby shell processes that are connected but
engine-less; ``add_replica`` activates one with a single ``start_engine``
frame (milliseconds), so autoscaling pays only the *modeled* provisioning
delay, exactly like the thread backend.
"""

from __future__ import annotations

import itertools
import multiprocessing
import pickle
import queue
import socket
import struct
import threading
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

from repro.core.client import LocalTransport
from repro.core.transport import (FrameWriter, TimekeeperServer,
                                  TransportClosed, pack_frame)
from repro.models.config import ModelConfig
from repro.serving.request import Request
from repro.serving.scheduler import EngineConfig

from .cluster import ClusterBase, ClusterConfig
from .router import Router
from .tiers import TierSpec

__all__ = ["ProcessCluster", "ProcessReplicaHandle", "SocketChannel",
           "build_process_cluster"]

_LEN = struct.Struct(">I")
_HANDSHAKE_TIMEOUT = 120.0      # spawn + interpreter boot + numpy import
_RPC_TIMEOUT = 60.0
_ACK_TIMEOUT = 60.0

TRANSPORTS = ("tcp", "shm")


def _send_obj(writer: FrameWriter, obj: dict) -> None:
    """Queue one pickled control frame on the socket's write combiner.

    All control-plane writes on a socket share one :class:`FrameWriter`, so
    bursts — completion frames from several finishing requests, acks racing
    replies — coalesce into a single ``sendmsg`` flush instead of paying one
    ``sendall`` syscall (plus lock convoy) each.
    """
    writer.send(pack_frame(pickle.dumps(obj)))


def _recv_obj(sock: socket.socket) -> Optional[dict]:
    buf = b""
    while len(buf) < _LEN.size:
        try:
            chunk = sock.recv(_LEN.size - len(buf))
        except OSError:
            return None
        if not chunk:
            return None
        buf += chunk
    (length,) = _LEN.unpack(buf)
    body = b""
    while len(body) < length:
        try:
            chunk = sock.recv(length - len(body))
        except OSError:
            return None
        if not chunk:
            return None
        body += chunk
    return pickle.loads(body)


class SocketChannel:
    """Control channel over one TCP socket (the duck type ``ShmChannel``
    mirrors): ``send_obj`` raises :class:`OSError` on a dead peer,
    ``recv_obj`` returns None at EOF.  ``mark_peer_dead`` is a no-op — the
    kernel delivers EOF for a SIGKILLed peer on its own."""

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self.writer = FrameWriter(sock)

    def send_obj(self, obj: dict) -> None:
        _send_obj(self.writer, obj)

    def recv_obj(self, timeout: Optional[float] = None) -> Optional[dict]:
        if timeout is not None:
            self.sock.settimeout(timeout)
            try:
                return _recv_obj(self.sock)
            finally:
                self.sock.settimeout(None)
        return _recv_obj(self.sock)

    def mark_peer_dead(self) -> None:
        pass

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass


@dataclass
class _EngineSpec:
    """Everything a child needs to build its replica engine (all picklable)."""
    model_cfg: ModelConfig
    engine_cfg: EngineConfig
    predictor: object
    name: str
    tier: Optional[str] = None


# =========================================================================
# child side
# =========================================================================

class _ReplicaServer:
    """Runs inside the child: one engine + the control-channel command loop.

    Transport-agnostic: ``chan`` is any control channel (socket or shm) and
    ``transport_factory`` builds the matching ``ActorTransport`` lazily, at
    engine activation — warm standbys stay engine-less and transport-less.
    """

    def __init__(self, chan, transport_factory: Callable[[], object],
                 index: int):
        self.chan = chan
        self.transport_factory = transport_factory
        self.index = index
        self.engine = None
        self.transport = None
        self.worker_client = None
        self._ack_events: Dict[int, threading.Event] = {}
        self._ack_lock = threading.Lock()
        self._cid = itertools.count()
        self._cmd_q: "queue.Queue[Optional[dict]]" = queue.Queue()

    # ------------------------------------------------------------ engine --
    def _build_engine(self, spec: _EngineSpec) -> None:
        from repro.core.client import TimeJumpClient
        from repro.core.emulation import VirtualDeviceContext
        from repro.core.hardware import get_chip
        from repro.serving.engine import LLMEngine
        from repro.serving.model_runner import TimeWarpModelRunner

        if self.transport is None:
            self.transport = self.transport_factory()
        cfg = spec.engine_cfg
        chip = get_chip(cfg.chip)
        n_dev = cfg.tp * cfg.pp
        devices = VirtualDeviceContext(n_dev, chip)
        kv_pool = int(cfg.num_blocks * cfg.block_size
                      * spec.model_cfg.kv_bytes_per_token())
        weights = spec.model_cfg.param_count() * spec.model_cfg.dtype_bytes
        self.worker_client = TimeJumpClient(
            self.transport, f"{spec.name}-worker")
        runner = TimeWarpModelRunner(
            spec.predictor, self.worker_client, devices=devices,
            weight_bytes=weights, kv_pool_bytes=kv_pool)
        self.engine = LLMEngine(cfg, runner, self.transport.clock,
                                name=spec.name)
        # Completion frames flow back BEFORE the engine's next barrier
        # round: on_finish runs in the step thread and blocks on the ack.
        self.engine.on_finish = self._on_finish
        self.engine.start()

    def _on_finish(self, finished: List[Request]) -> None:
        cid = next(self._cid)
        ev = threading.Event()
        with self._ack_lock:
            self._ack_events[cid] = ev
        try:
            self.chan.send_obj({"op": "complete", "cid": cid,
                                "reqs": finished})
        except OSError:
            return                        # parent died: nothing to wait for
        # Block the step thread until the parent has run every completion
        # listener (think-actor registration included): the worker actor
        # cannot re-enter the barrier before the follow-up work exists.
        ev.wait(timeout=_ACK_TIMEOUT)
        with self._ack_lock:
            self._ack_events.pop(cid, None)

    # -------------------------------------------------------------- loop --
    def run(self) -> None:
        """Reader (main thread) + command executor (worker thread).

        Acks are dispatched by the reader directly so a slow command — e.g.
        ``stop_engine`` joining a step thread that is itself blocked on a
        ``complete_ack`` — can never dam the ack behind it.
        """
        cmd_thread = threading.Thread(
            target=self._cmd_loop, name=f"replica-{self.index}-cmds",
            daemon=True)
        cmd_thread.start()
        try:
            while True:
                msg = self.chan.recv_obj()
                if msg is None:
                    break                    # parent gone
                if msg["op"] == "complete_ack":
                    with self._ack_lock:
                        ev = self._ack_events.get(msg["cid"])
                    if ev is not None:
                        ev.set()
                    continue
                if msg["op"] == "shutdown":
                    break
                self._cmd_q.put(msg)
        finally:
            # Release any step thread still waiting on an ack, then tear
            # down: engine first (deregisters its actor), sockets last.
            with self._ack_lock:
                for ev in self._ack_events.values():
                    ev.set()
            self._cmd_q.put(None)
            if self.engine is not None:
                try:
                    self.engine.stop()
                except (TransportClosed, KeyError, RuntimeError, OSError):
                    pass
            if self.worker_client is not None:
                try:
                    self.worker_client.deregister()
                except (TransportClosed, KeyError, RuntimeError, OSError):
                    pass
            if self.transport is not None:
                self.transport.close()
            self.chan.close()

    def _cmd_loop(self) -> None:
        while True:
            msg = self._cmd_q.get()
            if msg is None:
                return
            op, rid = msg["op"], msg.get("rid")
            try:
                reply = self._execute(op, msg)
            except (TransportClosed, OSError) as e:
                reply = {"op": "error", "error": f"replica transport: {e}"}
            except Exception as e:  # noqa: BLE001 — ship it to the parent
                reply = {"op": "error", "error": f"{type(e).__name__}: {e}"}
            if rid is None:
                continue                     # fire-and-forget op
            reply["rid"] = rid
            try:
                self.chan.send_obj(reply)
            except OSError:
                return

    def _execute(self, op: str, msg: dict) -> dict:
        if op == "start_engine":
            self._build_engine(msg["spec"])
            return {"op": "ack"}
        if op == "submit":
            self.engine.submit(msg["req"])
            return {"op": "ack"}
        if op == "probe":
            tokens = msg.get("tokens")
            return {
                "op": "probe_ack",
                "num_outstanding": self.engine.num_outstanding(),
                "outstanding_tokens": self.engine.outstanding_tokens(),
                "prefix_match": (self.engine.prefix_match_len(tokens)
                                 if tokens is not None else 0),
            }
        if op == "stats":
            return {"op": "stats_ack", "stats": self.engine.stats()}
        if op == "configure":
            self.engine.set_audit(msg["audit"])
            return {"op": "ack"}
        if op == "step_log":
            return {"op": "step_log_ack", "log": list(self.engine.step_log)}
        if op == "slowdown":
            from repro.cluster.faults import SlowdownPredictor
            runner = self.engine.runner
            base = SlowdownPredictor.unwrap(runner.predictor)
            factor = msg["factor"]
            runner.predictor = (base if factor is None
                                else SlowdownPredictor(base, factor))
            return {"op": "ack"}
        if op == "retire":
            # drain final step: park semantics then the full departure —
            # TimeJumpClient.park is a no-op once deregistered, so the
            # engine loop's idle parking stays harmless afterwards
            self.engine.retire()
            return {"op": "ack"}
        if op == "stop_engine":
            self.engine.stop()
            return {"op": "ack"}
        return {"op": "error", "error": f"unknown op {op!r}"}


def _replica_main(ctrl_desc, tk_desc, index: int) -> None:
    """Child process entry point (multiprocessing ``spawn`` target).

    Descriptors are ``(kind, payload)`` pairs: ``("tcp", address)`` dials
    sockets; ``("shm", ShmEndpointSpec)`` attaches the pre-created segment —
    the control channel and the timekeeper ring pair live in the same
    endpoint, so both descriptors carry the same spec.
    """
    kind, payload = ctrl_desc
    if kind == "shm":
        from repro.core.shm_transport import ShmEndpoint
        endpoint = ShmEndpoint.attach(payload)
        chan = endpoint.child_channel()
        transport_factory = endpoint.child_transport
    else:
        ctrl = socket.create_connection(tuple(payload))
        ctrl.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        chan = SocketChannel(ctrl)
        tk_addr = tuple(tk_desc[1])

        def transport_factory():
            from repro.core.transport import SocketTransport
            return SocketTransport(tk_addr)

    server = _ReplicaServer(chan, transport_factory, index)
    chan.send_obj({"op": "hello", "replica": index})
    server.run()


# =========================================================================
# parent side
# =========================================================================

class ProcessReplicaHandle:
    """Parent-side proxy for one replica child process.

    Satisfies the cluster's replica-handle protocol (``submit`` +
    ReplicaView probes + drain/lifecycle hooks); every probe is a real RPC
    into the child's engine counters, so routing policies see the same
    racy-read semantics they see on the thread backend.  ``in_flight_ids``
    is parent-side bookkeeping (submits minus completion frames) — exact,
    because completions are the parent's own observation point.

    ``reclaim`` (shm transport) releases the child's shared-memory segment
    name once the child is gone — called after a graceful shutdown AND after
    a SIGKILL's ledger drain, so crash faults cannot leak segments.
    """

    def __init__(self, index: int, chan, proc, *,
                 reclaim: Optional[Callable[[], None]] = None):
        self.index = index
        self.chan = chan
        self.proc = proc
        self._reclaim = reclaim
        self.name = f"replica-{index}"
        self.on_complete: Optional[Callable[[List[Request]], None]] = None
        self._replies: Dict[int, "queue.Queue[dict]"] = {}
        self._replies_lock = threading.Lock()
        self._rid = itertools.count()
        # request_id -> the parent's Request copy: submits minus completion
        # frames.  Keeping the object (not just the id) is the crash-recovery
        # ledger — after a SIGKILL the child's progressed copies are gone,
        # and these are what gets requeued/failed.
        self._in_flight: Dict[int, Request] = {}
        self._in_flight_lock = threading.Lock()
        self.activated = False
        self.retired = False
        self.stopped = False
        self._stats_cache: Optional[dict] = None
        self._step_log_cache: Optional[list] = None
        self._closed = False
        self._reader = threading.Thread(
            target=self._read_loop, name=f"replica-{index}-reader",
            daemon=True)
        self._reader.start()

    # ------------------------------------------------------------ plumbing --
    def _read_loop(self) -> None:
        # try/finally: the fail-fast cleanup must run even if a completion
        # listener raises out of on_complete — a dead reader that left
        # _closed unset would turn every later RPC into a full-timeout
        # stall instead of an immediate TransportClosed.
        try:
            while True:
                msg = self.chan.recv_obj()
                if msg is None:
                    break
                if msg["op"] == "complete":
                    finished = msg["reqs"]
                    with self._in_flight_lock:
                        for r in finished:
                            self._in_flight.pop(r.request_id, None)
                    try:
                        if self.on_complete is not None:
                            self.on_complete(finished)
                    finally:
                        # The ack releases the child's step thread:
                        # listeners have run, follow-up actors are
                        # registered, the replica may re-enter the barrier.
                        try:
                            self.chan.send_obj({"op": "complete_ack",
                                                "cid": msg["cid"]})
                        except OSError:
                            pass
                    continue
                rid = msg.get("rid")
                if rid is None:
                    continue
                with self._replies_lock:
                    q = self._replies.get(rid)
                if q is not None:
                    q.put(msg)
        finally:
            self._closed = True
            with self._replies_lock:
                pending = list(self._replies.values())
            for q in pending:
                q.put({"op": "error", "error": "replica connection closed"})

    def _rpc(self, msg: dict, timeout: float = _RPC_TIMEOUT) -> dict:
        if self._closed:
            raise TransportClosed(f"{self.name}: connection closed")
        rid = next(self._rid)
        msg["rid"] = rid
        q: "queue.Queue[dict]" = queue.Queue(maxsize=1)
        with self._replies_lock:
            self._replies[rid] = q
        try:
            try:
                self.chan.send_obj(msg)
            except OSError as e:
                raise TransportClosed(f"{self.name}: {e}") from None
            try:
                reply = q.get(timeout=timeout)
            except queue.Empty:
                raise TransportClosed(
                    f"{self.name}: no reply to {msg['op']!r} within "
                    f"{timeout}s") from None
        finally:
            with self._replies_lock:
                self._replies.pop(rid, None)
        if reply["op"] == "error":
            raise RuntimeError(f"{self.name}: {reply['error']}")
        return reply

    def _send_oneway(self, msg: dict) -> None:
        try:
            self.chan.send_obj(msg)
        except OSError:
            pass

    # ------------------------------------------------------------ replica --
    def activate(self, spec: _EngineSpec) -> None:
        self._rpc({"op": "start_engine", "spec": spec})
        self.activated = True
        self.name = spec.name

    def submit(self, req: Request) -> None:
        """Ship one request; returns once the child's engine enqueued it and
        its worker actor is back on the Timekeeper barrier (the submit-ack
        is the cross-process equivalent of the thread backend's synchronous
        unpark — without it the dispatcher's next jump could skip the
        request's processing entirely)."""
        with self._in_flight_lock:
            self._in_flight[req.request_id] = req
        try:
            self._rpc({"op": "submit", "req": req})
        except Exception:
            with self._in_flight_lock:
                self._in_flight.pop(req.request_id, None)
            raise

    def set_audit(self, audit: str) -> None:
        """Forward the audit mode to the child's engine (drops its step log
        and finished-list retention under ``sampled``/``off``)."""
        if not self.activated or self.stopped:
            return
        self._rpc({"op": "configure", "audit": audit})

    # --------------------------------------------------------- ReplicaView --
    def num_outstanding(self) -> int:
        return self._rpc({"op": "probe"})["num_outstanding"]

    def outstanding_tokens(self) -> int:
        return self._rpc({"op": "probe"})["outstanding_tokens"]

    def prefix_match_len(self, tokens) -> int:
        return self._rpc({"op": "probe", "tokens": list(tokens)})["prefix_match"]

    def in_flight_ids(self) -> set:
        with self._in_flight_lock:
            return set(self._in_flight)

    # ----------------------------------------------------------- lifecycle --
    def start(self) -> None:
        """No-op: the child's engine starts at activation (``start_engine``)."""

    def retire(self) -> None:
        """Drain final step — fire-and-forget by design: retirement can be
        triggered from this handle's own completion path (the last in-flight
        finish), where waiting for a reply would deadlock against the
        child's pending ``complete_ack``."""
        self.retired = True
        self._send_oneway({"op": "retire"})

    def stop(self) -> None:
        if self.stopped or not self.activated:
            return
        # Snapshot accounting before the engine goes away mid-teardown.
        try:
            self._step_log_cache = self._rpc({"op": "step_log"})["log"]
            self._stats_cache = self._rpc({"op": "stats"})["stats"]
            self._rpc({"op": "stop_engine"})
        except (TransportClosed, RuntimeError):
            pass
        self.stopped = True

    def set_slowdown(self, factor: Optional[float]) -> bool:
        """Straggler injection: swap the child engine's predictor wrap."""
        if not self.activated or self.stopped:
            return False
        self._rpc({"op": "slowdown", "factor": factor})
        return True

    def force_kill(self) -> List[Request]:
        """Fault injection: SIGKILL the child — no drain, no goodbye frame —
        and surrender the requests it was holding.

        This is the real failure mode the socket transport must survive:
        the child's worker actor dies mid-jump, its Timekeeper socket goes
        EOF, and the server's per-connection reaper deregisters every actor
        of the dead connection so pending barrier rounds re-resolve without
        it.  Order matters:

        1. snapshot ``stats``/``step_log`` over the still-live command loop
           (a SIGKILLed engine can never answer the shutdown-time RPC, and
           the dead replica still owes its device-time accounting);
        2. ``proc.kill()`` — SIGKILL, nothing runs in the child;
        3. join the reader to EOF: completion frames already on the wire
           (steps that finished *before* the crash instant) still land, so
           the ledger handed back is exact — submits minus every completion
           the dead replica actually delivered.  TCP gets the EOF from the
           kernel; shm gets it from ``mark_peer_dead`` (the ring drains
           committed frames first, same exactness);
        4. reclaim the shm segment — a SIGKILLed child can never unlink
           anything itself.
        """
        self.retired = True
        if self.activated and not self.stopped:
            try:
                self._step_log_cache = self._rpc({"op": "step_log"})["log"]
                self._stats_cache = self._rpc({"op": "stats"})["stats"]
            except (TransportClosed, RuntimeError):
                pass                      # child already dying: ledger still valid
        self.stopped = True
        self.proc.kill()
        self.proc.join(timeout=30.0)
        self.chan.mark_peer_dead()
        self._reader.join(timeout=30.0)
        assert not self._reader.is_alive(), \
            f"{self.name}: reader failed to reach EOF after SIGKILL"
        with self._in_flight_lock:
            victims = list(self._in_flight.values())
            self._in_flight.clear()
        if self._reclaim is not None:
            self._reclaim()
        return victims

    def shutdown(self, timeout: float = 10.0) -> None:
        self._send_oneway({"op": "shutdown"})
        self.chan.close()
        self.proc.join(timeout=timeout)
        if self.proc.is_alive():
            self.proc.terminate()
            self.proc.join(timeout=5.0)
        if self._reclaim is not None:
            self._reclaim()

    # ----------------------------------------------------------- accounting --
    def stats(self) -> dict:
        if self._stats_cache is not None:
            return self._stats_cache
        if not self.activated:
            return {"name": self.name, "warm": True, "finished": 0,
                    "outstanding_reqs": 0, "outstanding_tokens": 0,
                    "steps": 0, "device_time_s": 0.0, "cpu_overhead_s": 0.0,
                    "num_preemptions": 0, "prefix_hit_rate": 0.0}
        try:
            return self._rpc({"op": "stats"})["stats"]
        except (TransportClosed, RuntimeError):
            return self._stats_cache or {"name": self.name, "finished": 0,
                                         "steps": 0, "device_time_s": 0.0,
                                         "cpu_overhead_s": 0.0,
                                         "num_preemptions": 0}

    @property
    def step_log(self) -> list:
        if self._step_log_cache is not None:
            return self._step_log_cache
        if not self.activated:
            return []
        try:
            return self._rpc({"op": "step_log"})["log"]
        except (TransportClosed, RuntimeError):
            return []


class ProcessCluster(ClusterBase):
    """Process backend: every replica engine in its own OS process.

    The parent keeps the Timekeeper (served over TCP), the router, the
    elastic-membership ledger, and the benchmark-facing surface; children
    keep the engines.  The parent-side ``transport`` is a
    :class:`~repro.core.client.LocalTransport` on the server's Timekeeper,
    so the dispatcher / think-time / autoscaler actors of
    ``BenchmarkRunner`` work unchanged — they are parent-process actors
    coordinating with remote replica actors through one barrier.
    """

    backend = "process"

    def __init__(
        self,
        handles: List[ProcessReplicaHandle],
        router: Router,
        *,
        server,            # TimekeeperServer | ShmTimekeeperServer
        warm_pool: List[ProcessReplicaHandle],
        spec_of: Callable[[int, Optional[str]], _EngineSpec],
        spawn_replica: Callable[[int], ProcessReplicaHandle],
        ctrl_listener: Optional[socket.socket] = None,
        cfg: Optional[ClusterConfig] = None,
        model_cfg: Optional[ModelConfig] = None,
        tier_specs: Optional[Dict[str, TierSpec]] = None,
        tier_spec_factory=None,
        transport: str = "tcp",
    ):
        self.server = server
        # NB: ClusterBase.transport is the parent-side ActorTransport object;
        # the wire-transport *kind* gets its own name.
        self.transport_kind = transport
        self._warm_pool = list(warm_pool)
        self._spec_of = spec_of
        self._spawn_replica = spawn_replica
        # kept open for pool-exhausted on-demand spawns; closed at shutdown
        self._ctrl_listener = ctrl_listener
        super().__init__(
            handles, router, clock=server.timekeeper.clock,
            transport=LocalTransport(server.timekeeper),
            timekeeper=server.timekeeper, model_cfg=model_cfg, cfg=cfg,
            tier_specs=tier_specs, tier_spec_factory=tier_spec_factory)
        for h in handles + self._warm_pool:
            h.on_complete = self._complete

    # ------------------------------------------------------------ backend --
    @property
    def warm_available(self) -> int:
        return len(self._warm_pool)

    def _new_replica(self, idx: int, tier: Optional[str]):
        """Activate a warm standby child (fast path: one ``start_engine``
        frame); with the pool exhausted, spawn a fresh process — correct but
        wall-expensive, so size ``warm_replicas`` to the autoscaler's
        ``max_replicas`` for latency-faithful elastic runs."""
        if self._warm_pool:
            handle = self._warm_pool.pop(0)
        else:
            handle = self._spawn_replica(idx)
            handle.on_complete = self._complete
        handle.index = idx
        handle.activate(self._spec_of(idx, tier))
        return handle

    def _attach_replica(self, handle) -> None:
        handle.on_complete = self._complete

    def _force_kill(self, idx: int) -> List[Request]:
        return self.replicas[idx].force_kill()

    def _set_slowdown(self, idx: int, factor: Optional[float]) -> bool:
        return self.replicas[idx].set_slowdown(factor)

    # ---------------------------------------------------------- lifecycle --
    def shutdown(self) -> None:
        self.stop()
        for h in self.replicas + self._warm_pool:
            h.shutdown()
        self._warm_pool.clear()
        if self._ctrl_listener is not None:
            try:
                self._ctrl_listener.close()
            except OSError:
                pass
        # Server last: its close broadcasts the final releasing clock update
        # to any child still mid-teardown.
        self.server.close()

    # --------------------------------------------------------- aggregates --
    def stats(self) -> dict:
        agg = super().stats()
        agg["warm_standby"] = self.warm_available
        agg["transport"] = self.transport_kind
        return agg


# =========================================================================
# factory
# =========================================================================

def build_process_cluster(
    *,
    model_cfg: ModelConfig,
    router: Router,
    num_replicas: int,
    resolve_cfg: Callable[[int, Optional[str]], EngineConfig],
    resolve_pred: Callable[[EngineConfig, Optional[str]], object],
    default_tier: Callable[[int], Optional[str]],
    cluster_cfg: ClusterConfig,
    tier_specs: Optional[Dict[str, TierSpec]] = None,
    tier_spec_factory=None,
    jitter_cooldown: float = 0.0,
    warm_replicas: Optional[int] = None,
    name: str = "cluster",
    transport: str = "tcp",
) -> ProcessCluster:
    """Spawn the Timekeeper server + child replica processes and wire them
    into a :class:`ProcessCluster`.  Called through
    :func:`repro.cluster.build_cluster` (``backend="process"``), which owns
    the config/tier/predictor resolution shared with the thread backend.

    ``transport`` selects the wire: ``"tcp"`` (framed sockets) or ``"shm"``
    (seqlock clock word + SPSC rings, :mod:`repro.core.shm_transport`).
    """
    if transport not in TRANSPORTS:
        raise ValueError(
            f"transport={transport!r}: choose from {TRANSPORTS}")
    ctx = multiprocessing.get_context("spawn")   # parent is multi-threaded:
    # fork would duplicate it mid-lock; spawn re-imports a clean interpreter

    listener = None
    if transport == "shm":
        from repro.core.shm_transport import ShmEndpoint, ShmTimekeeperServer
        server = ShmTimekeeperServer(jitter_cooldown=jitter_cooldown)
    else:
        server = TimekeeperServer(jitter_cooldown=jitter_cooldown)
        # Control listener: children dial back in and identify via `hello`.
        listener = socket.create_server(("127.0.0.1", 0))
        ctrl_addr = listener.getsockname()

    total = max(num_replicas, warm_replicas or 0)

    def spawn_replica(index: int) -> ProcessReplicaHandle:
        if transport == "shm":
            # The segment exists before the child does; the child only ever
            # attaches.  The parent-side service thread and control channel
            # poll child liveness so a SIGKILL can never wedge them.
            endpoint = ShmEndpoint.create(server.clock_word.name)
            proc = ctx.Process(
                target=_replica_main,
                args=(("shm", endpoint.spec), ("shm", endpoint.spec), index),
                name=f"{name}-r{index}", daemon=True)
            proc.start()
            # Doorbell handshake: the child dials during attach; a timeout
            # (crashed child, exotic platform) just leaves the endpoint on
            # its bounded-poll fallback — correct either way.
            endpoint.accept_wakes(_HANDSHAKE_TIMEOUT)
            server.serve(endpoint.tk_c2p, endpoint.tk_p2c,
                         peer_alive=proc.is_alive,
                         name=f"shm-tk-r{index}")
            chan = endpoint.parent_channel(peer_alive=proc.is_alive)
            try:
                hello = chan.recv_obj(timeout=_HANDSHAKE_TIMEOUT)
            except TransportClosed:
                hello = None
            assert hello is not None and hello["op"] == "hello", \
                f"replica {index} handshake failed"
            return ProcessReplicaHandle(hello["replica"], chan, proc,
                                        reclaim=endpoint.unlink)
        proc = ctx.Process(
            target=_replica_main,
            args=(("tcp", ctrl_addr), ("tcp", tuple(server.address)), index),
            name=f"{name}-r{index}", daemon=True)
        proc.start()
        listener.settimeout(_HANDSHAKE_TIMEOUT)
        conn, _ = listener.accept()
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        hello = _recv_obj(conn)
        assert hello is not None and hello["op"] == "hello", \
            f"replica {index} handshake failed"
        return ProcessReplicaHandle(hello["replica"], SocketChannel(conn),
                                    proc)

    def spec_of(i: int, tier: Optional[str]) -> _EngineSpec:
        tier = tier if tier is not None else default_tier(i)
        cfg = resolve_cfg(i, tier)
        return _EngineSpec(model_cfg=model_cfg, engine_cfg=cfg,
                           predictor=resolve_pred(cfg, tier),
                           name=f"{name}-r{i}", tier=tier)

    handles: List[ProcessReplicaHandle] = []
    warm: List[ProcessReplicaHandle] = []
    try:
        for i in range(total):
            h = spawn_replica(i)
            (handles if i < num_replicas else warm).append(h)
        for i, h in enumerate(handles):
            h.activate(spec_of(i, None))
    except Exception:
        for h in handles + warm:
            h.shutdown(timeout=2.0)
        if listener is not None:
            listener.close()
        server.close()
        raise

    return ProcessCluster(
        handles, router, server=server, warm_pool=warm, spec_of=spec_of,
        spawn_replica=spawn_replica, ctrl_listener=listener,
        cfg=cluster_cfg, model_cfg=model_cfg,
        tier_specs=tier_specs, tier_spec_factory=tier_spec_factory,
        transport=transport)
