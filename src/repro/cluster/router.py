"""Pluggable request routing for multi-replica serving (data-parallel mode).

A :class:`Router` decides which replica serves each request.  Policies are
*engine-agnostic*: they see replicas only through the tiny
:class:`ReplicaView` protocol (load + prefix-cache probes), so the very same
policy objects route the time-warp emulator's real ``LLMEngine`` replicas
(``repro.cluster.Cluster``) **and** the DES baseline's simulated replicas
(``repro.des.simulator.MultiReplicaSimulator``).  Sharing the policy code is
what extends the paper's §2.3 semantic-gap argument to cluster scale: any
emulator-vs-DES divergence at N replicas is attributable to engine-semantics
re-implementation, never to a routing difference.

Policies
--------
``round_robin``
    Cyclic assignment; ignores replica state.  Deterministic, the baseline.
``least_outstanding_tokens``
    Place on the replica with the fewest remaining scheduled tokens
    (prefill left + decode left) — the token-aware analogue of
    least-outstanding-requests, robust to skewed prompt lengths.  On a
    heterogeneous pool the backlog is divided by the replica's tier
    throughput weight, so "fewest tokens" becomes "shortest estimated
    drain time" (an idle L4 should not outrank a lightly loaded H100 that
    clears its queue sooner).
``prefix_affinity``
    Score replicas by their radix prefix-cache hit potential for the
    request's prompt and route to the best scorer; unseen prefixes fall
    back to least-outstanding placement and are remembered (sticky key on
    the prompt head) so a session's follow-ups land on the replica already
    holding its KV.
``pd_pool``
    Prefill/decode disaggregation as a routing policy: the replica set is
    split into a prefill pool and a decode pool; fresh requests go to the
    least-loaded prefill replica, and after the KV handoff the cluster asks
    :meth:`PDPoolRouter.route_decode` for the decode-side placement.  This
    unifies ``repro.serving.disagg`` behind the same Router interface.
``adapter_affinity``
    Multi-LoRA placement (``repro.fleet``): requests tagged with an adapter
    name stick to the replica that already holds that adapter's weights
    (first placement by shortest drain), so each adapter is resident on one
    replica and swap churn is minimized; untagged (base-model) traffic is
    placed by shortest drain.  A sticky replica that drains away triggers a
    deterministic re-placement.
``cost_normalized_load``
    Heterogeneous-pool placement by *marginal dollar cost*: each replica is
    scored by its estimated drain time (weighted backlog, as in
    ``least_outstanding_tokens``) multiplied by its tier's $/replica-second,
    so comparable load lands on the cheaper tier while a genuinely shorter
    queue on an expensive tier still wins.  With no tier info configured
    every weight/cost is 1.0 and the policy degrades to exactly
    ``least_outstanding_tokens``.

Tier info reaches a router through :meth:`Router.set_tier` (and the
``weight``/``cost`` keywords of :meth:`Router.grow` for autoscale-added
replicas); both the emulated :class:`~repro.cluster.cluster.Cluster` and the
DES baseline derive those numbers from the same
:class:`~repro.cluster.tiers.TierSpec` objects, so identically-constructed
router instances behave identically on both sides.

Invariant: ``route`` only ever returns an index from the ``active`` list it
was given — a draining or not-yet-provisioned replica can never receive a
fresh request, whatever the policy.  Deterministic tie-breaking (lowest
index) is part of every policy's contract; it is what makes same-seed runs
byte-identical.

>>> class V:
...     def __init__(self, tokens): self._t = tokens
...     def outstanding_tokens(self): return self._t
...     def prefix_match_len(self, toks): return 0
>>> r = make_router("least_outstanding_tokens", 2)
>>> r.route(None, [V(100), V(40)])
1
>>> r.set_tier(0, weight=4.0)          # replica 0 is a 4x-faster tier
>>> r.route(None, [V(100), V(40)])     # 100/4 = 25 beats 40/1
0
"""

from __future__ import annotations

from typing import Dict, List, Optional, Protocol, Sequence, Tuple

__all__ = [
    "ReplicaView",
    "Router",
    "RoundRobinRouter",
    "LeastOutstandingTokensRouter",
    "CostNormalizedLoadRouter",
    "PrefixAffinityRouter",
    "AdapterAffinityRouter",
    "PDPoolRouter",
    "ROUTER_POLICIES",
    "make_router",
]


class ReplicaView(Protocol):
    """What a routing policy may observe about one replica.

    Implementations are cheap, non-blocking, racy-read probes: an engine
    replica answers from lock-free counters, a DES replica from its event
    state.  Policies must tolerate (and tie-break deterministically under)
    slightly stale values.
    """

    def outstanding_tokens(self) -> int: ...

    def prefix_match_len(self, tokens: Sequence[int]) -> int: ...


class Router:
    """Base router: maps each request to a replica index in [0, n).

    Elastic membership: ``route`` takes an optional ``active`` index list —
    the replicas a request may currently land on (autoscaling: draining
    replicas leave it, freshly provisioned ones join it).  ``num_replicas``
    grows via :meth:`grow` when the cluster adds a replica; policies must
    only ever pick from ``active``.

    Heterogeneous pools: ``weights[i]`` (tier decode throughput, default 1.0)
    and ``costs[i]`` ($/replica-second, default 0.0 = untiered) let policies
    normalize load and price placement per replica.  Both lists always cover
    ``num_replicas`` entries.
    """

    def __init__(self, num_replicas: int):
        assert num_replicas >= 1
        self.num_replicas = num_replicas
        self.decisions: List[int] = []       # audit log (tests/benchmarks)
        self.record_decisions = True         # False: skip the log (scale runs)
        self.weights: List[float] = [1.0] * num_replicas
        self.costs: List[float] = [0.0] * num_replicas

    def route(self, req, views: Sequence[ReplicaView],
              active: Optional[Sequence[int]] = None) -> int:
        """Place one request; returns the chosen replica index.

        ``views`` are the per-replica :class:`ReplicaView` probes (racy,
        non-blocking reads); ``active`` restricts the choice to the current
        routing membership.  The chosen index is appended to
        :attr:`decisions` — the audit log tests and benchmarks replay.
        """
        act = list(active) if active is not None else list(range(len(views)))
        assert act, "routing needs at least one active replica"
        idx = self._pick(req, views, act)
        assert idx in act, f"policy picked inactive replica {idx}"
        if self.record_decisions:
            self.decisions.append(idx)
        return idx

    def _pick(self, req, views: Sequence[ReplicaView],
              active: List[int]) -> int:
        raise NotImplementedError

    def set_tier(self, idx: int, *, weight: float = 1.0,
                 cost: float = 0.0) -> None:
        """Record replica ``idx``'s tier throughput weight and $/second."""
        assert 0 <= idx < self.num_replicas and weight > 0
        self.weights[idx] = weight
        self.costs[idx] = cost

    def grow(self, num_replicas: int, *, weight: float = 1.0,
             cost: float = 0.0) -> None:
        """Cluster scale-up: the replica index space expanded.  ``weight``/
        ``cost`` describe the tier of every newly added index (scale-up adds
        one replica at a time in practice)."""
        assert num_replicas >= self.num_replicas
        while len(self.weights) < num_replicas:
            self.weights.append(weight)
            self.costs.append(cost)
        self.num_replicas = num_replicas

    # replicas a fresh request may land on (overridden by pd_pool)
    def intake_indices(self) -> List[int]:
        return list(range(self.num_replicas))

    # ------------------------------------------------ tier-aware scoring --
    def _drain_time(self, views, i: int) -> float:
        """Estimated seconds to clear replica ``i``'s backlog: outstanding
        tokens over tier throughput.  With default weights this orders
        replicas exactly like raw outstanding tokens."""
        return views[i].outstanding_tokens() / self.weights[i]

    def _shortest_drain(self, views, indices) -> int:
        """Lowest-load replica among ``indices`` by estimated drain time
        (tier-weighted; plain outstanding tokens on homogeneous pools);
        lowest index wins ties so the decision is deterministic under equal
        (or stale-equal) loads."""
        return min(indices, key=lambda i: (self._drain_time(views, i), i))


class RoundRobinRouter(Router):
    """Cyclic assignment over the *active* set.  A plain counter modulo the
    current membership reproduces ``itertools.cycle`` exactly for a static
    cluster and keeps cycling deterministically as replicas join/leave."""

    policy = "round_robin"

    def __init__(self, num_replicas: int):
        super().__init__(num_replicas)
        self._rr = -1

    def _pick(self, req, views, active) -> int:
        self._rr += 1
        return active[self._rr % len(active)]


class LeastOutstandingTokensRouter(Router):
    """Shortest estimated drain time (= fewest outstanding tokens on a
    homogeneous pool; tier-throughput-normalized on a mixed one)."""

    policy = "least_outstanding_tokens"

    def _pick(self, req, views, active) -> int:
        return self._shortest_drain(views, active)


class CostNormalizedLoadRouter(Router):
    """Cheapest marginal placement on a heterogeneous pool.

    Score per replica: estimated drain time × tier $/second — roughly "what
    does parking this request behind replica *i*'s queue cost".  Untiered
    replicas (cost 0.0) are scored with cost 1.0 so the policy stays a
    well-defined load balancer on homogeneous pools.  Ties break toward the
    cheaper tier, then the lower index.
    """

    policy = "cost_normalized_load"

    def _pick(self, req, views, active) -> int:
        def score(i: int):
            cost = self.costs[i] if self.costs[i] > 0 else 1.0
            return (self._drain_time(views, i) * cost, cost, i)
        return min(active, key=score)


class PrefixAffinityRouter(Router):
    """Route by radix prefix-cache hit potential (session affinity).

    The probe answers "how many prompt tokens does replica i already hold?";
    the best scorer wins (ties to lower load).  A request whose prefix no
    replica holds yet is placed least-outstanding and its prompt head is
    remembered, so same-session requests that arrive *before* the first one
    has populated the cache still co-locate (the sticky map is the router's
    own state, not a cache re-implementation — the actual hit accounting
    stays inside the engine's radix tree).
    """

    policy = "prefix_affinity"

    def __init__(self, num_replicas: int, *, affinity_key_len: int = 32):
        super().__init__(num_replicas)
        self.affinity_key_len = affinity_key_len
        self._sticky: Dict[Tuple[int, ...], int] = {}

    def _key(self, tokens: Sequence[int]) -> Tuple[int, ...]:
        return tuple(tokens[: self.affinity_key_len])

    def _pick(self, req, views, active) -> int:
        toks = getattr(req, "prompt_tokens", None)
        if not toks:
            # No routing key (e.g. a DES SimRequest built from lengths
            # only): nothing to be affine to — place by load.
            return self._shortest_drain(views, active)
        tokens = list(toks)
        scores = {i: views[i].prefix_match_len(tokens) for i in active}
        best = max(scores.values())
        if best > 0:
            idx = min((i for i in active if scores[i] == best),
                      key=lambda i: (self._drain_time(views, i), i))
            self._sticky[self._key(tokens)] = idx
            return idx
        key = self._key(tokens)
        idx = self._sticky.get(key)
        if idx is None or idx not in active:
            # unseen session, or its sticky replica drained away: re-place
            idx = self._shortest_drain(views, active)
            self._sticky[key] = idx
        return idx


class AdapterAffinityRouter(Router):
    """Sticky adapter→replica placement for multi-LoRA pools.

    The fleet ingress tags each request with the LoRA adapter it must be
    served with (``req.adapter``; ``None`` = base model).  The first request
    for an adapter is placed on the shortest-drain replica and the mapping
    is remembered, so subsequent requests for that adapter land where its
    weights (and its sessions' KV) already live — one resident replica per
    adapter, no swap churn.  Base-model traffic load-balances by shortest
    drain.  If a sticky replica leaves the active set (drain/crash) the
    adapter is deterministically re-placed.

    The sticky map is router state shared verbatim by the emulator and the
    DES (both see the same tag on the same request in the same order), so
    adapter placements are part of the audited decision log that parity
    compares.
    """

    policy = "adapter_affinity"

    def __init__(self, num_replicas: int):
        super().__init__(num_replicas)
        self._sticky: Dict[str, int] = {}

    def _pick(self, req, views, active) -> int:
        adapter = getattr(req, "adapter", None)
        if not adapter:
            return self._shortest_drain(views, active)
        idx = self._sticky.get(adapter)
        if idx is None or idx not in active:
            idx = self._shortest_drain(views, active)
            self._sticky[adapter] = idx
        return idx

    def adapter_placements(self) -> Dict[str, int]:
        """Current adapter→replica residency (audit/introspection)."""
        return dict(self._sticky)


class PDPoolRouter(Router):
    """Prefill/decode pool split (DistServe/Splitwise-style) as routing.

    The first ``num_prefill`` replicas form the prefill pool; the rest form
    the decode pool.  ``route`` places fresh requests on the least-loaded
    prefill replica; ``route_decode`` places KV-migrated requests on the
    least-loaded decode replica (the cluster calls it after the emulated KV
    transfer lands).
    """

    policy = "pd_pool"

    def __init__(self, num_replicas: int, *, num_prefill: Optional[int] = None):
        super().__init__(num_replicas)
        assert num_replicas >= 2, "pd_pool needs at least one of each pool"
        self.num_prefill = num_prefill if num_prefill is not None \
            else max(1, num_replicas // 2)
        assert 1 <= self.num_prefill < num_replicas
        self.prefill_indices = list(range(self.num_prefill))
        self.decode_indices = list(range(self.num_prefill, num_replicas))

    def intake_indices(self) -> List[int]:
        return list(self.prefill_indices)

    def _pick(self, req, views, active) -> int:
        pool = [i for i in self.prefill_indices if i in active]
        assert pool, "pd_pool: no active prefill replica"
        return self._shortest_drain(views, pool)

    def route_decode(self, req, views: Sequence[ReplicaView],
                     active: Optional[Sequence[int]] = None) -> int:
        pool = (self.decode_indices if active is None
                else [i for i in self.decode_indices if i in active])
        assert pool, "pd_pool: no active decode replica"
        return self._shortest_drain(views, pool)


ROUTER_POLICIES = {
    cls.policy: cls
    for cls in (RoundRobinRouter, LeastOutstandingTokensRouter,
                CostNormalizedLoadRouter, PrefixAffinityRouter,
                AdapterAffinityRouter, PDPoolRouter)
}


def make_router(policy: str, num_replicas: int, **kwargs) -> Router:
    """Build a fresh router (routers are stateful — one per run).

    >>> make_router("round_robin", 2).policy
    'round_robin'
    >>> sorted(ROUTER_POLICIES)      # doctest: +NORMALIZE_WHITESPACE
    ['adapter_affinity', 'cost_normalized_load', 'least_outstanding_tokens',
     'pd_pool', 'prefix_affinity', 'round_robin']
    """
    try:
        cls = ROUTER_POLICIES[policy]
    except KeyError:
        raise ValueError(
            f"unknown routing policy {policy!r}; "
            f"choose from {sorted(ROUTER_POLICIES)}") from None
    return cls(num_replicas, **kwargs)
