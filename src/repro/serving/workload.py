"""Compatibility shim: the workload layer moved to :mod:`repro.workload`.

Kept so historical imports (``from repro.serving.workload import
WorkloadConfig, synthesize``) keep working; new code should import from
``repro.workload`` which adds arrival processes and session workloads.
"""

from repro.workload.synth import (WorkloadConfig, lognormal_lengths,  # noqa: F401
                                  replay_trace, synthesize)

__all__ = ["WorkloadConfig", "synthesize", "replay_trace",
           "lognormal_lengths"]
