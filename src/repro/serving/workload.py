"""DEPRECATED compatibility shim: the workload layer moved to
:mod:`repro.workload`.

Kept so historical imports (``from repro.serving.workload import
WorkloadConfig, synthesize``) keep working; new code should import from
``repro.workload``, which adds arrival processes and session workloads.
Importing this module emits a :class:`DeprecationWarning` (once per
process, per the import cache).
"""

import warnings

from repro.workload.synth import (WorkloadConfig, lognormal_lengths,  # noqa: F401
                                  replay_trace, synthesize)

warnings.warn(
    "repro.serving.workload is deprecated; import from repro.workload "
    "instead (same names, plus arrival processes and session workloads)",
    DeprecationWarning,
    stacklevel=2,
)

__all__ = ["WorkloadConfig", "synthesize", "replay_trace",
           "lognormal_lengths"]
