"""Stack factory: wire a serving engine in any of the three modes.

This is the ~25-line "onboarding" surface the paper advertises: choosing
``mode="emulate"`` swaps the model runner and attaches the Timekeeper; every
other component (scheduler, block manager, prefix cache, benchmark runner)
is reused bit-for-bit.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.core.client import LocalTransport, TimeJumpClient
from repro.core.clock import VirtualClock, WallSource
from repro.core.emulation import VirtualDeviceContext
from repro.core.hardware import get_chip
from repro.core.predictor import (AnalyticalPredictor, ParallelSpec,
                                  RuntimePredictor)
from repro.core.timekeeper import Timekeeper
from repro.models.config import ModelConfig

from .engine import LLMEngine
from .model_runner import (RealModelRunner, SleepModelRunner,
                           TimeWarpModelRunner)
from .scheduler import EngineConfig
from .workers import WorkerGroup


@dataclass
class ServingStack:
    engine: LLMEngine
    clock: VirtualClock
    transport: Optional[LocalTransport] = None
    timekeeper: Optional[Timekeeper] = None
    devices: Optional[VirtualDeviceContext] = None
    runner: object = None

    def shutdown(self) -> None:
        self.engine.stop()
        if self.timekeeper is not None:
            self.timekeeper.close()


def default_predictor(model_cfg: ModelConfig, engine_cfg: EngineConfig,
                      *, overlap_collectives: bool = False) -> AnalyticalPredictor:
    return AnalyticalPredictor(
        model_cfg,
        ParallelSpec(tp=engine_cfg.tp, pp=engine_cfg.pp, ep=engine_cfg.ep),
        get_chip(engine_cfg.chip),
        overlap_collectives=overlap_collectives,
    )


def build_stack(
    model_cfg: ModelConfig,
    engine_cfg: EngineConfig,
    mode: str,
    *,
    predictor: Optional[RuntimePredictor] = None,
    model=None,
    params=None,
    max_seqs: Optional[int] = None,
    max_len: int = 512,
    jitter_cooldown: float = 0.0,
    use_worker_group: bool = True,
    wall: Optional[WallSource] = None,
    name: str = "engine",
) -> ServingStack:
    """``wall`` injects a deterministic wall source (e.g. ManualWallSource:
    virtual time advances only through coordinated jumps — reproducibility
    tests use it to get exact, jitter-free timelines)."""
    if mode == "emulate":
        tk = Timekeeper(clock=VirtualClock(wall),
                        jitter_cooldown=jitter_cooldown)
        transport = LocalTransport(tk)
        clock = tk.clock
        pred = predictor or default_predictor(model_cfg, engine_cfg)
        chip = get_chip(engine_cfg.chip)
        n_dev = engine_cfg.tp * engine_cfg.pp
        devices = VirtualDeviceContext(n_dev, chip)
        kv_pool = int(
            engine_cfg.num_blocks * engine_cfg.block_size
            * model_cfg.kv_bytes_per_token())
        weights = model_cfg.param_count() * model_cfg.dtype_bytes
        if use_worker_group and n_dev > 1:
            workers = WorkerGroup(transport, n_dev, name=f"{name}-w")
            runner = TimeWarpModelRunner(
                pred, workers=workers, devices=devices,
                weight_bytes=weights, kv_pool_bytes=kv_pool)
        else:
            client = TimeJumpClient(transport, f"{name}-worker")
            runner = TimeWarpModelRunner(
                pred, client, devices=devices,
                weight_bytes=weights, kv_pool_bytes=kv_pool)
        engine = LLMEngine(engine_cfg, runner, clock, name=name)
        return ServingStack(engine, clock, transport, tk, devices, runner)

    if mode == "sleep":
        clock = VirtualClock(wall)
        pred = predictor or default_predictor(model_cfg, engine_cfg)
        runner = SleepModelRunner(pred, clock)
        engine = LLMEngine(engine_cfg, runner, clock, name=name)
        return ServingStack(engine, clock, runner=runner)

    if mode == "real":
        assert model is not None and params is not None, \
            "real mode needs a model + params"
        clock = VirtualClock()
        runner = RealModelRunner(
            model, params,
            max_seqs=max_seqs or engine_cfg.max_num_seqs,
            max_len=max_len, clock=clock)
        runner.warmup()   # exclude XLA compiles from measured step times
        engine = LLMEngine(engine_cfg, runner, clock, name=name)
        return ServingStack(engine, clock, runner=runner)

    raise ValueError(f"unknown mode {mode!r}")
