"""Emulated distributed worker group (TP×PP) for the serving engine.

In a real deployment each tensor-parallel rank is an OS process blocked on
its own GPU stream and NCCL collectives.  Under Revati each rank becomes an
*Actor* thread: per step it time-jumps over its predicted shard duration,
then meets the group in an :class:`EmulatedCollective` — the paper's
"NCCL collectives become barrier synchronization points" (§4.3).  The group
exit time is max(ranks), so straggler ranks (MoE imbalance, jittered
predictions) propagate exactly as a real all-reduce would propagate them.

Pipeline stages are folded into the per-rank duration by the predictor
(stage time + activation hops); see DESIGN.md §5 for the modelling note.
"""

from __future__ import annotations

import queue
import threading
from typing import List, Optional, Sequence

from repro.core.client import TimeJumpClient
from repro.core.emulation import EmulatedCollective


class WorkerGroup:
    def __init__(
        self,
        transport,
        num_workers: int,
        *,
        name: str = "worker",
        jitter: Optional[Sequence[float]] = None,   # per-rank duration skew
    ):
        self.transport = transport
        self.num_workers = num_workers
        self.name = name
        self.jitter = list(jitter) if jitter else [0.0] * num_workers
        self._collective = EmulatedCollective(num_workers, name=f"{name}-allreduce")
        self._in: List["queue.Queue"] = [queue.Queue() for _ in range(num_workers)]
        self._done: "queue.Queue" = queue.Queue()
        self._clients: List[TimeJumpClient] = []
        self._threads: List[threading.Thread] = []
        self._parked = True
        for rank in range(num_workers):
            client = TimeJumpClient(transport, f"{name}-{rank}", auto_register=False)
            self._clients.append(client)
            t = threading.Thread(
                target=self._worker_loop, args=(rank, client),
                name=f"{name}-{rank}", daemon=True)
            self._threads.append(t)
            t.start()
        self.unpark()

    # ----------------------------------------------------------- workers --
    def _worker_loop(self, rank: int, client: TimeJumpClient) -> None:
        while True:
            item = self._in[rank].get()
            if item is None:
                return
            duration = item * (1.0 + self.jitter[rank])
            client.time_jump(duration)
            # Collective barrier: everyone leaves at max(rank exit times).
            # Waiting ranks deregister so they don't hold the virtual clock
            # hostage; the completing rank stays registered so outside actors
            # can't race time past the collective exit (see EmulatedCollective).
            exit_t = self._collective.arrive(
                client.now(), 0.0,
                before_wait=client.deregister, after_wait=client.register)
            lag = exit_t - client.now()
            if lag > 0:
                client.time_jump(lag)
            self._done.put(rank)

    # ------------------------------------------------------------- group --
    def execute_step(self, duration: float) -> None:
        """Run one step on all ranks; blocks until the group completes."""
        for q in self._in:
            q.put(duration)
        for _ in range(self.num_workers):
            self._done.get()

    def resize(self, num_workers: int) -> None:
        """Elastic scale: change the group size between steps.

        Ranks are quiescent between ``execute_step`` calls (the engine never
        resizes mid-step), so shrinking retires the tail ranks' threads and
        growing spawns fresh ones; the collective is rebuilt at the new
        cardinality.  Under emulation this models adding/removing TP shards
        without restarting the engine — the Timekeeper's elastic actor
        registry absorbs the membership change between barrier rounds."""
        if num_workers == self.num_workers:
            return
        was_parked = self._parked
        self.park()                        # deregister everyone first
        if num_workers < self.num_workers:
            for rank in range(num_workers, self.num_workers):
                self._in[rank].put(None)   # retire tail ranks
            self._in = self._in[:num_workers]
            self._clients = self._clients[:num_workers]
            self._threads = self._threads[:num_workers]
        else:
            for rank in range(self.num_workers, num_workers):
                client = TimeJumpClient(
                    self.transport, f"{self.name}-{rank}", auto_register=False)
                self._clients.append(client)
                self._in.append(queue.Queue())
                t = threading.Thread(
                    target=self._worker_loop, args=(rank, client),
                    name=f"{self.name}-{rank}", daemon=True)
                self._threads.append(t)
                t.start()
        self.num_workers = num_workers
        self.jitter = (self.jitter + [0.0] * num_workers)[:num_workers]
        self._collective = EmulatedCollective(
            num_workers, name=f"{self.name}-allreduce")
        if not was_parked:
            self.unpark()

    def park(self) -> None:
        if not self._parked:
            for c in self._clients:
                c.deregister()
            self._parked = True

    def unpark(self) -> None:
        if self._parked:
            for c in self._clients:
                c.register()
            self._parked = False

    def shutdown(self) -> None:
        self.park()
        for q in self._in:
            q.put(None)
