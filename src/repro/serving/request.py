"""Request lifecycle: the unit of work the serving engine schedules.

The state machine mirrors vLLM's sequence states; all timestamps are
*virtual* seconds (Observers read the shared clock).  Generation lengths are
fixed by the workload, not by EOS sampling — the paper's footnote 1: standard
practice for performance modeling, and what keeps the control plane
independent of GPU *values*.
"""

from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

_req_counter = itertools.count()


class RequestState(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"          # running, prompt not fully processed
    DECODE = "decode"            # running, generating
    PREEMPTED = "preempted"      # evicted under memory pressure; recompute
    FINISHED = "finished"


@dataclass
class Request:
    prompt_tokens: Sequence[int]
    max_new_tokens: int
    arrival_time: float = 0.0
    request_id: int = field(default_factory=lambda: next(_req_counter))

    # closed-loop session identity (repro.workload.session): follow-up turns
    # are re-injected on completion and carry the prior turn's tokens
    session_id: Optional[int] = None
    turn_index: int = 0

    # fleet-plane identity (repro.fleet): the tenant the ingress assigned
    # this request to, and the LoRA adapter it must be served with (None =
    # base model).  Routing keys only — the engine never branches on them.
    tenant: Optional[str] = None
    adapter: Optional[str] = None

    # progress
    state: RequestState = RequestState.WAITING
    num_prefilled: int = 0            # prompt tokens processed so far
    output_tokens: List[int] = field(default_factory=list)
    cached_prefix_len: int = 0        # served from prefix cache (skip compute)

    # measurements (virtual time)
    first_scheduled_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    token_times: List[float] = field(default_factory=list)
    num_preemptions: int = 0
    kv_transfer_time: float = 0.0     # PD disaggregation accounting
    kv_migrated: bool = False         # KV arrived via PD transfer: skip compute

    # ------------------------------------------------------------ derived --
    @property
    def prompt_len(self) -> int:
        return len(self.prompt_tokens)

    @property
    def num_generated(self) -> int:
        return len(self.output_tokens)

    @property
    def prefill_complete(self) -> bool:
        return self.num_prefilled >= self.prompt_len

    @property
    def context_len(self) -> int:
        """Tokens currently represented in the KV cache / recurrent state."""
        return self.num_prefilled + self.num_generated

    @property
    def total_len(self) -> int:
        return self.prompt_len + self.max_new_tokens

    @property
    def finished(self) -> bool:
        return self.state == RequestState.FINISHED

    # ------------------------------------------------------------ metrics --
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    def tpot(self) -> Optional[float]:
        """Mean time per output token after the first."""
        if self.finish_time is None or self.first_token_time is None:
            return None
        n = self.num_generated - 1
        if n <= 0:
            return 0.0
        return (self.finish_time - self.first_token_time) / n

    def e2e_latency(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival_time

    def reset_for_requeue(self) -> None:
        """Crash recovery (fault injection): the replica — and every byte of
        its KV/prefix state — is gone.  The request re-enters routing as if
        freshly arrived: identity and the *original* arrival time are kept
        (TTFT keeps measuring from first submission, so a crash costs
        latency, never erases it), all progress and measurements zero."""
        self.output_tokens = []
        self.num_prefilled = 0
        self.cached_prefix_len = 0
        self.first_scheduled_time = None
        self.first_token_time = None
        self.finish_time = None
        self.token_times = []
        self.kv_migrated = False
        self.state = RequestState.WAITING

    def reset_for_recompute(self) -> None:
        """Preemption-by-recompute: KV is dropped; prompt + generated tokens
        are replayed as a (longer) prefill on resume."""
        self.num_preemptions += 1
        self.num_prefilled = 0
        self.cached_prefix_len = 0
        self.state = RequestState.PREEMPTED
