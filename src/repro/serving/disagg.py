"""Prefill/decode disaggregation (DistServe/Splitwise-style) under emulation.

Two engines share one Timekeeper: a *prefill engine* that admits fresh
requests and a *decode engine* that continues them.  On prefill completion
the request's KV cache "migrates" over an :class:`EmulatedChannel` — no data
moves, but the transfer occupies virtual time (bytes / link bandwidth) and
the decode engine cannot act on the request before its virtual arrival
(§4.3 "Preserving Distributed Dependencies").

Since the multi-replica refactor this module is a thin compatibility facade:
the handoff machinery (KV channel, mover actors, causal registration) lives
in :class:`repro.cluster.Cluster` under its ``pd_pool`` routing policy, and
:class:`DisaggregatedCluster` is exactly that cluster with one prefill and
one decode replica.  The Table-1 claim is unchanged — the disaggregation
logic is real orchestration code built on unmodified ``LLMEngine``s, not a
simulator approximation — and now the same code path scales to arbitrary
prefill/decode pool sizes via ``build_cluster(..., policy="pd_pool")``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List

from repro.models.config import ModelConfig

from .engine import LLMEngine
from .request import Request


@dataclass
class DisaggConfig:
    kv_link_bandwidth: float = 50e9      # inter-engine KV fabric (bytes/s)


class DisaggregatedCluster:
    """Router + prefill engine + decode engine + KV-transfer channel.

    Facade over ``repro.cluster.Cluster`` with a 2-replica ``pd_pool``
    router (replica 0 = prefill, replica 1 = decode)."""

    def __init__(
        self,
        model_cfg: ModelConfig,
        prefill_engine: LLMEngine,
        decode_engine: LLMEngine,
        cfg: DisaggConfig = DisaggConfig(),
        transport=None,
    ):
        from repro.cluster import Cluster, ClusterConfig, PDPoolRouter

        self.model_cfg = model_cfg
        self.prefill_engine = prefill_engine
        self.decode_engine = decode_engine
        self.cfg = cfg
        self._cluster = Cluster(
            [prefill_engine, decode_engine],
            PDPoolRouter(2, num_prefill=1),
            transport=transport,
            model_cfg=model_cfg,
            cfg=ClusterConfig(kv_link_bandwidth=cfg.kv_link_bandwidth),
        )
        self.channel = self._cluster.channel
        self.clock = self._cluster.clock

    # ------------------------------------------------------------- intake --
    def submit(self, req: Request) -> None:
        self._cluster.submit(req)

    def start(self) -> None:
        self._cluster.start()

    def stop(self) -> None:
        self._cluster.stop()

    @property
    def is_running(self) -> bool:
        return self._cluster.is_running

    # ------------------------------------------------------------ waiting --
    def wait_until_complete(self, expected: int, timeout: float = 600.0) -> bool:
        return self._cluster.wait_until_complete(expected, timeout=timeout)

    @property
    def finished(self) -> List[Request]:
        return self._cluster.finished

    @property
    def step_log(self):
        return self._cluster.step_log

    @property
    def engines(self):
        return self._cluster.engines

    @property
    def router(self):
        return self._cluster.router
