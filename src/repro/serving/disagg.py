"""Prefill/decode disaggregation (DistServe/Splitwise-style) under emulation.

Two engines share one Timekeeper: a *prefill engine* that admits fresh
requests and a *decode engine* that continues them.  On prefill completion
the request's KV cache "migrates" over an :class:`EmulatedChannel` — no data
moves, but the transfer occupies virtual time (bytes / link bandwidth) and
the decode engine cannot act on the request before its virtual arrival
(§4.3 "Preserving Distributed Dependencies").

Causality of the handoff: the prefill engine invokes ``on_finish``
*synchronously in its step thread*, and the KV mover registers with the
Timekeeper right there — before the prefill engine can participate in
another barrier round.  Virtual time therefore cannot advance past the KV
arrival without the mover's consent (a wall-clock-polling mover would leak
its polling latency into accelerated virtual time — ~40× dilated — and
corrupt decode-side latencies; found by examples/pd_disaggregation.py).

This module is deliberately built *on top of* the unmodified LLMEngine —
demonstrating the paper's Table-1 claim that complex deployment features
work under emulation without bespoke modelling: the disaggregation logic
here is real orchestration code, not a simulator approximation.
"""

from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass
from typing import List, Optional

from repro.core.client import TimeJumpClient
from repro.core.emulation import EmulatedChannel
from repro.models.config import ModelConfig

from .engine import LLMEngine
from .request import Request, RequestState


@dataclass
class DisaggConfig:
    kv_link_bandwidth: float = 50e9      # inter-engine KV fabric (bytes/s)


class DisaggregatedCluster:
    """Router + prefill engine + decode engine + KV-transfer channel."""

    def __init__(
        self,
        model_cfg: ModelConfig,
        prefill_engine: LLMEngine,
        decode_engine: LLMEngine,
        cfg: DisaggConfig = DisaggConfig(),
        transport=None,
    ):
        self.model_cfg = model_cfg
        self.prefill_engine = prefill_engine
        self.decode_engine = decode_engine
        self.cfg = cfg
        self.channel = EmulatedChannel(cfg.kv_link_bandwidth, name="kv-transfer")
        self.transport = transport
        self._mover_ids = itertools.count()
        self._movers: List[threading.Thread] = []
        self._expected = 0

    # ------------------------------------------------------------- intake --
    def submit(self, req: Request) -> None:
        # Prefill-stage request: finish after the first token (the KV is then
        # complete) and hand off for decode.
        self._expected += 1
        req._disagg_total_new = req.max_new_tokens          # stash
        req.max_new_tokens = 1
        self.prefill_engine.submit(req)

    def start(self) -> None:
        self.prefill_engine.on_finish = self._handoff
        self.prefill_engine.start()
        self.decode_engine.start()

    def stop(self) -> None:
        self.prefill_engine.stop()
        self.decode_engine.stop()
        for t in self._movers:
            t.join(timeout=5)

    # ----------------------------------------------------------- handoff --
    def _handoff(self, finished: List[Request]) -> None:
        """Runs in the prefill engine's step thread, synchronously with
        completion.  Registering the mover HERE is what preserves causality:
        the prefill engine cannot re-enter the barrier until this returns."""
        now = self.prefill_engine.clock.now()
        for req in finished:
            kv_bytes = req.context_len * self.model_cfg.kv_bytes_per_token()
            t_visible = self.channel.send(req, now, kv_bytes)
            mover: Optional[TimeJumpClient] = None
            if self.transport is not None:
                mover = TimeJumpClient(
                    self.transport, f"kv-mover-{next(self._mover_ids)}")
            t = threading.Thread(
                target=self._transfer, args=(req, t_visible, mover),
                name="kv-mover", daemon=True)
            t.start()
            self._movers.append(t)

    def _transfer(self, req: Request, t_visible: float,
                  mover: Optional[TimeJumpClient]) -> None:
        try:
            if mover is not None:
                mover.jump_to(t_visible)       # occupy the transfer duration
            req.kv_transfer_time = (t_visible - req.finish_time
                                    if req.finish_time is not None else 0.0)
            # Re-arm for the decode stage: KV arrives whole; the first
            # generated token becomes the last prompt token.
            first_token = req.output_tokens[0] if req.output_tokens else 0
            req.max_new_tokens = max(req._disagg_total_new - 1, 1)
            req.prompt_tokens = list(req.prompt_tokens) + [first_token]
            req.output_tokens = []
            req.num_prefilled = 0
            req.cached_prefix_len = 0
            req.state = RequestState.WAITING
            req.finish_time = None
            req.kv_migrated = True
            self.decode_engine.submit(req)
        finally:
            if mover is not None:
                mover.deregister()

    # ------------------------------------------------------------ waiting --
    def wait_until_complete(self, expected: int, timeout: float = 600.0) -> bool:
        return self.decode_engine.wait_until_complete(expected, timeout=timeout)

    @property
    def finished(self) -> List[Request]:
        return self.decode_engine.finished
