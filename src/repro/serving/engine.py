"""The LLM serving engine: continuous batching over any model runner.

One engine = one scheduler + block manager + prefix cache + model runner.
The *same control-plane code* runs in all three modes (the paper's central
claim — no re-implementation, mode changes swap only the runner):

  mode="real"    RealModelRunner      — actual JAX execution (ground truth)
  mode="emulate" TimeWarpModelRunner  — Revati time-warp emulation
  mode="sleep"   SleepModelRunner     — strawman wall-clock sleep baseline

Engine-as-Actor: the engine loop's CPU work (scheduling, bookkeeping)
consumes virtual time at wall rate (Eq. 1); device work is jumped by the
runner.  When idle, the engine *parks* (its actors leave the Timekeeper
barrier but stay known) so the benchmark dispatcher alone drives virtual
time; ``submit`` unparks it.

Replica surface: the engine is one replica of a (possibly N-replica)
deployment — ``repro.cluster.Cluster`` parks many of these on a single
shared VirtualClock.  The non-blocking intake/outtake surface the cluster
builds on: ``submit``/``submit_many`` enqueue without blocking, ``poll``
drains completions incrementally, and ``outstanding_tokens`` /
``prefix_match_len`` / ``stats`` are cheap racy-read probes the Router
policies use to place requests without ever stalling the engine loop.

Fault tolerance: ``snapshot()``/``restore()`` serialise the complete
control-plane state (queues, block tables, radix tree, request progress,
virtual-clock offset) so an emulation can checkpoint/restart across process
failures — requests in flight resume exactly (emulated modes; real mode
would also need device state).  ``snapshot()`` synchronises with the step
loop (``_state_lock``) so it always observes a between-steps state — never
a torn mid-step one — making restore deterministic even while submits keep
arriving.  See tests/test_fault_tolerance.py.
"""

from __future__ import annotations

import pickle
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.clock import VirtualClock

from .kv_cache import BlockManager
from .prefix_cache import RadixPrefixCache
from .request import Request, RequestState
from .scheduler import EngineConfig, Scheduler, SchedulerOutput


@dataclass
class StepRecord:
    t_start: float
    t_end: float
    num_prefill_tokens: int
    num_decode: int
    batch_size: int
    cpu_overhead_wall: float     # scheduler+bookkeeping wall seconds
    device_time: float           # executed/jumped seconds


class LLMEngine:
    def __init__(
        self,
        cfg: EngineConfig,
        runner,
        clock: VirtualClock,
        *,
        name: str = "engine",
    ):
        self.cfg = cfg
        self.runner = runner
        self.clock = clock
        self.name = name
        self.bm = BlockManager(cfg.num_blocks, cfg.block_size)
        self.prefix_cache = RadixPrefixCache(
            self.bm,
            enable=cfg.enable_prefix_caching,
            host_tier_blocks=cfg.host_tier_blocks,
            host_write_policy=cfg.host_write_policy,
        )
        self.scheduler = Scheduler(cfg, self.bm, self.prefix_cache)
        self._inbox: List[Request] = []
        self._lock = threading.Lock()
        # Serialises step() (and the loop's scheduler intake) against
        # snapshot(): a snapshot can only observe between-steps state.
        self._state_lock = threading.RLock()
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._idle = threading.Event()
        # set by force_kill (crash injection): the loop thread swallows the
        # unwedge exception from its aborted jump and exits immediately
        self._killed = threading.Event()
        self.finished: List[Request] = []
        self.step_log: List[StepRecord] = []
        self._finish_cond = threading.Condition()
        self._poll_cursor = 0
        # Aggregate counters maintained unconditionally: stats() and
        # wait_until_complete() read these, so they stay O(1) and correct
        # even when audit mode drops the per-request/per-step lists.
        self._finished_count = 0
        self._num_steps = 0
        self._device_time_s = 0.0
        self._cpu_overhead_s = 0.0
        # audit != "full": stop retaining finished requests / step records
        # (the scale path: memory must not grow with the request count)
        self.retain_finished = True
        self.retain_step_log = True
        # Live set for lock-free load probes (router placement hints):
        # request_id -> Request, maintained by submit/step under _live_lock.
        self._live: Dict[int, Request] = {}
        self._live_lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        # Called in the engine thread, synchronously with completion —
        # BEFORE the engine's next barrier participation.  PD disaggregation
        # uses this to register the KV-mover actor race-free (§4.3).
        self.on_finish = None
        # Additional completion subscribers with the same synchronous
        # guarantee (closed-loop session workloads register their follow-up
        # re-injection here; the Cluster reserves ``on_finish`` for itself).
        self.completion_listeners: List = []

    def add_completion_listener(self, fn) -> None:
        """Subscribe ``fn(finished: List[Request])``; runs in the step thread
        synchronously with completion, before the next barrier round — safe
        to register new Timekeeper actors from (think-time actors, movers)."""
        self.completion_listeners.append(fn)

    def remove_completion_listener(self, fn) -> None:
        if fn in self.completion_listeners:
            self.completion_listeners.remove(fn)

    def set_audit(self, audit: str) -> None:
        """Bound per-request memory: audit != "full" stops retaining the
        ``finished`` list, the ``step_log``, and the runner's per-step
        estimate breakdown (aggregate counters keep working; ``poll()``
        and ``snapshot()`` need full retention)."""
        retain = audit == "full"
        self.retain_finished = retain
        self.retain_step_log = retain
        if hasattr(self.runner, "retain_estimates"):
            self.runner.retain_estimates = retain

    @property
    def finished_count(self) -> int:
        """Completions so far — counter-backed, valid in every audit mode."""
        return self._finished_count

    # ------------------------------------------------------------- intake --
    def submit(self, req: Request) -> None:
        """Thread-safe request submission (benchmark dispatcher calls this).

        The runner is unparked *synchronously in the caller's thread*, under
        the same lock the engine's park decision takes: by the time submit
        returns, the engine's actors are registered with the Timekeeper, so
        the dispatcher's next TIMEJUMP cannot resolve a barrier without them
        (that race would skip virtual time over the request's processing and
        corrupt TTFT — see tests/test_system.py fidelity tests)."""
        # _live insert precedes inbox visibility: the engine loop may finish
        # the request (and pop it) any time after the append, and a pop
        # racing ahead of the insert would leave a permanently stale entry
        # inflating this replica's load probes.
        with self._live_lock:
            self._live[req.request_id] = req
        with self._lock:
            self._inbox.append(req)
            self.runner.unpark()
        self._wake.set()

    def submit_many(self, reqs: List[Request]) -> None:
        with self._live_lock:
            for req in reqs:
                self._live[req.request_id] = req
        with self._lock:
            self._inbox.extend(reqs)
            self.runner.unpark()
        self._wake.set()

    # ----------------------------------------------------- replica probes --
    def poll(self) -> List[Request]:
        """Drain completions that finished since the previous ``poll`` call.

        Non-blocking Observer surface for external consumers (serving
        front-ends, incremental metric collectors); the in-process Cluster
        aggregates through ``on_finish`` callbacks instead, which fire
        synchronously in the step thread before the next barrier round."""
        with self._finish_cond:
            new = self.finished[self._poll_cursor:]
            self._poll_cursor = len(self.finished)
        return list(new)

    def num_outstanding(self) -> int:
        """Requests submitted but not yet finished (racy read, routing hint)."""
        with self._live_lock:
            return len(self._live)

    def in_flight_ids(self) -> set:
        """Snapshot of submitted-but-unfinished request ids (drain
        bookkeeping: the cluster waits for exactly this set before retiring
        a replica)."""
        with self._live_lock:
            return set(self._live)

    def outstanding_tokens(self) -> int:
        """Remaining scheduled work in tokens (prefill left + decode left).

        A racy best-effort read over the live set — field reads are atomic
        ints, so the estimate is never torn, just possibly a step stale.
        Routers use it for least-loaded placement; it must never block on
        the step loop (the dispatcher probes it between time jumps)."""
        with self._live_lock:
            live = list(self._live.values())
        total = 0
        for r in live:
            total += max(r.prompt_len - r.num_prefilled, 0)
            total += max(r.max_new_tokens - r.num_generated, 0)
        return total

    def prefix_match_len(self, tokens) -> int:
        """Longest radix-cached prefix (tokens) this replica already holds.

        Read-only probe (no stats, no pins, no LRU touch) so routers can
        score prefix affinity without perturbing cache behaviour."""
        return self.prefix_cache.probe(tokens)

    def stats(self) -> dict:
        """Cheap per-replica counters; the cluster aggregates these."""
        pc = self.prefix_cache.stats
        return {
            "name": self.name,
            "finished": self._finished_count,
            "outstanding_reqs": self.num_outstanding(),
            "outstanding_tokens": self.outstanding_tokens(),
            "steps": self._num_steps,
            "device_time_s": self._device_time_s,
            "cpu_overhead_s": self._cpu_overhead_s,
            "num_preemptions": self.scheduler.num_preemptions,
            "prefix_hit_rate": pc.hit_rate,
        }

    # -------------------------------------------------------------- loop --
    def start(self) -> "LLMEngine":
        self._thread = threading.Thread(
            target=self.run_loop, name=f"{self.name}-loop", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        self._wake.set()
        if self._thread is not None:
            self._thread.join(timeout=30)
        self.runner.shutdown()

    @property
    def is_running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def retire(self) -> None:
        """Leave the shared timeline permanently (cluster drain): the
        replica's worker actors deregister from the Timekeeper — a full
        departure with an epoch bump, not a park — while the engine thread
        keeps running (it idles parked-less and costs nothing on the
        barrier); ``stop()`` reaps it with the rest of the cluster."""
        retire = getattr(self.runner, "retire", None)
        if retire is not None:
            retire()

    def force_kill(self) -> List[Request]:
        """Crash semantics (fault injection): tear the engine down *now* and
        surrender every in-flight request.

        The step thread may be blocked mid-TIMEJUMP; retiring the worker
        actor deregisters it, and the resulting epoch bump makes the blocked
        client raise ``KeyError`` (the established force-departure path the
        autoscaler's ``stop`` uses).  The wake-and-recheck can race the
        deregistration by one epoch, so we keep bumping the clock epoch (a
        virtual-time no-op: ``advance_to(now)``) until the loop thread
        exits — required on a ManualWallSource, where a missed wakeup would
        otherwise never time out.  Only after the join are the queues
        harvested, so no step mutates them concurrently.  KV/prefix state
        is lost by construction: the surrendered ``Request`` objects keep
        only identity, prompt, and arrival time as far as the caller is
        concerned (the cluster zeroes their progress before requeueing).
        """
        self._killed.set()
        self._stop.set()
        self._wake.set()
        self.retire()
        if self._thread is not None and self._thread.is_alive():
            deadline = time.monotonic() + 30.0
            while self._thread.is_alive() and time.monotonic() < deadline:
                self.clock.advance_to(self.clock.now())   # epoch bump only
                self._thread.join(timeout=0.02)
            assert not self._thread.is_alive(), \
                f"{self.name}: step thread failed to exit on force_kill"
        with self._state_lock, self._lock, self._live_lock:
            victims = list(self._live.values())
            self._live.clear()
            self._inbox = []
            self.scheduler.waiting.clear()
            self.scheduler.running.clear()
        return victims

    def run_loop(self) -> None:
        while not self._stop.is_set():
            # Drain + scheduler-add under one _state_lock acquisition: a
            # snapshot() between the two would otherwise catch the drained
            # requests in neither inbox nor scheduler and silently lose them.
            with self._state_lock:
                with self._lock:
                    new = self._inbox
                    self._inbox = []
                for req in new:
                    self.scheduler.add_request(req)

            if not self.scheduler.has_work():
                # Park: deregister actors so we never wedge the Timekeeper
                # barrier while idle; dispatcher arrivals wake us.  The park
                # decision races with submit(): take the inbox lock so a
                # concurrent submit either lands before (we skip parking) or
                # after (its synchronous unpark re-registers us).
                with self._lock:
                    if self._inbox:
                        continue
                    self.runner.park()
                self._idle.set()
                self._wake.wait(timeout=0.05)
                self._wake.clear()
                continue
            with self._lock:
                if self._killed.is_set():
                    break                 # never re-register a dead replica
                self.runner.unpark()
            self._idle.clear()

            try:
                self.step()
            except Exception:
                # force_kill retires the worker actor out from under a
                # blocked jump; the client raises (KeyError) — that is the
                # expected unwedge path, not an error
                if self._killed.is_set():
                    break
                raise
        # drain: mark idle so waiters exit
        self._idle.set()

    def step(self) -> List[Request]:
        """One engine iteration: schedule -> execute -> bookkeep."""
        with self._state_lock:
            return self._step_locked()

    def _step_locked(self) -> List[Request]:
        cpu_t0 = time.monotonic()
        t_start = self.clock.now()
        out = self.scheduler.schedule(t_start)
        if out.is_empty:
            # can happen under total memory pressure; let time flow
            return []
        for req in out.preempted:
            release = getattr(self.runner, "release", None)
            if release:
                release(req.request_id)
        cpu_sched = time.monotonic() - cpu_t0
        # snapshot batch composition BEFORE bookkeeping mutates request state
        n_prefill_tokens = sum(
            s.num_new_tokens for s in out.batch if s.is_prefill)
        n_decode = sum(1 for s in out.batch if not s.is_prefill)

        tokens = self.runner.execute(out)

        cpu_t1 = time.monotonic()
        now = self.clock.now()
        finished = self.scheduler.on_step_complete(out, tokens, now)
        for req in finished:
            release = getattr(self.runner, "release", None)
            if release:
                release(req.request_id)
        if finished:
            with self._live_lock:
                for req in finished:
                    self._live.pop(req.request_id, None)
            if self.on_finish is not None:
                self.on_finish(finished)
            for fn in list(self.completion_listeners):
                fn(finished)
            with self._finish_cond:
                self._finished_count += len(finished)
                if self.retain_finished:
                    self.finished.extend(finished)
                self._finish_cond.notify_all()
        cpu_post = time.monotonic() - cpu_t1

        self._num_steps += 1
        self._device_time_s += now - t_start
        self._cpu_overhead_s += cpu_sched + cpu_post
        if self.retain_step_log:
            self.step_log.append(StepRecord(
                t_start=t_start,
                t_end=now,
                num_prefill_tokens=n_prefill_tokens,
                num_decode=n_decode,
                batch_size=len(out.batch),
                cpu_overhead_wall=cpu_sched + cpu_post,
                device_time=now - t_start,
            ))
        return finished

    # ----------------------------------------------------------- waiting --
    def wait_until_complete(self, expected: int, timeout: float = 600.0) -> bool:
        deadline = time.monotonic() + timeout
        with self._finish_cond:
            while self._finished_count < expected:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return False
                self._finish_cond.wait(timeout=min(remaining, 1.0))
        return True

    # ---------------------------------------------------- fault tolerance --
    def snapshot(self) -> bytes:
        """Serialise the full control-plane state (emulated modes).

        ``_state_lock`` is taken first, so the capture always lands *between*
        steps even while the engine thread is running and submits keep
        arriving through the non-blocking intake — a snapshot can never
        observe a torn mid-step state (half-applied ``on_step_complete``,
        requests in ``running`` with in-flight chunks).  Restoring into a
        fresh engine resumes every in-flight request (running requests are
        re-queued for recompute, mirroring a real node-failure restart where
        device state is lost but the request log survives)."""
        with self._state_lock, self._lock:
            state = {
                "cfg": self.cfg,
                "clock_offset": self.clock.offset,
                "waiting": list(self.scheduler.waiting),
                "running": list(self.scheduler.running),
                "num_preemptions": self.scheduler.num_preemptions,
                "inbox": list(self._inbox),
                "finished": list(self.finished),
                "step_log": list(self.step_log),
            }
            return pickle.dumps(state)

    @staticmethod
    def restore(blob: bytes, runner, clock: VirtualClock,
                name: str = "engine-restored") -> "LLMEngine":
        state = pickle.loads(blob)
        eng = LLMEngine(state["cfg"], runner, clock, name=name)
        clock.advance_to(clock.wall.time() + state["clock_offset"])
        # Device KV state died with the failure: running requests are
        # re-queued for recompute-from-scratch (idempotent replay).  Queue
        # order is deterministic: running requests (earliest-admitted, FCFS)
        # re-enter ahead of the waiting backlog, and the waiting deque's own
        # order — including preempted requests parked at its front — is
        # preserved verbatim.
        for req in state["running"]:
            req.reset_for_recompute()
            req.state = RequestState.WAITING
            eng.scheduler.waiting.append(req)
        for req in state["waiting"]:
            eng.scheduler.waiting.append(req)
        eng.scheduler.num_preemptions = state.get("num_preemptions", 0)
        eng._inbox = list(state["inbox"])
        eng.finished = list(state["finished"])
        eng.step_log = list(state["step_log"])
        eng._finished_count = len(eng.finished)
        eng._num_steps = len(eng.step_log)
        eng._device_time_s = sum(s.device_time for s in eng.step_log)
        eng._cpu_overhead_s = sum(s.cpu_overhead_wall for s in eng.step_log)
        eng._poll_cursor = len(eng.finished)
        with eng._live_lock:
            for req in (state["running"] + state["waiting"] + state["inbox"]):
                eng._live[req.request_id] = req
        return eng
