"""Paged KV-cache block manager (vLLM-style), engine control-plane state.

This is *metadata* in the paper's split-state memory model: block tables,
refcounts and free lists are small, faithfully-executed host state (the
emulated compute buffers backing the actual KV pool live in the
VirtualDeviceContext).  The manager supports:

* block allocation/free with refcounting (copy-on-write prefix sharing),
* watermark-based admission (reserve headroom so running decodes don't
  immediately re-preempt),
* integration hooks for the radix prefix cache (cached blocks enter a
  request's table with an extra ref instead of being recomputed).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from .request import Request


class OutOfBlocksError(RuntimeError):
    pass


@dataclass
class Block:
    block_id: int
    ref_count: int = 0
    # token ids stored in this block (control metadata; enables prefix reuse)
    token_ids: tuple = ()


class BlockManager:
    def __init__(self, num_blocks: int, block_size: int, *,
                 watermark_frac: float = 0.01):
        assert num_blocks > 0 and block_size > 0
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.watermark_blocks = max(1, int(num_blocks * watermark_frac))
        self._blocks = [Block(i) for i in range(num_blocks)]
        self._free: set[int] = set(range(num_blocks))
        self.block_tables: Dict[int, List[int]] = {}

    # ----------------------------------------------------------- queries --
    @property
    def num_free(self) -> int:
        return len(self._free)

    def blocks_needed(self, num_tokens: int) -> int:
        return -(-num_tokens // self.block_size)

    def can_admit(self, req: Request) -> bool:
        """Admission check for a WAITING/PREEMPTED request: must fit its
        prompt (minus cached prefix) plus watermark headroom."""
        need = self.blocks_needed(req.prompt_len - req.cached_prefix_len)
        return self.num_free - need >= self.watermark_blocks

    def can_append(self, n_blocks: int = 1) -> bool:
        return self.num_free >= n_blocks

    # --------------------------------------------------------- mutations --
    def _take(self) -> Block:
        if not self._free:
            raise OutOfBlocksError("KV pool exhausted")
        b = self._blocks[self._free.pop()]  # arbitrary free block
        assert b.ref_count == 0
        b.ref_count = 1
        b.token_ids = ()
        return b

    def allocate_request(self, req: Request,
                         cached_blocks: Optional[List[int]] = None) -> None:
        """Create a block table: referenced prefix-cache blocks + fresh
        blocks for the uncached remainder of the prompt."""
        assert req.request_id not in self.block_tables
        table: List[int] = []
        if cached_blocks:
            for bid in cached_blocks:
                self._blocks[bid].ref_count += 1
                table.append(bid)
        uncached = req.prompt_len - len(table) * self.block_size
        for _ in range(self.blocks_needed(max(uncached, 0))):
            table.append(self._take().block_id)
        self.block_tables[req.request_id] = table

    def append_slot(self, req: Request) -> None:
        """Ensure capacity for one more token (decode step)."""
        table = self.block_tables[req.request_id]
        if req.context_len + 1 > len(table) * self.block_size:
            table.append(self._take().block_id)

    def free_request(self, req: Request) -> List[int]:
        """Drop the request's references; returns block ids that hit ref 0
        (the prefix cache may resurrect them before they're reused)."""
        table = self.block_tables.pop(req.request_id, [])
        released = []
        for bid in table:
            b = self._blocks[bid]
            b.ref_count -= 1
            assert b.ref_count >= 0
            if b.ref_count == 0:
                self._free.add(bid)
                released.append(bid)
        return released

    # --------------------------------------------- prefix-cache interface --
    def pin(self, bid: int) -> None:
        b = self._blocks[bid]
        if b.ref_count == 0 and bid in self._free:
            self._free.discard(bid)  # resurrect from free list (O(1))
        b.ref_count += 1

    def unpin(self, bid: int) -> None:
        b = self._blocks[bid]
        b.ref_count -= 1
        assert b.ref_count >= 0
        if b.ref_count == 0:
            self._free.add(bid)

    def set_block_tokens(self, bid: int, token_ids: tuple) -> None:
        self._blocks[bid].token_ids = token_ids

    def utilization(self) -> float:
        return 1.0 - self.num_free / self.num_blocks
