"""Continuous-batching schedulers.

Two policies reproduce the real-engine behavioural split the paper leans on
(§2.3 "Framework Specificity", §6.2: "SGLang does not perform mixed batching
by default, though it performs chunked prefills"):

* ``vllm``   — Sarathi-style chunked prefill **with mixed batching**: every
  step packs all running decodes (1 token each) plus prefill chunks up to the
  token budget.
* ``sglang`` — chunked prefill with **prefill prioritisation, no mixing**:
  if admissible prefill work exists, the step is prefill-only; otherwise it
  is decode-only.

Shared mechanics: FCFS admission bounded by ``max_num_seqs`` and KV-block
watermark, radix prefix-cache matching on admission, preemption-by-recompute
under memory pressure (newest running request loses, vLLM semantics), and
prefix-cache insert at prefill completion.

The scheduler is pure control-plane: microseconds of CPU per step, no
dependence on GPU values — exactly the property (paper §3.3) that makes
time-warp emulation viable.  The same class runs unmodified in real,
emulated, and sleep modes.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

from .kv_cache import BlockManager, OutOfBlocksError
from .prefix_cache import RadixPrefixCache
from .request import Request, RequestState


@dataclass(frozen=True)
class EngineConfig:
    policy: str = "vllm"                  # vllm | sglang
    max_num_seqs: int = 64
    max_batched_tokens: int = 512         # chunk size / step token budget
    block_size: int = 16
    num_blocks: int = 8192
    enable_prefix_caching: bool = True
    host_tier_blocks: int = 0             # hierarchical cache tier (0 = off)
    host_write_policy: str = "write_through"
    # emulated hardware
    chip: str = "tpu-v5e"
    tp: int = 1
    pp: int = 1
    ep: int = 1


@dataclass
class ScheduledSeq:
    request: Request
    num_new_tokens: int                   # prefill chunk size; 1 for decode

    @property
    def is_prefill(self) -> bool:
        return not self.request.prefill_complete


@dataclass
class SchedulerOutput:
    batch: List[ScheduledSeq] = field(default_factory=list)
    preempted: List[Request] = field(default_factory=list)
    admitted: List[Request] = field(default_factory=list)

    @property
    def is_empty(self) -> bool:
        return not self.batch

    @property
    def num_tokens(self) -> int:
        return sum(s.num_new_tokens for s in self.batch)


class Scheduler:
    def __init__(self, cfg: EngineConfig, bm: BlockManager,
                 prefix_cache: RadixPrefixCache):
        assert cfg.policy in ("vllm", "sglang"), cfg.policy
        self.cfg = cfg
        self.bm = bm
        self.prefix_cache = prefix_cache
        self.waiting: Deque[Request] = deque()
        self.running: List[Request] = []     # admission order
        self.num_preemptions = 0

    # ------------------------------------------------------------ intake --
    def add_request(self, req: Request) -> None:
        req.state = RequestState.WAITING
        self.waiting.append(req)

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def num_unfinished(self) -> int:
        return len(self.waiting) + len(self.running)

    # ---------------------------------------------------------- schedule --
    def schedule(self, now: float) -> SchedulerOutput:
        out = SchedulerOutput()
        if self.cfg.policy == "vllm":
            self._schedule_decodes(out, now)
            self._schedule_prefills(out, now,
                                    budget=self.cfg.max_batched_tokens - out.num_tokens)
        else:  # sglang: prefill-prioritised, unmixed
            self._schedule_prefills(out, now, budget=self.cfg.max_batched_tokens)
            if not out.batch:
                self._schedule_decodes(out, now)
        return out

    # ------------------------------------------------- decode scheduling --
    def _schedule_decodes(self, out: SchedulerOutput, now: float) -> None:
        decodes = [r for r in self.running if r.state == RequestState.DECODE]
        for req in list(decodes):
            if req.state != RequestState.DECODE:
                continue  # preempted as a victim earlier in this same step
            while True:
                try:
                    self.bm.append_slot(req)
                    out.batch.append(ScheduledSeq(req, 1))
                    break
                except OutOfBlocksError:
                    # memory pressure: first reclaim cold prefix-cache blocks,
                    # then preempt the newest running request (recompute).
                    if self.prefix_cache.evict(1, now):
                        continue
                    victim = self._pick_victim(exclude=req)
                    if victim is None:
                        # cannot even preempt: victimise this request itself
                        self._preempt(req, out)
                        break
                    self._preempt(victim, out)
            # victim loop may have preempted req itself
            if req.state != RequestState.DECODE:
                continue

    def _pick_victim(self, exclude: Request) -> Optional[Request]:
        for req in reversed(self.running):       # newest first (vLLM)
            if req is not exclude:
                return req
        return None

    def _preempt(self, req: Request, out: SchedulerOutput) -> None:
        self.bm.free_request(req)
        self.running.remove(req)
        req.reset_for_recompute()
        self.waiting.appendleft(req)
        out.preempted.append(req)
        # drop any slot already scheduled for it this step
        out.batch = [s for s in out.batch if s.request is not req]
        self.num_preemptions += 1

    # ------------------------------------------------ prefill scheduling --
    def _schedule_prefills(self, out: SchedulerOutput, now: float,
                           budget: int) -> None:
        # (1) continue running chunked prefills (admission order)
        for req in self.running:
            if budget <= 0:
                return
            if req.state == RequestState.PREFILL:
                chunk = min(budget, req.prompt_len - req.num_prefilled)
                if chunk > 0:
                    out.batch.append(ScheduledSeq(req, chunk))
                    budget -= chunk

        # (2) admit waiting requests FCFS
        while budget > 0 and self.waiting and len(self.running) < self.cfg.max_num_seqs:
            req = self.waiting[0]
            # Prefix-cache match (re-done on each attempt: an eviction retry
            # below may have invalidated a previous match).  Preempted
            # requests recompute from scratch (vLLM recompute semantics).
            cached_blocks: List[int] = []
            n_dev = 0
            if req.num_preemptions == 0 and not req.kv_migrated:
                cached_blocks, n_dev, _n_host = self.prefix_cache.match(
                    req.prompt_tokens, now)
                # never cache-skip the whole prompt: the last token must be
                # recomputed to produce the first output logits
                while cached_blocks and n_dev >= req.prompt_len:
                    cached_blocks = cached_blocks[:-1]
                    n_dev -= self.bm.block_size
            req.cached_prefix_len = n_dev
            if not self.bm.can_admit(req):
                if not self.prefix_cache.evict(
                        self.bm.blocks_needed(
                            req.prompt_len - req.cached_prefix_len), now):
                    break
                continue
            self.waiting.popleft()
            self.bm.allocate_request(req, cached_blocks)
            # PD-migrated KV occupies blocks but needs no recompute: only the
            # final position runs (producing the next token).
            req.num_prefilled = (req.prompt_len - 1 if req.kv_migrated
                                 else req.cached_prefix_len)
            req.state = RequestState.PREFILL
            if req.first_scheduled_time is None:
                req.first_scheduled_time = now
            self.running.append(req)
            out.admitted.append(req)
            chunk = min(budget, req.prompt_len - req.num_prefilled)
            out.batch.append(ScheduledSeq(req, chunk))
            budget -= chunk

    # ------------------------------------------------------- completion --
    def on_step_complete(self, out: SchedulerOutput, token_ids: Dict[int, int],
                         now: float) -> List[Request]:
        """Apply one executed step.  ``token_ids`` maps request_id -> new
        token (only for sequences that produced one: completed-prefill and
        decode).  Returns newly finished requests (already freed)."""
        finished: List[Request] = []
        for sched in out.batch:
            req = sched.request
            if req.state == RequestState.PREFILL:
                req.num_prefilled += sched.num_new_tokens
                if req.prefill_complete:
                    # final chunk produced the first output token
                    req.output_tokens.append(token_ids.get(req.request_id, 0))
                    if req.first_token_time is None:  # preserved across PD handoff
                        req.first_token_time = now
                    req.token_times.append(now)
                    req.state = RequestState.DECODE
                    self._cache_prompt(req, now)
            elif req.state == RequestState.DECODE:
                req.output_tokens.append(token_ids.get(req.request_id, 0))
                req.token_times.append(now)
            if (req.state == RequestState.DECODE
                    and req.num_generated >= req.max_new_tokens):
                req.state = RequestState.FINISHED
                req.finish_time = now
                self.running.remove(req)
                self.bm.free_request(req)
                finished.append(req)
        return finished

    def _cache_prompt(self, req: Request, now: float) -> None:
        if not self.cfg.enable_prefix_caching:
            return
        table = self.bm.block_tables.get(req.request_id, [])
        n_full = req.prompt_len // self.bm.block_size
        self.prefix_cache.insert(
            list(req.prompt_tokens)[: n_full * self.bm.block_size],
            table[:n_full], now)
