"""Model runners: where the serving engine crosses into "device" execution.

This boundary is the JAX analogue of vLLM/SGLang's CUDA call sites, and the
*only* place Revati integration touches the engine (the paper's "<25 lines to
onboard a serving system" — here it is the :class:`TimeWarpModelRunner`):

* :class:`RealModelRunner` — executes the actual JAX model (ground truth for
  the fidelity benchmarks; CPU here, TPU in production).  Also doubles as
  the profiler that fits the :class:`~repro.core.predictor.TablePredictor`.
* :class:`TimeWarpModelRunner` — Revati: predicts the step duration and
  requests a TIMEJUMP instead of executing.  Weights and KV pool are
  ComputeBuffers in the VirtualDeviceContext (split-state memory model);
  returned token values are constants — a successful run proves the control
  plane never consumed phantom data.
* :class:`SleepModelRunner` — the paper's strawman: predict, then *sleep* the
  wall clock for the duration (correct but slow; Figs. 8–10 baseline).

All runners share the BatchSpec translation, so predictor inputs are
identical across modes by construction.
"""

from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.client import TimeJumpClient
from repro.core.clock import VirtualClock
from repro.core.emulation import VirtualDeviceContext
from repro.core.predictor import BatchSpec, RuntimePredictor, SeqSpec

from .scheduler import ScheduledSeq, SchedulerOutput

DUMMY_TOKEN = 0  # emulated modes: values are never consumed by control flow


def batch_spec_of(out: SchedulerOutput) -> BatchSpec:
    seqs = []
    for s in out.batch:
        req = s.request
        seqs.append(SeqSpec(
            new_tokens=s.num_new_tokens,
            context_len=req.context_len + s.num_new_tokens,
            cached_prefix=req.cached_prefix_len if s.is_prefill else 0,
        ))
    return BatchSpec.make(tuple(seqs))


def _producing(out: SchedulerOutput) -> List[ScheduledSeq]:
    """Sequences that emit a token this step (decode + final prefill chunk)."""
    res = []
    for s in out.batch:
        req = s.request
        if not s.is_prefill:
            res.append(s)
        elif req.num_prefilled + s.num_new_tokens >= req.prompt_len:
            res.append(s)
    return res


class TimeWarpModelRunner:
    """Revati's device-side integration: ~20 effective lines of engine patch.

    Each ``execute`` asks the predictor "how long would this batch take on
    the target hardware?" and jumps virtual time by the answer through the
    Timekeeper.  With ``workers`` set, the jump is performed by every worker
    of the TP group plus a collective barrier (NCCL-as-barrier, §4.3).
    """

    def __init__(
        self,
        predictor: RuntimePredictor,
        client: Optional[TimeJumpClient] = None,
        *,
        workers: Optional["object"] = None,   # repro.serving.workers.WorkerGroup
        devices: Optional[VirtualDeviceContext] = None,
        weight_bytes: int = 0,
        kv_pool_bytes: int = 0,
    ):
        self.predictor = predictor
        self.client = client
        self.workers = workers
        self.devices = devices
        # per-step breakdown for the accuracy/split figures; audit modes
        # below "full" switch retention off so memory stays flat over
        # million-request streams (engine.set_audit flips the flag)
        self.retain_estimates = True
        self.step_estimates: List[dict] = []
        if devices is not None:
            n = len(devices.devices)
            self._buffers = []
            for d in range(n):
                if weight_bytes:
                    self._buffers.append(devices.malloc(
                        weight_bytes // n, d, tag="weights"))
                if kv_pool_bytes:
                    self._buffers.append(devices.malloc(
                        kv_pool_bytes // n, d, tag="kv_pool"))

    # ------------------------------------------------------------ running --
    def execute(self, out: SchedulerOutput) -> Dict[int, int]:
        est = self.predictor.predict_step(batch_spec_of(out))
        if self.retain_estimates:
            self.step_estimates.append(est.as_dict())
        if self.workers is not None:
            self.workers.execute_step(est.total)
        elif self.client is not None:
            self.client.time_jump(est.total)          # <-- the Revati patch
        return {s.request.request_id: DUMMY_TOKEN for s in _producing(out)}

    # actor lifecycle (engine parks when idle so it never wedges the barrier)
    def park(self) -> None:
        if self.workers is not None:
            self.workers.park()
        elif self.client is not None:
            self.client.park()

    def unpark(self) -> None:
        if self.workers is not None:
            self.workers.unpark()
        elif self.client is not None:
            self.client.unpark()

    def retire(self) -> None:
        """Permanent departure from the Timekeeper (cluster drain): a real
        deregistration — with the barrier re-evaluation + epoch bump that
        park lacks — so a drained replica is forgotten entirely."""
        if self.workers is not None:
            self.workers.park()          # WorkerGroup park == deregister all
        elif self.client is not None:
            self.client.deregister()

    def shutdown(self) -> None:
        self.park()
        if self.workers is not None:
            self.workers.shutdown()


class SleepModelRunner:
    """Strawman sleep-based emulation (§3.2): correct, wall-clock slow."""

    def __init__(self, predictor: RuntimePredictor, clock: VirtualClock):
        self.predictor = predictor
        self.clock = clock
        self.retain_estimates = True
        self.step_estimates: List[dict] = []

    def execute(self, out: SchedulerOutput) -> Dict[int, int]:
        est = self.predictor.predict_step(batch_spec_of(out))
        if self.retain_estimates:
            self.step_estimates.append(est.as_dict())
        # Precise (spin-tailed) sleep: plain time.sleep overshoots by OS timer
        # slop, which would systematically bias this baseline slow.
        self.clock.wall.sleep_precise(est.total)
        return {s.request.request_id: DUMMY_TOKEN for s in _producing(out)}

    def park(self) -> None: ...
    def unpark(self) -> None: ...
    def retire(self) -> None: ...
    def shutdown(self) -> None: ...


class RealModelRunner:
    """Executes the actual JAX model — ground truth for fidelity runs.

    Slot-based execution with fixed shapes (no recompilation in steady
    state): a shared decode cache holds ``max_seqs`` slots; prefill chunks
    run per-sequence (batch 1, bucketed chunk lengths) and their KV is
    scattered into the slot cache.  Mixed batches execute as
    prefill-calls + one batched decode call; the wall-clock sum is the
    step's real duration (recorded for TablePredictor calibration).
    """

    def __init__(self, model, params, *, max_seqs: int, max_len: int,
                 clock: VirtualClock, chunk_buckets=(32, 64, 128, 256, 512)):
        import jax
        import jax.numpy as jnp

        self.model = model
        self.params = params
        self.max_seqs = max_seqs
        self.max_len = max_len
        self.clock = clock
        self.chunk_buckets = tuple(sorted(chunk_buckets))
        # Padded prefill is only sound for pure-attention stacks (pad KV is
        # position-masked).  Recurrent blocks (SSD / RG-LRU) would fold pad
        # tokens into their state, so those archs run exact-length chunks
        # (one extra compile per distinct remainder length).
        kinds = set(getattr(model.cfg, "layer_pattern", ("attn",)))
        self._pad_prefill = kinds <= {"attn", "local_attn"}
        self._jax = jax
        self._jnp = jnp
        self._slack = self.chunk_buckets[-1]
        self.cache = model.init_cache(max_seqs, max_len, jnp.float32,
                                      window_slack=self._slack)
        self._slot_of: Dict[int, int] = {}
        self._free_slots = list(range(max_seqs))[::-1]
        self._axes = self._cache_batch_axes()
        self._decode = jax.jit(model.decode_step, donate_argnums=(1,))
        self._prefill = jax.jit(model.prefill)
        self.samples: List[tuple] = []       # (BatchSpec, seconds) for fitting
        self._pending_tokens: Dict[int, int] = {}

    # ------------------------------------------------------------ warmup --
    def warmup(self) -> None:
        """Compile every steady-state shape (prefill buckets + the batched
        decode) outside measured time.  Without this, first-call XLA compiles
        (seconds) land inside step timings and poison both the TablePredictor
        calibration and the fidelity comparison — the real-hardware analogue
        of excluding warmup iterations from profiling."""
        jax, jnp = self._jax, self._jnp
        import numpy as np
        cfg = self.model.cfg
        if self._pad_prefill and cfg.frontend is None:
            empty = self.model.init_cache(1, self.max_len, jnp.float32,
                                          window_slack=self._slack)
            for b in self.chunk_buckets:
                toks = jnp.zeros((1, b), jnp.int32)
                pos = jnp.asarray(np.arange(b)[None], jnp.int32)
                small = dict(empty)
                small["cache_len"] = jnp.asarray([0], jnp.int32)
                self._prefill(self.params,
                              {"tokens": toks, "positions": pos}, small)
        toks = jnp.zeros((self.max_seqs, 1), jnp.int32)
        _, donated = self._decode(self.params, self.cache, toks)
        jax.block_until_ready(donated["cache_len"])
        # decode warmup stamped pos-0 tags into every slot; rebuild the pool
        self.cache = self.model.init_cache(self.max_seqs, self.max_len,
                                           jnp.float32,
                                           window_slack=self._slack)

    # ---------------------------------------------------- cache plumbing --
    def _cache_batch_axes(self) -> Dict[str, int]:
        axes = {"cache_len": 0}
        uniform = getattr(self.model, "uniform", "x")
        axes["layers"] = 0 if uniform is None else 1
        axes["cross_k"] = 1
        axes["cross_v"] = 1
        return axes

    def _write_slot(self, slot: int, small_cache) -> None:
        """Scatter a batch-1 cache into slot ``slot`` of the shared cache."""
        jnp = self._jnp
        for key, sub in small_cache.items():
            ax = self._axes.get(key, 0)
            def put(big, small):
                idx = [slice(None)] * big.ndim
                idx[ax] = slice(slot, slot + 1)
                return big.at[tuple(idx)].set(small.astype(big.dtype))
            self.cache[key] = self._jax.tree.map(put, self.cache[key], sub)

    def _slot_cache(self, slot: int):
        def take(big, ax):
            idx = [slice(None)] * big.ndim
            idx[ax] = slice(slot, slot + 1)
            return big[tuple(idx)]
        return {
            key: self._jax.tree.map(lambda x, a=self._axes.get(key, 0): take(x, a), sub)
            for key, sub in self.cache.items()
        }

    # ------------------------------------------------------------ running --
    def execute(self, out: SchedulerOutput) -> Dict[int, int]:
        jax, jnp = self._jax, self._jnp
        t0 = time.monotonic()
        tokens: Dict[int, int] = {}

        prefills = [s for s in out.batch if s.is_prefill]
        decodes = [s for s in out.batch if not s.is_prefill]

        # ---- prefill chunks, per sequence, bucketed lengths ----
        for s in prefills:
            req = s.request
            slot = self._slot_of.get(req.request_id)
            if slot is None:
                slot = self._free_slots.pop()
                self._slot_of[req.request_id] = slot
                # zero the slot
                empty = self.model.init_cache(1, self.max_len, jnp.float32,
                                              window_slack=self._slack)
                self._write_slot(slot, {k: empty[k] for k in empty})
            start = req.num_prefilled
            chunk = list(req.prompt_tokens[start : start + s.num_new_tokens])
            if self._pad_prefill:
                bucket = next((b for b in self.chunk_buckets if b >= len(chunk)),
                              len(chunk))
            else:
                bucket = len(chunk)
            pad = bucket - len(chunk)
            toks = jnp.asarray(chunk + [0] * pad, jnp.int32)[None]
            # pad positions land in the scratch region past max_len: they are
            # masked for every real query (pos > any q_pos) and their ring
            # slots never alias live context.
            real_pos = start + np.arange(len(chunk))
            pad_pos = self.max_len + np.arange(pad)
            positions = jnp.asarray(
                np.concatenate([real_pos, pad_pos])[None], jnp.int32)
            small = self._slot_cache(slot)
            # correct cache_len for padding: advance only by real chunk
            small["cache_len"] = jnp.asarray([start], jnp.int32)
            logits, new_small = self._prefill(
                self.params, {"tokens": toks, "positions": positions}, small)
            new_small["cache_len"] = jnp.asarray([start + len(chunk)], jnp.int32)
            self._write_slot(slot, new_small)
            if start + len(chunk) >= req.prompt_len:
                # padded garbage may occupy ring slots > prompt end; for the
                # fidelity workloads prompts are block-aligned so pad == 0 in
                # the final chunk, and logits are the true first token.
                tokens[req.request_id] = int(jnp.argmax(logits[0]))

        # ---- batched decode over the shared slot cache ----
        if decodes:
            step_tokens = np.zeros((self.max_seqs, 1), np.int32)
            for s in decodes:
                req = s.request
                slot = self._slot_of[req.request_id]
                last = (req.output_tokens[-1] if req.output_tokens
                        else self._pending_tokens.get(req.request_id, 0))
                step_tokens[slot, 0] = last
            # cache_len per slot must reflect each sequence's context
            cl = np.zeros((self.max_seqs,), np.int32)
            for s in decodes:
                cl[self._slot_of[s.request.request_id]] = s.request.context_len
            self.cache["cache_len"] = jnp.asarray(cl)
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(step_tokens))
            picked = np.asarray(jnp.argmax(logits, axis=-1))
            for s in decodes:
                slot = self._slot_of[s.request.request_id]
                tokens[s.request.request_id] = int(picked[slot])

        jax.block_until_ready(self.cache["cache_len"])
        dt = time.monotonic() - t0
        self.samples.append((batch_spec_of(out), dt))

        # release slots of finishing requests
        for s in out.batch:
            req = s.request
            if (not s.is_prefill and
                    req.num_generated + 1 >= req.max_new_tokens):
                slot = self._slot_of.pop(req.request_id, None)
                if slot is not None:
                    self._free_slots.append(slot)
        return tokens

    def release(self, request_id: int) -> None:
        slot = self._slot_of.pop(request_id, None)
        if slot is not None:
            self._free_slots.append(slot)

    def park(self) -> None: ...
    def unpark(self) -> None: ...
    def shutdown(self) -> None: ...
