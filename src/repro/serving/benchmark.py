"""Benchmark runner: dispatches a request stream and collects latency
distributions (paper Fig. 4's left-hand process).

The **request dispatcher is an Actor**: between arrivals it jumps virtual
time to the next dispatch timestamp instead of sleeping — this is the other
half of the paper's integration (the benchmark-runner patch).  The **output
processor is an Observer**: request completion timestamps are read from the
shared virtual clock without participating in barriers.

In real/sleep modes the dispatcher degrades transparently: with no
Timekeeper attached it wall-sleeps to each arrival (the exact strawman
behaviour), so one code path drives all three modes.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.client import TimeJumpClient
from repro.core.clock import VirtualClock

from .engine import LLMEngine
from .request import Request


@dataclass
class LatencyStats:
    mean: float
    p50: float
    p90: float
    p99: float
    values: List[float] = field(repr=False, default_factory=list)

    @staticmethod
    def of(values: Sequence[float]) -> "LatencyStats":
        if not values:
            return LatencyStats(0.0, 0.0, 0.0, 0.0, [])
        arr = np.asarray(values, dtype=np.float64)
        return LatencyStats(
            float(arr.mean()),
            float(np.percentile(arr, 50)),
            float(np.percentile(arr, 90)),
            float(np.percentile(arr, 99)),
            list(map(float, arr)),
        )


@dataclass
class BenchmarkResult:
    ttft: LatencyStats
    tpot: LatencyStats
    e2e: LatencyStats
    makespan_virtual: float
    wall_seconds: float
    num_requests: int
    throughput_tokens_per_s: float
    engine_cpu_overhead: float
    engine_device_time: float

    @property
    def speedup(self) -> float:
        """Virtual seconds simulated per wall second."""
        return self.makespan_virtual / self.wall_seconds if self.wall_seconds else 0.0

    def summary(self) -> dict:
        return {
            "num_requests": self.num_requests,
            "ttft_p50_ms": self.ttft.p50 * 1e3,
            "ttft_p90_ms": self.ttft.p90 * 1e3,
            "ttft_p99_ms": self.ttft.p99 * 1e3,
            "tpot_p50_ms": self.tpot.p50 * 1e3,
            "tpot_p90_ms": self.tpot.p90 * 1e3,
            "e2e_p50_s": self.e2e.p50,
            "makespan_virtual_s": self.makespan_virtual,
            "wall_s": self.wall_seconds,
            "speedup_x": self.speedup,
            "throughput_tok_s": self.throughput_tokens_per_s,
        }


class BenchmarkRunner:
    def __init__(
        self,
        engine: LLMEngine,
        requests: List[Request],
        *,
        transport=None,              # Timekeeper transport (emulate mode)
        name: str = "bench",
    ):
        self.engine = engine
        self.requests = sorted(requests, key=lambda r: r.arrival_time)
        self.transport = transport
        self.name = name
        self.clock: VirtualClock = engine.clock

    # ---------------------------------------------------------- dispatch --
    def _dispatch_loop(self) -> None:
        client: Optional[TimeJumpClient] = None
        if self.transport is not None:
            client = TimeJumpClient(self.transport, f"{self.name}-dispatcher")
        t0 = self.clock.now()
        try:
            for req in self.requests:
                target = t0 + req.arrival_time
                if client is not None:
                    client.jump_to(target)        # Actor: jump, don't sleep
                else:
                    dt = target - self.clock.now()
                    if dt > 0:
                        self.clock.wall.sleep(dt)  # real/sleep modes
                req.arrival_time = self.clock.now()
                self.engine.submit(req)
        finally:
            if client is not None:
                client.deregister()

    # --------------------------------------------------------------- run --
    def run(self, timeout: float = 600.0) -> BenchmarkResult:
        wall0 = time.monotonic()
        v0 = self.clock.now()
        dispatcher = threading.Thread(
            target=self._dispatch_loop, name=f"{self.name}-dispatch", daemon=True)
        started_here = False
        if self.engine._thread is None:
            self.engine.start()
            started_here = True
        dispatcher.start()
        ok = self.engine.wait_until_complete(len(self.requests), timeout=timeout)
        dispatcher.join(timeout=10)
        wall = time.monotonic() - wall0
        v1 = self.clock.now()
        if started_here:
            self.engine.stop()
        if not ok:
            raise TimeoutError(
                f"benchmark timed out: {len(self.engine.finished)}/"
                f"{len(self.requests)} finished")
        return self._collect(wall, v1 - v0)

    def _collect(self, wall: float, makespan: float) -> BenchmarkResult:
        reqs = self.engine.finished
        ttft = LatencyStats.of([r.ttft() for r in reqs if r.ttft() is not None])
        tpot = LatencyStats.of([r.tpot() for r in reqs
                                if r.tpot() is not None and r.num_generated > 1])
        e2e = LatencyStats.of([r.e2e_latency() for r in reqs
                               if r.e2e_latency() is not None])
        total_tokens = sum(r.num_generated for r in reqs)
        cpu = sum(s.cpu_overhead_wall for s in self.engine.step_log)
        dev = sum(s.device_time for s in self.engine.step_log)
        return BenchmarkResult(
            ttft=ttft, tpot=tpot, e2e=e2e,
            makespan_virtual=makespan,
            wall_seconds=wall,
            num_requests=len(reqs),
            throughput_tokens_per_s=total_tokens / makespan if makespan else 0.0,
            engine_cpu_overhead=cpu,
            engine_device_time=dev,
        )


def compare_distributions(a: LatencyStats, b: LatencyStats) -> Dict[str, float]:
    """Percentile-wise relative error between two latency distributions
    (the paper's Fig. 6/8 accuracy metric: <5% across the CDF)."""
    out = {}
    for q in (50, 75, 90, 95, 99):
        va = float(np.percentile(a.values, q)) if a.values else 0.0
        vb = float(np.percentile(b.values, q)) if b.values else 0.0
        denom = max(abs(va), 1e-9)
        out[f"p{q}_rel_err"] = abs(va - vb) / denom
    out["median_rel_err"] = out["p50_rel_err"]
    return out
