"""Benchmark pipeline: Workload → Cluster → Metrics (paper Fig. 4, scaled).

The runner is decoupled from any one engine: it drives a *target* — a single
:class:`~repro.serving.engine.LLMEngine` or an N-replica
:class:`~repro.cluster.Cluster` — through the uniform non-blocking surface
both expose (``submit`` / ``wait_until_complete`` / ``finished`` /
``step_log`` / ``clock``).  Dataflow:

    Workload (synthesize/replay)  →  dispatcher (Actor: time-jumps to each
    arrival, routes via the target's submit)  →  target replicas (engines
    stepping on the shared virtual clock)  →  Metrics (Observer: collects
    TTFT/TPOT/e2e/goodput percentiles from completion timestamps).

The **request dispatcher is an Actor**: between arrivals it jumps virtual
time to the next dispatch timestamp instead of sleeping.  The **metrics
collector is an Observer**: completion timestamps are read from the shared
virtual clock without participating in barriers.  In real/sleep modes the
dispatcher degrades transparently: with no Timekeeper attached it
wall-sleeps to each arrival (the exact strawman behaviour), so one code
path drives all modes and all cluster sizes.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.client import TimeJumpClient
from repro.core.clock import VirtualClock

from .request import Request


@dataclass
class LatencyStats:
    mean: float
    p50: float
    p90: float
    p99: float
    values: List[float] = field(repr=False, default_factory=list)

    @staticmethod
    def of(values: Sequence[float]) -> "LatencyStats":
        if not values:
            return LatencyStats(0.0, 0.0, 0.0, 0.0, [])
        arr = np.asarray(values, dtype=np.float64)
        return LatencyStats(
            float(arr.mean()),
            float(np.percentile(arr, 50)),
            float(np.percentile(arr, 90)),
            float(np.percentile(arr, 99)),
            list(map(float, arr)),
        )


@dataclass
class BenchmarkResult:
    ttft: LatencyStats
    tpot: LatencyStats
    e2e: LatencyStats
    makespan_virtual: float
    wall_seconds: float
    num_requests: int
    throughput_tokens_per_s: float
    engine_cpu_overhead: float
    engine_device_time: float
    num_replicas: int = 1
    per_replica: List[dict] = field(repr=False, default_factory=list)
    routing_policy: Optional[str] = None
    # (ttft, tpot) per completed request; tpot is None for 1-token outputs
    slo_samples: List[tuple] = field(repr=False, default_factory=list)

    @property
    def speedup(self) -> float:
        """Virtual seconds simulated per wall second."""
        return self.makespan_virtual / self.wall_seconds if self.wall_seconds else 0.0

    @property
    def request_rate_completed(self) -> float:
        """Completed requests per virtual second (cluster throughput)."""
        return (self.num_requests / self.makespan_virtual
                if self.makespan_virtual else 0.0)

    def goodput_rps(self, slo_ttft_s: float = float("inf"),
                    slo_tpot_s: float = float("inf")) -> float:
        """SLO-attaining completions per virtual second: only requests whose
        TTFT and TPOT both meet the SLOs count (DistServe-style goodput).
        A request with no TPOT sample (single-token output) is judged on
        TTFT alone."""
        if not self.makespan_virtual:
            return 0.0
        good = 0
        for ttft, tpot in self.slo_samples:
            ttft_ok = ttft is None or ttft <= slo_ttft_s
            tpot_ok = tpot is None or tpot <= slo_tpot_s
            good += int(ttft_ok and tpot_ok)
        return good / self.makespan_virtual

    def summary(self) -> dict:
        out = {
            "num_requests": self.num_requests,
            "ttft_p50_ms": self.ttft.p50 * 1e3,
            "ttft_p90_ms": self.ttft.p90 * 1e3,
            "ttft_p99_ms": self.ttft.p99 * 1e3,
            "tpot_p50_ms": self.tpot.p50 * 1e3,
            "tpot_p90_ms": self.tpot.p90 * 1e3,
            "e2e_p50_s": self.e2e.p50,
            "makespan_virtual_s": self.makespan_virtual,
            "wall_s": self.wall_seconds,
            "speedup_x": self.speedup,
            "throughput_tok_s": self.throughput_tokens_per_s,
            "completed_rps": self.request_rate_completed,
        }
        if self.num_replicas > 1:
            out["num_replicas"] = self.num_replicas
            out["routing_policy"] = self.routing_policy
        return out


def _is_started(target) -> bool:
    """Engine, cluster, and the disagg facade all expose ``is_running``."""
    return bool(getattr(target, "is_running", False))


class BenchmarkRunner:
    """Drive a request stream through an engine or a cluster.

    ``target`` needs only the uniform replica surface: ``submit``,
    ``start``/``stop``, ``wait_until_complete``, ``finished``,
    ``step_log``, and a ``clock`` attribute.
    """

    def __init__(
        self,
        target,
        requests: List[Request],
        *,
        transport=None,              # Timekeeper transport (emulate mode)
        name: str = "bench",
    ):
        self.target = target
        self.engine = target         # backwards-compatible alias
        self.requests = sorted(requests, key=lambda r: r.arrival_time)
        self.transport = transport
        self.name = name
        self.clock: VirtualClock = target.clock

    # ---------------------------------------------------------- dispatch --
    def _dispatch_loop(self) -> None:
        client: Optional[TimeJumpClient] = None
        if self.transport is not None:
            client = TimeJumpClient(self.transport, f"{self.name}-dispatcher")
        t0 = self.clock.now()
        try:
            for req in self.requests:
                target_t = t0 + req.arrival_time
                if client is not None:
                    client.jump_to(target_t)      # Actor: jump, don't sleep
                else:
                    dt = target_t - self.clock.now()
                    if dt > 0:
                        self.clock.wall.sleep(dt)  # real/sleep modes
                req.arrival_time = self.clock.now()
                self.target.submit(req)
        finally:
            if client is not None:
                client.deregister()

    # --------------------------------------------------------------- run --
    def run(self, timeout: float = 600.0) -> BenchmarkResult:
        wall0 = time.monotonic()
        v0 = self.clock.now()
        dispatcher = threading.Thread(
            target=self._dispatch_loop, name=f"{self.name}-dispatch", daemon=True)
        started_here = False
        if not _is_started(self.target):
            self.target.start()
            started_here = True
        dispatcher.start()
        ok = self.target.wait_until_complete(len(self.requests), timeout=timeout)
        dispatcher.join(timeout=10)
        wall = time.monotonic() - wall0
        v1 = self.clock.now()
        if started_here:
            self.target.stop()
        if not ok:
            raise TimeoutError(
                f"benchmark timed out: {len(self.target.finished)}/"
                f"{len(self.requests)} finished")
        return self._collect(wall, v1 - v0)

    def _collect(self, wall: float, makespan: float) -> BenchmarkResult:
        reqs = self.target.finished
        ttft = LatencyStats.of([r.ttft() for r in reqs if r.ttft() is not None])
        tpot = LatencyStats.of([r.tpot() for r in reqs
                                if r.tpot() is not None and r.num_generated > 1])
        e2e = LatencyStats.of([r.e2e_latency() for r in reqs
                               if r.e2e_latency() is not None])
        total_tokens = sum(r.num_generated for r in reqs)
        step_log = self.target.step_log
        cpu = sum(s.cpu_overhead_wall for s in step_log)
        dev = sum(s.device_time for s in step_log)
        engines = getattr(self.target, "engines", None)
        return BenchmarkResult(
            ttft=ttft, tpot=tpot, e2e=e2e,
            makespan_virtual=makespan,
            wall_seconds=wall,
            num_requests=len(reqs),
            throughput_tokens_per_s=total_tokens / makespan if makespan else 0.0,
            engine_cpu_overhead=cpu,
            engine_device_time=dev,
            num_replicas=len(engines) if engines else 1,
            per_replica=([e.stats() for e in engines] if engines else []),
            routing_policy=getattr(
                getattr(self.target, "router", None), "policy", None),
            slo_samples=[
                (r.ttft(),
                 r.tpot() if r.num_generated > 1 else None)
                for r in reqs
            ],
        )


def run_pipeline(workload_cfg, target, *, transport=None,
                 timeout: float = 600.0) -> BenchmarkResult:
    """One-call Workload → Cluster → Metrics pipeline: synthesize the
    request stream from a WorkloadConfig and benchmark ``target`` with it."""
    from .workload import synthesize

    reqs = synthesize(workload_cfg)
    return BenchmarkRunner(target, reqs, transport=transport).run(timeout=timeout)


def compare_distributions(a: LatencyStats, b: LatencyStats) -> Dict[str, float]:
    """Percentile-wise relative error between two latency distributions
    (the paper's Fig. 6/8 accuracy metric: <5% across the CDF)."""
    out = {}
    for q in (50, 75, 90, 95, 99):
        va = float(np.percentile(a.values, q)) if a.values else 0.0
        vb = float(np.percentile(b.values, q)) if b.values else 0.0
        denom = max(abs(va), 1e-9)
        out[f"p{q}_rel_err"] = abs(va - vb) / denom
    out["median_rel_err"] = out["p50_rel_err"]
    return out
