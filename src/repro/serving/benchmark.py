"""Benchmark pipeline: Workload → Cluster → Metrics (paper Fig. 4, scaled).

The runner is decoupled from any one engine: it drives a *target* — a single
:class:`~repro.serving.engine.LLMEngine` or an N-replica
:class:`~repro.cluster.Cluster` — through the uniform non-blocking surface
both expose (``submit`` / ``wait_until_complete`` / ``finished`` /
``step_log`` / ``clock``).  Dataflow:

    Workload (synthesize/replay/sessions)  →  dispatcher (Actor: time-jumps
    to each arrival, routes via the target's submit)  →  target replicas
    (engines stepping on the shared virtual clock)  →  Metrics (Observer:
    TTFT/TPOT/e2e/goodput/SLO-attainment percentiles, per-session stats,
    replica-seconds).

The **request dispatcher is an Actor**: between arrivals it jumps virtual
time to the next dispatch timestamp instead of sleeping.  The **metrics
collector is an Observer**: completion timestamps are read from the shared
virtual clock without participating in barriers.  In real/sleep modes the
dispatcher degrades transparently: with no Timekeeper attached it
wall-sleeps to each arrival (the exact strawman behaviour), so one code
path drives all modes and all cluster sizes.

Closed loop: given a :class:`~repro.workload.session.SessionWorkload`, the
runner registers a completion listener on the target; each finished turn
re-injects its follow-up (carrying the prior turn's tokens) through a
*think-time actor* — a short-lived Timekeeper client registered
synchronously in the finishing replica's step thread (before its next
barrier round, the §4.3 trick), which jumps to ``finish + think`` and
submits.  Virtual time therefore can never skip over a pending follow-up,
even while the open-loop dispatcher is mid-jump toward a far-future arrival.
"""

from __future__ import annotations

import heapq
import itertools
import threading
import time
from collections import defaultdict
from dataclasses import dataclass, field
from operator import attrgetter
from typing import Dict, List, Optional

import numpy as np

from repro.core.client import TimeJumpClient
from repro.core.clock import VirtualClock
# LatencyStats and compare_distributions moved to repro.metrics (the
# O(1)-memory scale path); re-exported here for backwards compatibility.
from repro.metrics import (LatencyStats, StreamingMetrics,
                           compare_distributions)

from .request import Request

__all__ = ["LatencyStats", "BenchmarkResult", "BenchmarkRunner",
           "run_pipeline", "compare_distributions"]

AUDIT_MODES = ("full", "sampled", "off")


@dataclass
class BenchmarkResult:
    ttft: LatencyStats
    tpot: LatencyStats
    e2e: LatencyStats
    makespan_virtual: float
    wall_seconds: float
    num_requests: int
    throughput_tokens_per_s: float
    engine_cpu_overhead: float
    engine_device_time: float
    num_replicas: int = 1
    per_replica: List[dict] = field(repr=False, default_factory=list)
    routing_policy: Optional[str] = None
    # (ttft, tpot) per completed request; tpot is None for 1-token outputs.
    # audit="full": every request.  audit="sampled": a seeded uniform
    # reservoir — num_slo_samples keeps the exact observation count so
    # goodput stays unbiased.  audit="off": empty.
    slo_samples: List[tuple] = field(repr=False, default_factory=list)
    num_slo_samples: int = 0
    audit: str = "full"
    # cost proxy: total replica-on virtual seconds across the run window
    # (elastic membership: drained replicas stop accruing, added ones start
    # at their join time; fixed clusters: num_replicas * makespan)
    replica_seconds: float = 0.0
    # heterogeneous pools: replica-on seconds per hardware tier over the run
    # window, and their dollar cost (per-tier $/replica-second from the
    # ChipSpec).  0.0 / None when the target is untiered.
    cost_dollars: float = 0.0
    tier_seconds: Optional[Dict[str, float]] = None
    # closed-loop session stats (None for open-loop workloads): percentiles
    # over *per-session mean* TTFT / TPOT — the chat-level experience
    num_sessions: int = 0
    session_ttft: Optional[LatencyStats] = None
    session_tpot: Optional[LatencyStats] = None

    @property
    def speedup(self) -> float:
        """Virtual seconds simulated per wall second."""
        return self.makespan_virtual / self.wall_seconds if self.wall_seconds else 0.0

    @property
    def request_rate_completed(self) -> float:
        """Completed requests per virtual second (cluster throughput)."""
        return (self.num_requests / self.makespan_virtual
                if self.makespan_virtual else 0.0)

    def slo_attainment(self, slo_ttft_s: float = float("inf"),
                       slo_tpot_s: float = float("inf")) -> float:
        """Fraction of completed requests meeting both SLOs.  A request with
        no TPOT sample (single-token output) is judged on TTFT alone."""
        if not self.slo_samples:
            return 0.0
        good = 0
        for ttft, tpot in self.slo_samples:
            ttft_ok = ttft is None or ttft <= slo_ttft_s
            tpot_ok = tpot is None or tpot <= slo_tpot_s
            good += int(ttft_ok and tpot_ok)
        return good / len(self.slo_samples)

    def goodput_rps(self, slo_ttft_s: float = float("inf"),
                    slo_tpot_s: float = float("inf")) -> float:
        """SLO-attaining completions per virtual second (DistServe-style).

        Under ``audit="sampled"`` the attainment fraction comes from the
        reservoir but is scaled by the *exact* completion count, so goodput
        carries no subsampling bias in its magnitude."""
        if not self.makespan_virtual:
            return 0.0
        n = self.num_slo_samples or len(self.slo_samples)
        return (self.slo_attainment(slo_ttft_s, slo_tpot_s)
                * n / self.makespan_virtual)

    def summary(self) -> dict:
        out = {
            "num_requests": self.num_requests,
            "ttft_p50_ms": self.ttft.p50 * 1e3,
            "ttft_p90_ms": self.ttft.p90 * 1e3,
            "ttft_p99_ms": self.ttft.p99 * 1e3,
            "tpot_p50_ms": self.tpot.p50 * 1e3,
            "tpot_p90_ms": self.tpot.p90 * 1e3,
            "e2e_p50_s": self.e2e.p50,
            "makespan_virtual_s": self.makespan_virtual,
            "wall_s": self.wall_seconds,
            "speedup_x": self.speedup,
            "throughput_tok_s": self.throughput_tokens_per_s,
            "completed_rps": self.request_rate_completed,
            "replica_seconds": self.replica_seconds,
        }
        if self.num_replicas > 1:
            out["num_replicas"] = self.num_replicas
            out["routing_policy"] = self.routing_policy
        if self.cost_dollars:
            out["cost_dollars"] = self.cost_dollars
        if self.num_sessions:
            out["num_sessions"] = self.num_sessions
            out["session_ttft_p50_ms"] = self.session_ttft.p50 * 1e3
            out["session_ttft_p99_ms"] = self.session_ttft.p99 * 1e3
        return out


def _is_started(target) -> bool:
    """Engine, cluster, and the disagg facade all expose ``is_running``."""
    return bool(getattr(target, "is_running", False))


def _declared_count(workload) -> Optional[int]:
    """A workload's self-declared request count, if it declares one."""
    for attr in ("total_requests", "expected"):
        n = getattr(workload, attr, None)
        if n is not None:
            return int(n)
    return None


def _num_finished(target) -> int:
    """Completion count without touching retained lists (audit != full
    keeps a counter, not the requests)."""
    n = getattr(target, "finished_count", None)
    if n is not None:
        return int(n)
    return len(target.finished)


class BenchmarkRunner:
    """Drive a request stream (open- or closed-loop) through an engine or a
    cluster.

    ``workload`` is one of:

    - a list of :class:`Request` (open loop, eagerly materialized — sorted
      here, exactly the historical behavior);
    - a :class:`~repro.workload.session.SessionWorkload` /
      :class:`~repro.workload.streaming.StreamingSessionWorkload` (closed
      loop: follow-up turns are released on completion + think time);
    - a lazy arrival-sorted request stream — e.g.
      :class:`~repro.workload.streaming.StreamingWorkload` — or a list of
      several such streams, which the dispatcher heap-merges on
      ``arrival_time`` without materializing any of them.

    Streaming workloads must declare how many requests the run waits for:
    either the workload exposes ``expected`` / ``total_requests`` or the
    caller passes ``expected=N`` — there is no ``len(requests)`` fallback
    to fall back on.

    ``audit`` bounds result memory: ``"full"`` (default) retains every
    finished request on the target and builds metrics from the raw lists;
    ``"sampled"`` keeps O(1) sketches + a seeded SLO reservoir and tells
    the target to drop per-request retention (``set_audit``); ``"off"``
    additionally drops the reservoir.  Percentiles under sampled/off are
    bit-identical to full below the sketch's exact cap (~2k samples) and
    carry ±0.5% rank error beyond.

    ``target`` needs only the uniform replica surface: ``submit``,
    ``start``/``stop``, ``wait_until_complete``, ``finished``,
    ``step_log``, and a ``clock`` attribute — plus
    ``add_completion_listener`` for closed-loop or audited runs.

    ``autoscaler`` (optional, cluster targets): started/stopped with the
    run; its membership changes are reflected in ``replica_seconds``.
    """

    def __init__(
        self,
        target,
        workload,
        *,
        transport=None,              # Timekeeper transport (emulate mode)
        autoscaler=None,             # repro.cluster.autoscaler.Autoscaler
        fault_injector=None,         # repro.cluster.faults.FaultInjector
        name: str = "bench",
        expected: Optional[int] = None,   # streaming: declared request count
        audit: str = "full",
        metrics_seed: int = 0,       # reservoir seed (audit="sampled")
        slo_reservoir: int = 8192,
    ):
        if audit not in AUDIT_MODES:
            raise ValueError(f"audit must be one of {AUDIT_MODES}, "
                             f"got {audit!r}")
        self.target = target
        self.engine = target         # backwards-compatible alias
        self.audit = audit
        self.session_workload = None
        self.requests: Optional[List[Request]] = None
        declared = expected

        if hasattr(workload, "initial_stream"):
            # streaming closed loop: turn-0 requests arrive lazily
            self.session_workload = workload
            streams = [workload.initial_stream()]
            if declared is None:
                declared = workload.total_requests
        elif hasattr(workload, "initial_requests"):
            # eager closed loop (historical behavior, list retained)
            self.session_workload = workload
            self.requests = sorted(workload.initial_requests(),
                                   key=lambda r: r.arrival_time)
            streams = [iter(self.requests)]
            if declared is None:
                declared = workload.total_requests
        elif (isinstance(workload, (list, tuple)) and workload
              and not hasattr(workload[0], "arrival_time")):
            # several arrival-sorted streams: heap-merge below
            streams = [iter(s) for s in workload]
            if declared is None:
                counts = [_declared_count(s) for s in workload]
                if all(c is not None for c in counts):
                    declared = sum(counts)
        elif isinstance(workload, (list, tuple)):
            # eager open loop (historical behavior, list retained + sorted)
            self.requests = sorted(workload, key=lambda r: r.arrival_time)
            streams = [iter(self.requests)]
            if declared is None:
                declared = len(self.requests)
        else:
            # one lazy arrival-sorted stream
            streams = [iter(workload)]
            if declared is None:
                declared = _declared_count(workload)

        if declared is None:
            raise ValueError(
                "streaming workload with no declared request count: the "
                "runner cannot fall back to len(requests) without "
                "materializing the stream.  Pass expected=N to "
                "BenchmarkRunner, or use a workload that exposes "
                "`.expected` / `.total_requests` (e.g. "
                "repro.workload.StreamingWorkload)")
        self.expected = int(declared)
        # the dispatcher pulls from one heap-merged stream; each source must
        # be individually sorted by arrival_time (all synthesizers are)
        self._stream = (streams[0] if len(streams) == 1
                        else heapq.merge(*streams,
                                         key=attrgetter("arrival_time")))
        self.transport = transport
        self.autoscaler = autoscaler
        self.fault_injector = fault_injector
        self.name = name
        self.clock: VirtualClock = target.clock
        self._think_ids = itertools.count()
        self._thinkers: List[threading.Thread] = []
        self._metrics: Optional[StreamingMetrics] = None
        if self.audit != "full":
            self._metrics = StreamingMetrics(
                slo_reservoir=slo_reservoir, seed=metrics_seed,
                session_turns=getattr(self.session_workload,
                                      "session_turns", None))

    # ---------------------------------------------------------- dispatch --
    def _dispatch_loop(self, client: Optional[TimeJumpClient]) -> None:
        t0 = self.clock.now()
        try:
            for req in self._stream:
                target_t = t0 + req.arrival_time
                if client is not None:
                    client.jump_to(target_t)      # Actor: jump, don't sleep
                else:
                    dt = target_t - self.clock.now()
                    if dt > 0:
                        self.clock.wall.sleep(dt)  # real/sleep modes
                req.arrival_time = self.clock.now()
                self.target.submit(req)
        finally:
            if client is not None:
                client.deregister()

    # -------------------------------------------------------- closed loop --
    def _on_complete(self, finished: List[Request]) -> None:
        """Completion listener: runs in the finishing replica's step thread,
        before its next barrier round.  Registering the think-time actor
        *here* is what makes the re-injection race-free: the barrier cannot
        advance past ``finish + think`` before the new actor's jump request
        is pending (§4.3)."""
        for req in finished:
            fu = self.session_workload.follow_up(req)
            if fu is None:
                continue
            client: Optional[TimeJumpClient] = None
            if self.transport is not None:
                client = TimeJumpClient(
                    self.transport,
                    f"{self.name}-think-{next(self._think_ids)}")
            t = threading.Thread(
                target=self._think_and_submit, args=(fu, client),
                name=f"{self.name}-think", daemon=True)
            t.start()
            # drop joined thinkers so the list tracks *live* actors, not
            # every follow-up ever released (a million-turn run would
            # otherwise accumulate a million dead Thread objects)
            if len(self._thinkers) > 64:
                self._thinkers = [th for th in self._thinkers
                                  if th.is_alive()]
            self._thinkers.append(t)

    # ------------------------------------------------------ audited runs --
    def _observe_completions(self, finished: List[Request]) -> None:
        """Completion listener (audit != "full"): fold each finished request
        into the streaming accumulators; nothing is retained."""
        for req in finished:
            self._metrics.observe(req)

    def _think_and_submit(self, fu: Request,
                          client: Optional[TimeJumpClient]) -> None:
        try:
            if client is not None:
                client.jump_to(fu.arrival_time)
            else:
                dt = fu.arrival_time - self.clock.now()
                if dt > 0:
                    self.clock.wall.sleep(dt)
            fu.arrival_time = self.clock.now()
            self.target.submit(fu)
        finally:
            if client is not None:
                client.deregister()

    # --------------------------------------------------------------- run --
    def run(self, timeout: float = 600.0) -> BenchmarkResult:
        wall0 = time.monotonic()
        v0 = self.clock.now()
        listener_armed = False
        if self.session_workload is not None:
            self.target.add_completion_listener(self._on_complete)
            listener_armed = True
        metrics_armed = False
        if self._metrics is not None:
            # bounded-audit mode: metrics accumulate per completion and the
            # target stops retaining per-request state
            if hasattr(self.target, "set_audit"):
                self.target.set_audit(self.audit)
            self.target.add_completion_listener(self._observe_completions)
            metrics_armed = True
        # The dispatcher's actor is registered HERE, before the autoscaler's
        # tick actor can start jumping: were the autoscaler briefly the only
        # registered actor, its ticks would free-run virtual time far ahead
        # of the first arrival (barrier rounds resolve instantly for a lone
        # actor) and shift the whole timeline.
        disp_client: Optional[TimeJumpClient] = None
        if self.transport is not None:
            disp_client = TimeJumpClient(self.transport,
                                         f"{self.name}-dispatcher")
        # Same anchoring rule for the chaos schedule: arm (register) the
        # injector's actor before any other actor can move virtual time, so
        # fault times measure from the run's origin.
        if self.fault_injector is not None:
            self.fault_injector.arm()
        dispatcher = threading.Thread(
            target=self._dispatch_loop, args=(disp_client,),
            name=f"{self.name}-dispatch", daemon=True)
        started_here = False
        if not _is_started(self.target):
            self.target.start()
            started_here = True
        if self.autoscaler is not None:
            self.autoscaler.start()
        if self.fault_injector is not None:
            self.fault_injector.start()
        dispatcher.start()
        try:
            ok = self.target.wait_until_complete(self.expected, timeout=timeout)
            if ok and self.fault_injector is not None:
                # trailing schedule entries (after the last completion) must
                # apply deterministically, not race teardown — the DES drains
                # its heap unconditionally and the fault logs are compared
                self.fault_injector.join()
        finally:
            if self.fault_injector is not None:
                self.fault_injector.stop()
            if self.autoscaler is not None:
                self.autoscaler.stop()
            if listener_armed:
                self.target.remove_completion_listener(self._on_complete)
        dispatcher.join(timeout=10)
        for t in self._thinkers:
            t.join(timeout=10)
        if metrics_armed:
            self.target.remove_completion_listener(
                self._observe_completions)
        wall = time.monotonic() - wall0
        v1 = self.clock.now()
        if started_here:
            self.target.stop()
        if not ok:
            raise TimeoutError(
                f"benchmark timed out: {_num_finished(self.target)}/"
                f"{self.expected} finished")
        if self._metrics is not None:
            return self._collect_streaming(wall, v0, v1)
        return self._collect(wall, v0, v1)

    def _collect(self, wall: float, v0: float, v1: float) -> BenchmarkResult:
        reqs = self.target.finished
        # Makespan ends at the last completion, not at teardown: trailing
        # autoscaler ticks (which keep jumping the clock after the final
        # finish) must not leak into throughput/goodput denominators.
        finishes = [r.finish_time for r in reqs if r.finish_time is not None]
        v_end = max(finishes) if finishes else v1
        makespan = v_end - v0
        ttft = LatencyStats.of([r.ttft() for r in reqs if r.ttft() is not None])
        tpot = LatencyStats.of([r.tpot() for r in reqs
                                if r.tpot() is not None and r.num_generated > 1])
        e2e = LatencyStats.of([r.e2e_latency() for r in reqs
                               if r.e2e_latency() is not None])
        total_tokens = sum(r.num_generated for r in reqs)
        step_log = self.target.step_log
        cpu = sum(s.cpu_overhead_wall for s in step_log)
        dev = sum(s.device_time for s in step_log)
        engines = getattr(self.target, "engines", None)
        if hasattr(self.target, "replica_seconds"):
            replica_s = self.target.replica_seconds(v0, v_end)
        else:
            replica_s = makespan            # a single engine, always on
        cost = tier_s = None
        if hasattr(self.target, "replica_cost"):
            cost = self.target.replica_cost(v0, v_end)
        if hasattr(self.target, "tier_seconds"):
            tier_s = self.target.tier_seconds(v0, v_end)
        by_session: Dict[int, List[Request]] = defaultdict(list)
        for r in reqs:
            if r.session_id is not None:
                by_session[r.session_id].append(r)
        session_ttft = session_tpot = None
        if by_session:
            mean_ttfts, mean_tpots = [], []
            for rs in by_session.values():
                ts = [r.ttft() for r in rs if r.ttft() is not None]
                ps = [r.tpot() for r in rs
                      if r.tpot() is not None and r.num_generated > 1]
                if ts:
                    mean_ttfts.append(float(np.mean(ts)))
                if ps:
                    mean_tpots.append(float(np.mean(ps)))
            session_ttft = LatencyStats.of(mean_ttfts)
            session_tpot = LatencyStats.of(mean_tpots)
        return BenchmarkResult(
            ttft=ttft, tpot=tpot, e2e=e2e,
            makespan_virtual=makespan,
            wall_seconds=wall,
            num_requests=len(reqs),
            throughput_tokens_per_s=total_tokens / makespan if makespan else 0.0,
            engine_cpu_overhead=cpu,
            engine_device_time=dev,
            num_replicas=len(engines) if engines else 1,
            per_replica=([e.stats() for e in engines] if engines else []),
            routing_policy=getattr(
                getattr(self.target, "router", None), "policy", None),
            slo_samples=[
                (r.ttft(),
                 r.tpot() if r.num_generated > 1 else None)
                for r in reqs
            ],
            replica_seconds=replica_s,
            cost_dollars=cost or 0.0,
            tier_seconds=tier_s,
            num_sessions=len(by_session),
            session_ttft=session_ttft,
            session_tpot=session_tpot,
        )

    def _collect_streaming(self, wall: float, v0: float,
                           v1: float) -> BenchmarkResult:
        """Build the result from the streaming accumulators: no walk over
        ``target.finished`` (which audit != "full" does not retain)."""
        m = self._metrics
        m.finalize()
        v_end = m.max_finish if m.max_finish is not None else v1
        makespan = v_end - v0
        stats = self.target.stats() if hasattr(self.target, "stats") else {}
        cpu = float(stats.get("cpu_overhead_s", 0.0))
        dev = float(stats.get("device_time_s", 0.0))
        engines = getattr(self.target, "engines", None)
        if hasattr(self.target, "replica_seconds"):
            replica_s = self.target.replica_seconds(v0, v_end)
        else:
            replica_s = makespan
        cost = tier_s = None
        if hasattr(self.target, "replica_cost"):
            cost = self.target.replica_cost(v0, v_end)
        if hasattr(self.target, "tier_seconds"):
            tier_s = self.target.tier_seconds(v0, v_end)
        has_sessions = self.session_workload is not None
        return BenchmarkResult(
            ttft=m.ttft.stats(), tpot=m.tpot.stats(), e2e=m.e2e.stats(),
            makespan_virtual=makespan,
            wall_seconds=wall,
            num_requests=m.count,
            throughput_tokens_per_s=(m.total_new_tokens / makespan
                                     if makespan else 0.0),
            engine_cpu_overhead=cpu,
            engine_device_time=dev,
            num_replicas=len(engines) if engines else 1,
            per_replica=([e.stats() for e in engines] if engines else []),
            routing_policy=getattr(
                getattr(self.target, "router", None), "policy", None),
            slo_samples=([] if self.audit == "off"
                         else list(m.slo.items)),
            num_slo_samples=m.num_slo_samples,
            audit=self.audit,
            replica_seconds=replica_s,
            cost_dollars=cost or 0.0,
            tier_seconds=tier_s,
            num_sessions=m.num_sessions if has_sessions else 0,
            session_ttft=m.session_ttft.stats() if has_sessions else None,
            session_tpot=m.session_tpot.stats() if has_sessions else None,
        )


def run_pipeline(workload_cfg, target, *, transport=None,
                 timeout: float = 600.0) -> BenchmarkResult:
    """One-call Workload → Cluster → Metrics pipeline: synthesize the
    request stream from a WorkloadConfig (open loop) or SessionConfig
    (closed loop) and benchmark ``target`` with it."""
    from repro.workload import SessionConfig, SessionWorkload, synthesize

    if isinstance(workload_cfg, SessionConfig):
        workload = SessionWorkload(workload_cfg)
    else:
        workload = synthesize(workload_cfg)
    return BenchmarkRunner(target, workload,
                           transport=transport).run(timeout=timeout)
