"""Radix-tree prefix cache with hierarchical tiers.

Device tier: a radix tree over block-granular token chunks whose leaves pin
KV blocks in the :class:`BlockManager`.  A lookup returns the longest cached
prefix (whole blocks); matched blocks are refcounted into the requesting
sequence's block table instead of recomputing their KV.

Hierarchical tier (paper §2.3): a host-memory tier with **two write
policies**, reproducing the semantic divergence the paper calls out between
the engines:

* ``write_through`` (vLLM + LMCache): every block inserted into the device
  tier is immediately copied to the host tier.
* ``write_through_selective`` (SGLang): a block is copied to the host tier
  only upon its *first cache hit* (asynchronously in the real system; we
  charge the copy at hit time).

On a device-tier miss that hits the host tier, blocks are restored (the
engine charges the H2D transfer duration via its predictor).  Eviction is
LRU over unpinned leaves in virtual time.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .kv_cache import BlockManager


@dataclass
class _Node:
    chunk: Tuple[int, ...]                    # block_size token ids
    block_id: Optional[int]                   # device block (None = evicted)
    children: Dict[Tuple[int, ...], "_Node"] = field(default_factory=dict)
    parent: Optional["_Node"] = None
    last_access: float = 0.0
    pinned: int = 0                           # outstanding matched requests


@dataclass
class PrefixCacheStats:
    lookups: int = 0
    hit_tokens: int = 0
    query_tokens: int = 0
    device_hits: int = 0
    host_hits: int = 0
    evictions: int = 0
    host_evictions: int = 0
    inserts: int = 0

    @property
    def hit_rate(self) -> float:
        return self.hit_tokens / self.query_tokens if self.query_tokens else 0.0


class RadixPrefixCache:
    def __init__(
        self,
        block_manager: BlockManager,
        *,
        enable: bool = True,
        host_tier_blocks: int = 0,                 # 0 = no hierarchical tier
        host_write_policy: str = "write_through",  # | write_through_selective
    ):
        self.bm = block_manager
        self.enable = enable
        self.block_size = block_manager.block_size
        self.root = _Node(chunk=(), block_id=None)
        self._nodes_by_block: Dict[int, _Node] = {}
        self.host_tier_blocks = host_tier_blocks
        self.host_write_policy = host_write_policy
        self._host: Dict[Tuple[Tuple[int, ...], ...], float] = {}  # path -> last access
        self.stats = PrefixCacheStats()

    # -------------------------------------------------------------- match --
    def _chunks(self, tokens: Sequence[int]) -> List[Tuple[int, ...]]:
        bs = self.block_size
        n_full = len(tokens) // bs
        return [tuple(tokens[i * bs : (i + 1) * bs]) for i in range(n_full)]

    def match(self, tokens: Sequence[int], now: float) -> Tuple[List[int], int, int]:
        """Longest-prefix match.  Returns (device_block_ids, n_device_tokens,
        n_host_tokens).  Matched device blocks are pinned (caller must
        release via :meth:`release` when the request frees its table —
        the BlockManager refcount handles that automatically since the
        blocks enter the request's block table)."""
        if not self.enable:
            return [], 0, 0
        self.stats.lookups += 1
        self.stats.query_tokens += len(tokens)
        node = self.root
        blocks: List[int] = []
        path: List[Tuple[int, ...]] = []
        host_tokens = 0
        for chunk in self._chunks(tokens):
            child = node.children.get(chunk)
            if child is None or child.block_id is None:
                # device miss: consult host tier for the extended path
                if self.host_tier_blocks:
                    cand = tuple(path + [chunk])
                    if cand in self._host:
                        self._host[cand] = now
                        host_tokens += self.block_size
                        self.stats.host_hits += 1
                        path.append(chunk)
                        # (engine restores the block + charges transfer time)
                        continue
                break
            node = child
            node.last_access = now
            blocks.append(node.block_id)
            path.append(chunk)
            self.stats.device_hits += 1
            # SGLang-style: first hit promotes the block to the host tier
            if (self.host_tier_blocks
                    and self.host_write_policy == "write_through_selective"):
                self._host_insert(tuple(path), now)
        self.stats.hit_tokens += len(blocks) * self.block_size + host_tokens
        return blocks, len(blocks) * self.block_size, host_tokens

    def probe(self, tokens: Sequence[int]) -> int:
        """Read-only longest-prefix probe: returns the number of device-tier
        cached tokens without touching stats, LRU timestamps, pins, or the
        host tier.  Safe to call concurrently with engine mutation (chunk
        lookups are dict ``get``s under the GIL); routers use it to score
        prefix affinity without perturbing cache behaviour."""
        if not self.enable:
            return 0
        node = self.root
        n_tokens = 0
        for chunk in self._chunks(tokens):
            child = node.children.get(chunk)
            if child is None or child.block_id is None:
                break
            node = child
            n_tokens += self.block_size
        return n_tokens

    # -------------------------------------------------------------- insert --
    def insert(self, tokens: Sequence[int], block_ids: Sequence[int],
               now: float) -> None:
        """Register a computed sequence's blocks (called when a prefill
        completes).  Each block gets one cache reference (pin)."""
        if not self.enable:
            return
        node = self.root
        path: List[Tuple[int, ...]] = []
        for chunk, bid in zip(self._chunks(tokens), block_ids):
            path.append(chunk)
            child = node.children.get(chunk)
            if child is None:
                child = _Node(chunk=chunk, block_id=bid, parent=node,
                              last_access=now)
                node.children[chunk] = child
                self.bm.pin(bid)
                self._nodes_by_block[bid] = child
                self.bm.set_block_tokens(bid, chunk)
                self.stats.inserts += 1
                if (self.host_tier_blocks
                        and self.host_write_policy == "write_through"):
                    self._host_insert(tuple(path), now)
            elif child.block_id is None:
                child.block_id = bid
                self.bm.pin(bid)
                self._nodes_by_block[bid] = child
                child.last_access = now
            else:
                child.last_access = now
            node = child

    def restore_from_host(self, tokens: Sequence[int], block_ids: Sequence[int],
                          now: float) -> None:
        """Host-tier blocks recomputed into fresh device blocks get
        re-registered in the device tree."""
        self.insert(tokens, block_ids, now)

    # ------------------------------------------------------------- evict --
    def evict(self, n_blocks: int, now: float) -> int:
        """Free up to ``n_blocks`` LRU unpinned leaves; returns count."""
        freed = 0
        while freed < n_blocks:
            victim = self._lru_leaf()
            if victim is None:
                break
            bid = victim.block_id
            victim.block_id = None
            self._nodes_by_block.pop(bid, None)
            if not victim.children and victim.parent is not None:
                victim.parent.children.pop(victim.chunk, None)
            self.bm.unpin(bid)
            self.stats.evictions += 1
            freed += 1
        return freed

    def evict_to_watermark(self, now: float) -> int:
        need = self.bm.watermark_blocks - self.bm.num_free
        return self.evict(need, now) if need > 0 else 0

    def _lru_leaf(self) -> Optional[_Node]:
        best: Optional[_Node] = None
        stack = [self.root]
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            if node is self.root or node.block_id is None:
                continue
            has_live_child = any(c.block_id is not None for c in node.children.values())
            if has_live_child:
                continue
            if best is None or node.last_access < best.last_access:
                best = node
        return best

    # --------------------------------------------------------- host tier --
    def _host_insert(self, path: Tuple[Tuple[int, ...], ...], now: float) -> None:
        if path in self._host:
            self._host[path] = now
            return
        if len(self._host) >= self.host_tier_blocks:
            victim = min(self._host, key=self._host.get)
            del self._host[victim]
            self.stats.host_evictions += 1
        self._host[path] = now

    # ---------------------------------------------------------- counters --
    def num_cached_blocks(self) -> int:
        return len(self._nodes_by_block)
