"""Named paper-style scenarios: the registry behind figures, CI, and the CLI.

Each preset is a factory returning a fresh :class:`Scenario`; the benchmark
figure scripts derive their grids from these bases (``benchmarks/fig_*.py``)
and the CLI runs them by name (``python -m repro.scenario run
cluster_scaling``), so a new workload × topology × policy experiment is a
JSON tweak away instead of a new Python file.

The ``*_parity`` presets are deliberately tiny and deterministic (uniform
arrivals, slow static predictor steps, prefix caching off) so a three-way
``compare`` across thread/process/DES finishes in CI seconds — they are the
scenario-smoke gate in ``.github/workflows/ci.yml``.

>>> sorted(PRESETS) == sorted(list_presets())
True
>>> get_preset("cluster_scaling").pool.replicas
2
>>> get_preset("nope")
Traceback (most recent call last):
  ...
KeyError: "unknown preset 'nope'; choose from ['autoscale_burst', \
'chaos_spot', 'cluster_scaling', 'crash_recovery', 'distributed_parity', \
'elastic_tier_parity', 'fleet_mix', 'hetero_mix', 'scale_stream']"
"""

from __future__ import annotations

from typing import Callable, Dict, List

from .spec import (AutoscaleSpec, FaultSpec, PoolSpec, RoutingSpec, Scenario,
                   SLOSpec, WorkloadSpec)

__all__ = ["PRESETS", "get_preset", "list_presets", "describe"]


def cluster_scaling() -> Scenario:
    """Open-loop ShareGPT-like stream on a data-parallel llama3-8b pool —
    the fig_cluster_scaling base cell (replicas/policy/QPS are the axes)."""
    return Scenario(
        name="cluster_scaling",
        workload=WorkloadSpec(
            kind="open", qps=4.0, num_requests=40,
            prompt_len_mean=180.0, output_len_mean=40.0,
            max_output_len=1024),
        pool=PoolSpec(
            model="llama3_8b", replicas=2, max_num_seqs=8,
            max_batched_tokens=512, block_size=16, num_blocks=16384,
            chip="h200-sxm", step_time_s=20e-3),
        routing=RoutingSpec(policy="round_robin"),
        slo=SLOSpec(ttft_s=1.0),
        seed=13)


def autoscale_burst() -> Scenario:
    """Bursty multi-turn chat sessions (gamma cv²=8) on an elastic pool with
    a TTFT-SLO autoscaler — the fig_autoscale base cell."""
    return Scenario(
        name="autoscale_burst",
        workload=WorkloadSpec(
            kind="sessions", qps=6.0, arrival="gamma",
            arrival_kwargs={"cv2": 8.0}, num_sessions=16,
            turns_mean=3.0, max_turns=5, think_time_mean=1.5,
            prompt_len_mean=180.0, followup_len_mean=60.0,
            output_len_mean=40.0, max_output_len=128),
        pool=PoolSpec(
            model="llama3_8b", replicas=2, max_num_seqs=8,
            max_batched_tokens=512, block_size=16, num_blocks=16384,
            chip="h200-sxm", step_time_s=20e-3),
        routing=RoutingSpec(policy="least_outstanding_tokens"),
        autoscale=AutoscaleSpec(
            policy="ttft_slo",
            kwargs={"slo_ttft_s": 0.5, "target_attainment": 0.98,
                    "window_s": 2.0},
            interval_s=0.1, provision_delay_s=0.5,
            min_replicas=2, max_replicas=4),
        slo=SLOSpec(ttft_s=0.5),
        seed=13)


def hetero_mix() -> Scenario:
    """Mixed H100+L4 pool under cost-normalized routing — the fig_hetero
    base cell (tier mix / policy / QPS are the axes).  Per-tier static
    steps encode the ~2.5× H100-vs-L4 roofline ratio."""
    return Scenario(
        name="hetero_mix",
        workload=WorkloadSpec(
            kind="open", qps=10.0, num_requests=16,
            prompt_len_mean=180.0, output_len_mean=40.0,
            max_output_len=1024),
        pool=PoolSpec(
            model="llama3_8b", replicas=4,
            tiers=("h100", "h100", "l4", "l4"),
            max_num_seqs=8, max_batched_tokens=512, block_size=16,
            num_blocks=16384,
            tier_step_time_s={"h100": 8e-3, "l4": 20e-3}),
        routing=RoutingSpec(policy="cost_normalized_load"),
        slo=SLOSpec(ttft_s=0.5),
        seed=13)


def distributed_parity() -> Scenario:
    """Tiny deterministic backend-parity cell: uniformly spaced arrivals
    with idle-replica headroom and one deliberately slow predictor step, so
    thread / process / DES must agree within one step (the
    fig_distributed methodology as a named scenario)."""
    return Scenario(
        name="distributed_parity",
        workload=WorkloadSpec(
            kind="open", qps=2.0, arrival="uniform", num_requests=10,
            prompt_len_mean=24.0, max_prompt_len=48,
            output_len_mean=4.0, max_output_len=5),
        pool=PoolSpec(
            model="qwen2_5_3b", reduced=True, replicas=2,
            max_num_seqs=8, max_batched_tokens=64, block_size=4,
            num_blocks=4096, enable_prefix_caching=False,
            # deliberately slow: socket round trips absorb wall time into
            # the virtual timeline (Eq. 1) on the process backend, and the
            # parity bar is "within one of these" — sized so a noisy CI
            # machine's absorption stays well inside a step
            step_time_s=100e-3),
        routing=RoutingSpec(policy="round_robin"),
        seed=17)


def elastic_tier_parity() -> Scenario:
    """Mixed-tier pool scaling up mid-run through a scripted tier-selecting
    autoscaler (cheapest candidate: L4) — the elastic/heterogeneous parity
    scenario all three backends must agree on."""
    return Scenario(
        name="elastic_tier_parity",
        workload=WorkloadSpec(
            kind="open", qps=2.5, arrival="uniform", num_requests=10,
            prompt_len_mean=24.0, max_prompt_len=48,
            output_len_mean=4.0, max_output_len=5),
        pool=PoolSpec(
            model="qwen2_5_3b", reduced=True, replicas=2,
            tiers=("h100", "l4"),
            max_num_seqs=8, max_batched_tokens=64, block_size=4,
            num_blocks=4096, enable_prefix_caching=False,
            tier_step_time_s={"h100": 50e-3, "l4": 125e-3}),
        routing=RoutingSpec(policy="round_robin"),
        autoscale=AutoscaleSpec(
            policy="schedule", schedule=((0.3, 1),),
            interval_s=0.1, provision_delay_s=0.1,
            min_replicas=2, max_replicas=3,
            tiers=("h100", "l4"),
            provision_delay_by_tier={"l4": 0.06}),
        seed=17)


def crash_recovery() -> Scenario:
    """Chaos parity cell: replica 1 is SIGKILL-crashed mid-decode at t=0.97
    (a deliberate off-step-grid instant — see the determinism caveat in
    :mod:`repro.cluster.faults`), its in-flight requests requeue through the
    router, and a warm standby respawns 0.35 virtual seconds later.  All
    three backends must report the identical fault log and requeue count."""
    return Scenario(
        name="crash_recovery",
        workload=WorkloadSpec(
            kind="open", qps=2.0, arrival="uniform", num_requests=10,
            prompt_len_mean=24.0, max_prompt_len=48,
            output_len_mean=4.0, max_output_len=5),
        pool=PoolSpec(
            model="qwen2_5_3b", reduced=True, replicas=2,
            max_num_seqs=8, max_batched_tokens=64, block_size=4,
            num_blocks=4096, enable_prefix_caching=False,
            step_time_s=100e-3),
        routing=RoutingSpec(policy="round_robin"),
        faults=(
            FaultSpec(kind="crash", time_s=0.97, replica=1,
                      on_crash="requeue", recover=True,
                      respawn_delay_s=0.35),
        ),
        seed=17)


def chaos_spot() -> Scenario:
    """Chaos parity cell on a mixed spot pool: an H100 replica straggles at
    2× for one virtual second, then the whole L4 (spot) tier is reclaimed
    with a notice window too short to drain, so the kill lands mid-decode
    and requeues work — stragglers, drain-then-kill, and warm-pool
    recovery in one deterministic scenario (fault times off the step
    grid).  The slowdown (2 × 50 ms = 100 ms) stays under the slow-step
    parity unit (125 ms), so the ≤ 1-slow-step latency bar still binds."""
    return Scenario(
        name="chaos_spot",
        workload=WorkloadSpec(
            kind="open", qps=2.0, arrival="uniform", num_requests=10,
            prompt_len_mean=24.0, max_prompt_len=48,
            output_len_mean=4.0, max_output_len=5),
        pool=PoolSpec(
            model="qwen2_5_3b", reduced=True, replicas=3,
            tiers=("h100", "h100", "l4"),
            max_num_seqs=8, max_batched_tokens=64, block_size=4,
            num_blocks=4096, enable_prefix_caching=False,
            tier_step_time_s={"h100": 50e-3, "l4": 125e-3}),
        routing=RoutingSpec(policy="round_robin"),
        faults=(
            FaultSpec(kind="straggler", time_s=0.47, replica=1,
                      slowdown=2.0, duration_s=1.0),
            FaultSpec(kind="spot_reclaim", time_s=1.07, tier="l4",
                      notice_s=0.15, on_crash="requeue", recover=True,
                      respawn_delay_s=0.4),
        ),
        seed=17)


def fleet_mix() -> Scenario:
    """Multi-model multi-tenant fleet parity cell (``repro.fleet``): a
    shared qwen "chat" base pool serving two LoRA tenants (adapter-affinity
    routing, per-adapter KV debit, cold-load swap stalls) plus a dedicated
    olmo "code" pool, under a scheduled per-pool scale-up — deterministic
    (uniform arrivals, static steps) so thread / process / DES must agree
    to one slow-step, multi-LoRA shared-base cell included."""
    from repro.fleet import (AdapterSpec, FleetSpec, ModelPoolSpec,
                             TenantSpec)
    return Scenario(
        name="fleet_mix",
        workload=WorkloadSpec(
            kind="open", qps=2.0, arrival="uniform", num_requests=12,
            prompt_len_mean=24.0, max_prompt_len=48,
            output_len_mean=4.0, max_output_len=5),
        fleet=FleetSpec(
            models=(
                ModelPoolSpec(
                    name="chat",
                    pool=PoolSpec(
                        model="qwen2_5_3b", reduced=True, replicas=2,
                        max_num_seqs=8, max_batched_tokens=64, block_size=4,
                        num_blocks=4096, enable_prefix_caching=False,
                        step_time_s=100e-3),
                    routing=RoutingSpec(policy="adapter_affinity"),
                    autoscale=AutoscaleSpec(
                        policy="schedule", schedule=((0.5, 1),),
                        interval_s=0.1, provision_delay_s=0.1,
                        min_replicas=2, max_replicas=3),
                    adapters=(
                        AdapterSpec(name="alpha", kv_blocks=64,
                                    swap_s=0.12),
                        AdapterSpec(name="beta", kv_blocks=64,
                                    swap_s=0.12),
                    )),
                ModelPoolSpec(
                    name="code",
                    pool=PoolSpec(
                        model="olmo_1b", reduced=True, replicas=1,
                        max_num_seqs=8, max_batched_tokens=64, block_size=4,
                        num_blocks=4096, enable_prefix_caching=False,
                        step_time_s=80e-3),
                    routing=RoutingSpec(policy="round_robin")),
            ),
            tenants=(
                TenantSpec(name="acme", share=2.0, priority=1,
                           model="chat", adapter="alpha",
                           slo=SLOSpec(ttft_s=2.0)),
                TenantSpec(name="bolt", share=1.0, model="chat",
                           adapter="beta", slo=SLOSpec(ttft_s=2.0)),
                TenantSpec(name="cava", share=1.0, model="code",
                           slo=SLOSpec(ttft_s=2.0)),
            )),
        slo=SLOSpec(ttft_s=2.0),
        seed=17)


def scale_stream() -> Scenario:
    """Diurnal-trace streaming sessions — the million-session scale base
    cell (``fig_scale`` sweeps ``num_sessions`` at fixed qps, so session
    count scales the virtual *duration*, not the concurrency; run with
    ``audit="sampled"`` for flat memory)."""
    return Scenario(
        name="scale_stream",
        workload=WorkloadSpec(
            kind="sessions", streaming=True, qps=50.0,
            arrival="trace",
            # one 240-virtual-second "day": quiet night, morning ramp,
            # midday peak, evening tail (relative rates; qps rescales the
            # mean, preserving the shape)
            arrival_kwargs={"trace": [
                [30.0, 0.3], [30.0, 0.6], [30.0, 1.0], [30.0, 1.5],
                [30.0, 1.7], [30.0, 1.3], [30.0, 0.8], [30.0, 0.4]]},
            num_sessions=10_000, turns_mean=2.0, max_turns=3,
            think_time_mean=0.5,
            prompt_len_mean=48.0, prompt_len_sigma=0.4,
            followup_len_mean=24.0,
            output_len_mean=12.0, output_len_sigma=0.4,
            max_output_len=24),
        pool=PoolSpec(
            model="qwen2_5_3b", reduced=True, replicas=2,
            max_num_seqs=64, max_batched_tokens=2048, block_size=16,
            num_blocks=16384, enable_prefix_caching=False,
            step_time_s=2e-3),
        routing=RoutingSpec(policy="round_robin"),
        slo=SLOSpec(ttft_s=1.0),
        seed=29)


PRESETS: Dict[str, Callable[[], Scenario]] = {
    fn.__name__: fn
    for fn in (cluster_scaling, autoscale_burst, hetero_mix,
               distributed_parity, elastic_tier_parity, crash_recovery,
               chaos_spot, fleet_mix, scale_stream)
}


def get_preset(name: str) -> Scenario:
    """A fresh scenario for ``name`` (each call builds a new tree)."""
    try:
        return PRESETS[name]()
    except KeyError:
        raise KeyError(f"unknown preset {name!r}; choose from "
                       f"{sorted(PRESETS)}") from None


def list_presets() -> List[str]:
    return sorted(PRESETS)


def describe(name: str) -> str:
    """First docstring line of the preset factory (CLI listing)."""
    doc = PRESETS[name].__doc__ or ""
    return " ".join(doc.split("\n\n")[0].split())
