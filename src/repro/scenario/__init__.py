"""Declarative scenario API: one serializable spec, one ``run()``, three
backends.

A serving experiment is *data*: a frozen :class:`Scenario` tree (workload,
pool, routing, autoscaling, SLOs, seed) with strict JSON round-tripping, a
:class:`Sweep` that expands axis grids into scenario lists, and a single
:func:`run` that executes any scenario on the thread-mode emulator, the
process-mode emulator, or the DES baseline — plus :func:`compare`, which
runs one spec on several backends and enforces the repo's ≤1-slow-step
parity bar.  See ``docs/scenarios.md``.

::

    from repro.scenario import Scenario, Sweep, run, compare, get_preset

    result = run(get_preset("cluster_scaling"), backend="thread")
    compare(get_preset("distributed_parity"),
            backends=("thread", "process", "des"))

    python -m repro.scenario run cluster_scaling        # same, from a shell
    python -m repro.scenario compare distributed_parity --backends thread,des
"""

from .presets import PRESETS, describe, get_preset, list_presets
from .runner import (BACKEND_ALIASES, CompareResult, ParityError,
                     ScenarioResult, compare, derive_cell_seed, run,
                     run_sweep)
from .spec import (BACKENDS, AutoscaleSpec, PoolSpec, RoutingSpec, Scenario,
                   SLOSpec, SpecError, WorkloadSpec, scenario_with)
from .sweep import Sweep

# fleet extension specs (repro.fleet) re-exported lazily (PEP 562):
# fleet.spec imports the codec from .spec, so an eager import here would
# cycle through this package's own init
_FLEET_EXPORTS = ("FleetSpec", "ModelPoolSpec", "TenantSpec", "AdapterSpec")


def __getattr__(name):
    if name in _FLEET_EXPORTS:
        from repro.fleet import spec as _fleet_spec
        return getattr(_fleet_spec, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "Scenario",
    "WorkloadSpec",
    "PoolSpec",
    "RoutingSpec",
    "AutoscaleSpec",
    "SLOSpec",
    "FleetSpec",
    "ModelPoolSpec",
    "TenantSpec",
    "AdapterSpec",
    "SpecError",
    "scenario_with",
    "Sweep",
    "BACKENDS",
    "BACKEND_ALIASES",
    "run",
    "run_sweep",
    "derive_cell_seed",
    "compare",
    "ScenarioResult",
    "CompareResult",
    "ParityError",
    "PRESETS",
    "get_preset",
    "list_presets",
    "describe",
]
