"""Declarative scenario specs: one serializable tree describes an experiment.

A :class:`Scenario` is a frozen dataclass tree — workload shape, replica
pool, routing policy, autoscaling, SLOs, seed — that fully determines a
serving experiment without naming an execution backend.  The same spec runs
unmodified on the thread-mode emulator, the process-mode emulator, and the
DES baseline through :func:`repro.scenario.run`, which is what turns a
config sweep into *data* instead of hand-wired Python (the paper's §2.1
hundreds-of-configurations story; see ``docs/scenarios.md``).

Serialization contract (tested in ``tests/test_scenario.py``):

* ``Scenario.from_dict(s.to_dict()) == s`` for every valid scenario — the
  dict form is plain JSON (tuples become lists and come back as tuples);
* unknown keys and invalid enum values raise :class:`SpecError` carrying the
  dotted **path** of the offending entry (``"autoscale.policy"``), so a
  typo'd 200-line JSON file fails with a pointer, not a stack trace;
* every field has a default — ``Scenario.from_dict({})`` is a valid tiny
  scenario, and spec files only need to name what they change.

>>> s = Scenario(name="demo", pool=PoolSpec(replicas=2))
>>> Scenario.from_dict(s.to_dict()) == s
True
>>> Scenario.from_json(s.to_json()) == s
True
>>> try:
...     Scenario.from_dict({"pool": {"replicaz": 2}})
... except SpecError as e:
...     print(str(e).split(" (")[0])
pool.replicaz: unknown key
>>> try:
...     Scenario.from_dict({"routing": {"policy": "warp_drive"}})
... except SpecError as e:
...     print(str(e).split(" (")[0])
routing.policy: invalid value 'warp_drive'
"""

from __future__ import annotations

import dataclasses
import json
import typing
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.cluster.faults import FaultSpec

__all__ = [
    "SpecError",
    "WorkloadSpec",
    "PoolSpec",
    "RoutingSpec",
    "AutoscaleSpec",
    "SLOSpec",
    "FaultSpec",
    "Scenario",
    "scenario_with",
    "BACKENDS",
]

#: Execution backends a scenario can run on (see repro.scenario.runner).
BACKENDS = ("thread", "process", "des")


class SpecError(ValueError):
    """Invalid scenario spec; the message starts with the dotted path of the
    offending entry (e.g. ``"autoscale.provision_delay_by_tier"``)."""


# =========================================================================
# generic dataclass <-> JSON-dict codec
# =========================================================================

def _encode(value):
    """Spec tree -> plain JSON value (tuples -> lists, dataclasses -> dicts)."""
    if dataclasses.is_dataclass(value):
        return {f.name: _encode(getattr(value, f.name))
                for f in dataclasses.fields(value)}
    if isinstance(value, (list, tuple)):
        return [_encode(v) for v in value]
    if isinstance(value, dict):
        return {str(k): _encode(v) for k, v in value.items()}
    return value


def _decode(typ, value, path: str):
    """JSON value -> ``typ``, raising :class:`SpecError` at ``path``."""
    origin = typing.get_origin(typ)
    args = typing.get_args(typ)

    # Optional[X] / Union[X, None]
    if origin is typing.Union:
        non_none = [a for a in args if a is not type(None)]
        if value is None:
            if type(None) in args:
                return None
            raise SpecError(f"{path}: may not be null")
        assert len(non_none) == 1, f"unsupported union at {path}"
        return _decode(non_none[0], value, path)

    if dataclasses.is_dataclass(typ):
        return _decode_dataclass(typ, value, path)

    if origin is tuple:
        if not isinstance(value, (list, tuple)):
            raise SpecError(f"{path}: expected a list, got {value!r}")
        if len(args) == 2 and args[1] is Ellipsis:
            return tuple(_decode(args[0], v, f"{path}[{i}]")
                         for i, v in enumerate(value))
        if len(value) != len(args):
            raise SpecError(f"{path}: expected {len(args)} elements, "
                            f"got {len(value)}")
        return tuple(_decode(a, v, f"{path}[{i}]")
                     for i, (a, v) in enumerate(zip(args, value)))

    if origin is dict:
        if not isinstance(value, dict):
            raise SpecError(f"{path}: expected an object, got {value!r}")
        key_t, val_t = args
        return {_decode(key_t, k, f"{path}.{k}"):
                _decode(val_t, v, f"{path}.{k}")
                for k, v in value.items()}

    if typ is dict:                      # free-form kwargs: plain JSON only
        if not isinstance(value, dict):
            raise SpecError(f"{path}: expected an object, got {value!r}")
        try:
            return json.loads(json.dumps(value))  # deep copy + JSON-only
        except TypeError as e:
            raise SpecError(f"{path}: values must be plain JSON ({e})") \
                from None

    if typ is bool:
        if not isinstance(value, bool):
            raise SpecError(f"{path}: expected a bool, got {value!r}")
        return value
    if typ is int:
        if isinstance(value, bool) or not isinstance(value, int):
            raise SpecError(f"{path}: expected an int, got {value!r}")
        return value
    if typ is float:
        if isinstance(value, bool) or not isinstance(value, (int, float)):
            raise SpecError(f"{path}: expected a number, got {value!r}")
        return float(value)
    if typ is str:
        if not isinstance(value, str):
            raise SpecError(f"{path}: expected a string, got {value!r}")
        return value
    raise AssertionError(f"unsupported spec field type {typ} at {path}")


def _type_hints(cls):
    """``typing.get_type_hints`` with the fleet extension specs in scope.

    ``Scenario.fleet`` is annotated as a *forward reference* to
    :class:`repro.fleet.FleetSpec` so the import stays one-directional
    (``repro.fleet.spec`` imports the codec from this module).  Resolving
    the hints therefore needs the fleet names injected into the lookup
    namespace — lazily, at decode time, to avoid the cycle.
    """
    import sys
    globalns = dict(getattr(sys.modules.get(cls.__module__), "__dict__", {}))
    if "FleetSpec" not in globalns:
        from repro.fleet.spec import FleetSpec
        globalns["FleetSpec"] = FleetSpec
    return typing.get_type_hints(cls, globalns=globalns)


def _decode_dataclass(cls, value, path: str):
    if isinstance(value, cls):
        return value
    if not isinstance(value, dict):
        raise SpecError(f"{path}: expected an object for {cls.__name__}, "
                        f"got {value!r}")
    hints = _type_hints(cls)
    names = [f.name for f in dataclasses.fields(cls)]
    kwargs = {}
    for key, v in value.items():
        kpath = f"{path}.{key}" if path else key
        if key not in names:
            raise SpecError(f"{kpath}: unknown key "
                            f"(valid keys: {', '.join(names)})")
        kwargs[key] = _decode(hints[key], v, kpath)
    out = cls(**kwargs)
    validate = getattr(out, "validate", None)
    if validate is not None:
        validate(path=path)
    return out


class _SpecBase:
    """Shared codec surface for every spec dataclass."""

    def to_dict(self) -> dict:
        """Plain-JSON dict form (tuples become lists); full and explicit —
        every field is present, so specs diff cleanly."""
        return _encode(self)

    @classmethod
    def from_dict(cls, d: dict, *, path: str = ""):
        """Strict inverse of :meth:`to_dict`: unknown keys / wrong types /
        invalid enum values raise :class:`SpecError` with the dotted path."""
        return _decode_dataclass(
            cls, d, path or cls.__name__.lower().replace("spec", ""))

    def to_json(self, **kw) -> str:
        kw.setdefault("indent", 2)
        kw.setdefault("sort_keys", True)
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_json(cls, text: str):
        try:
            d = json.loads(text)
        except json.JSONDecodeError as e:
            raise SpecError(f"invalid JSON: {e}") from None
        return cls.from_dict(d)

    def validate(self, *, path: str = "") -> None:
        """Semantic checks beyond types; subclasses override and raise
        :class:`SpecError` (with ``path`` prefixes) on violations."""


def _enum(path: str, name: str, value: str, valid) -> None:
    if value not in valid:
        raise SpecError(f"{path}.{name}: invalid value {value!r} "
                        f"(choose from {sorted(valid)})")


# =========================================================================
# the spec tree
# =========================================================================

@dataclass(frozen=True)
class WorkloadSpec(_SpecBase):
    """Traffic shape: open-loop request stream or closed-loop chat sessions.

    ``kind="open"`` materializes a :func:`repro.workload.synthesize` stream
    (``num_requests`` × the length marginals); ``kind="sessions"``
    materializes a :class:`repro.workload.SessionWorkload` (multi-turn chat,
    follow-ups released on completion + think time).  ``arrival`` names any
    registered arrival process — ``"uniform"`` gives the deterministically
    spaced arrivals backend-parity scenarios need.

    ``streaming=True`` swaps the materialized forms for their lazy
    equivalents (:class:`repro.workload.StreamingWorkload` /
    :class:`repro.workload.StreamingSessionWorkload`): requests are
    generated with bounded look-ahead instead of being pre-built, so one
    spec can replay millions of sessions in flat memory (pair with
    ``audit="sampled"`` on :func:`repro.scenario.run`).
    """

    kind: str = "open"                    # open | sessions
    qps: float = 4.0                      # request (or session) arrival rate
    arrival: str = "poisson"              # repro.workload.ARRIVAL_PROCESSES
    arrival_kwargs: Optional[dict] = None   # e.g. {"cv2": 8.0} for gamma
    # length marginals (lognormal, shared by both kinds)
    prompt_len_mean: float = 180.0
    prompt_len_sigma: float = 0.6
    output_len_mean: float = 40.0
    output_len_sigma: float = 0.6
    max_output_len: int = 256
    shared_prefix_len: int = 0            # common system prompt (tokens)
    # open-loop shape
    num_requests: int = 32
    max_prompt_len: int = 2048            # sessions bound context instead
    # closed-loop shape
    num_sessions: int = 8
    turns_mean: float = 3.0
    max_turns: int = 5
    think_time_mean: float = 1.0
    followup_len_mean: float = 40.0
    # lazy generation (flat-memory scale path); default keeps the
    # materialized forms every existing scenario/parity test uses
    streaming: bool = False

    def validate(self, *, path: str = "workload") -> None:
        from repro.workload import ARRIVAL_PROCESSES, make_arrival
        _enum(path, "kind", self.kind, ("open", "sessions"))
        _enum(path, "arrival", self.arrival, ARRIVAL_PROCESSES)
        if self.qps <= 0:
            raise SpecError(f"{path}.qps: must be > 0")
        if self.kind == "open" and self.num_requests < 1:
            raise SpecError(f"{path}.num_requests: must be >= 1")
        if self.kind == "sessions" and self.num_sessions < 1:
            raise SpecError(f"{path}.num_sessions: must be >= 1")
        # the kwargs must actually fit the chosen process: fail here with a
        # path, not at materialize time with a raw TypeError mid-sweep
        try:
            make_arrival(self.arrival, self.qps,
                         **(self.arrival_kwargs or {}))
        except (TypeError, ValueError, AssertionError) as e:
            raise SpecError(
                f"{path}.arrival_kwargs: invalid for arrival "
                f"{self.arrival!r} ({e})") from None

    @property
    def total_label(self) -> str:
        return (f"{self.num_requests} reqs" if self.kind == "open"
                else f"{self.num_sessions} sessions")

    def materialize(self, seed: int):
        """Build the runnable workload object (a fresh one per call): a
        ``List[Request]`` for ``kind="open"``, a :class:`SessionWorkload`
        for ``kind="sessions"`` — or their lazy streaming equivalents when
        ``streaming=True``."""
        from repro.workload import (SessionConfig, SessionWorkload,
                                    StreamingSessionWorkload,
                                    StreamingWorkload, WorkloadConfig,
                                    synthesize)
        if self.kind == "sessions":
            cfg = SessionConfig(
                num_sessions=self.num_sessions, qps=self.qps,
                arrival=self.arrival, arrival_kwargs=self.arrival_kwargs,
                turns_mean=self.turns_mean, max_turns=self.max_turns,
                think_time_mean=self.think_time_mean,
                prompt_len_mean=self.prompt_len_mean,
                prompt_len_sigma=self.prompt_len_sigma,
                followup_len_mean=self.followup_len_mean,
                output_len_mean=self.output_len_mean,
                output_len_sigma=self.output_len_sigma,
                max_output_len=self.max_output_len,
                shared_prefix_len=self.shared_prefix_len,
                seed=seed)
            if self.streaming:
                return StreamingSessionWorkload(cfg)
            return SessionWorkload(cfg)
        cfg = WorkloadConfig(
            num_requests=self.num_requests, qps=self.qps,
            arrival=self.arrival, arrival_kwargs=self.arrival_kwargs,
            prompt_len_mean=self.prompt_len_mean,
            prompt_len_sigma=self.prompt_len_sigma,
            output_len_mean=self.output_len_mean,
            output_len_sigma=self.output_len_sigma,
            max_prompt_len=self.max_prompt_len,
            max_output_len=self.max_output_len,
            shared_prefix_len=self.shared_prefix_len,
            seed=seed)
        if self.streaming:
            return StreamingWorkload(cfg)
        return synthesize(cfg)


@dataclass(frozen=True)
class PoolSpec(_SpecBase):
    """The replica pool: model, engine knobs, hardware tiers, predictor.

    ``tiers`` makes the pool heterogeneous — one chip name per replica (a
    single name broadcasts to all), resolved through
    :mod:`repro.cluster.tiers` so routing weights, KV capacity, and
    $/replica-second follow the chip identically on every backend.
    ``step_time_s`` (or per-tier ``tier_step_time_s``) pins a
    :class:`~repro.core.predictor.StaticPredictor` — the deterministic
    step-time parity scenarios use; ``None`` selects the analytical
    predictor for the chip.
    """

    model: str = "llama3_8b"              # repro.configs registry id
    reduced: bool = False                 # reduced() config (CI-sized runs)
    replicas: int = 2
    tiers: Optional[Tuple[str, ...]] = None
    # engine knobs (EngineConfig)
    scheduler: str = "vllm"               # vllm | sglang
    max_num_seqs: int = 8
    max_batched_tokens: int = 512
    block_size: int = 16
    num_blocks: int = 16384
    chip: str = "h200-sxm"                # ignored when tiers are set
    tp: int = 1
    ep: int = 1
    enable_prefix_caching: bool = True
    # predictor override: virtual step seconds (None = analytical predictor)
    step_time_s: Optional[float] = None
    tier_step_time_s: Optional[Dict[str, float]] = None
    # process-backend wire: "tcp" (framed sockets) | "shm" (shared-memory
    # rings + seqlock clock word); thread/des backends have no wire and
    # ignore it, so parity scenarios stay backend-portable
    transport: str = "tcp"

    def validate(self, *, path: str = "pool") -> None:
        from repro.configs import ARCH_IDS, PAPER_ARCH_IDS
        from repro.core.hardware import get_chip
        valid_models = set(ARCH_IDS) | set(PAPER_ARCH_IDS)
        _enum(path, "model", self.model, valid_models)
        _enum(path, "scheduler", self.scheduler, ("vllm", "sglang"))
        _enum(path, "transport", self.transport, ("tcp", "shm"))
        if self.replicas < 1:
            raise SpecError(f"{path}.replicas: must be >= 1")
        if self.tiers is not None:
            if len(self.tiers) not in (1, self.replicas):
                raise SpecError(
                    f"{path}.tiers: need 1 (broadcast) or {self.replicas} "
                    f"tier names, got {len(self.tiers)}")
            for i, t in enumerate(self.tiers):
                try:
                    get_chip(t)
                except KeyError:
                    raise SpecError(f"{path}.tiers[{i}]: unknown chip/tier "
                                    f"{t!r}") from None
        for t in (self.tier_step_time_s or {}):
            try:
                get_chip(t)
            except KeyError:
                raise SpecError(f"{path}.tier_step_time_s.{t}: unknown "
                                f"chip/tier {t!r}") from None

    def replica_tiers(self) -> Optional[list]:
        """Per-replica tier names with single-name broadcast applied."""
        if self.tiers is None:
            return None
        if len(self.tiers) == 1:
            return [self.tiers[0]] * self.replicas
        return list(self.tiers)

    def model_config(self):
        from repro.configs import get_config, get_reduced_config
        return (get_reduced_config(self.model) if self.reduced
                else get_config(self.model))

    def engine_config(self):
        from repro.serving.scheduler import EngineConfig
        return EngineConfig(
            policy=self.scheduler, max_num_seqs=self.max_num_seqs,
            max_batched_tokens=self.max_batched_tokens,
            block_size=self.block_size, num_blocks=self.num_blocks,
            chip=self.chip, tp=self.tp, ep=self.ep,
            enable_prefix_caching=self.enable_prefix_caching)


@dataclass(frozen=True)
class RoutingSpec(_SpecBase):
    """Request placement policy (see :mod:`repro.cluster.router`)."""

    policy: str = "round_robin"
    kwargs: Optional[dict] = None         # router constructor extras

    def validate(self, *, path: str = "routing") -> None:
        from repro.cluster.router import ROUTER_POLICIES
        _enum(path, "policy", self.policy, ROUTER_POLICIES)


@dataclass(frozen=True)
class AutoscaleSpec(_SpecBase):
    """Elastic membership: policy + control-loop config (+ tier candidates).

    ``policy="schedule"`` takes its scripted ``(virtual_time, delta)`` events
    from ``schedule`` (times relative to the run's virtual start — the
    deterministic shape every parity scenario uses); the feedback policies
    (``queue_depth``, ``ttft_slo``) take their knobs from ``kwargs``.
    """

    policy: str = "queue_depth"           # repro.cluster AUTOSCALER_POLICIES
    kwargs: Optional[dict] = None         # policy constructor extras
    schedule: Optional[Tuple[Tuple[float, int], ...]] = None
    interval_s: float = 0.25
    provision_delay_s: float = 1.0
    min_replicas: int = 1
    max_replicas: int = 8
    tiers: Tuple[str, ...] = ()           # scale-up tier candidates
    provision_delay_by_tier: Optional[Dict[str, float]] = None

    def validate(self, *, path: str = "autoscale") -> None:
        from repro.cluster.autoscaler import AUTOSCALER_POLICIES
        from repro.core.hardware import get_chip
        _enum(path, "policy", self.policy, AUTOSCALER_POLICIES)
        if (self.policy == "schedule") != (self.schedule is not None):
            raise SpecError(
                f"{path}.schedule: required exactly when policy='schedule'")
        if self.kwargs and self.policy == "schedule":
            raise SpecError(f"{path}.kwargs: schedule policy takes its "
                            "events from 'schedule', not kwargs")
        if self.min_replicas < 1 or self.max_replicas < self.min_replicas:
            raise SpecError(f"{path}.min_replicas/max_replicas: need "
                            "1 <= min <= max")
        for i, t in enumerate(self.tiers):
            try:
                get_chip(t)
            except KeyError:
                raise SpecError(f"{path}.tiers[{i}]: unknown chip/tier "
                                f"{t!r}") from None

    def make_policy(self):
        from repro.cluster.autoscaler import (SchedulePolicy,
                                              make_autoscaler_policy)
        if self.policy == "schedule":
            return SchedulePolicy([tuple(e) for e in self.schedule])
        return make_autoscaler_policy(self.policy, **(self.kwargs or {}))

    def make_config(self):
        from repro.cluster.autoscaler import AutoscalerConfig
        return AutoscalerConfig(
            interval_s=self.interval_s,
            provision_delay_s=self.provision_delay_s,
            min_replicas=self.min_replicas,
            max_replicas=self.max_replicas,
            tiers=tuple(self.tiers),
            provision_delay_by_tier=(dict(self.provision_delay_by_tier)
                                     if self.provision_delay_by_tier
                                     else None))


@dataclass(frozen=True)
class SLOSpec(_SpecBase):
    """Service-level objectives the result's attainment/goodput are judged
    against (``None`` = unconstrained on that axis)."""

    ttft_s: Optional[float] = None
    tpot_s: Optional[float] = None

    def validate(self, *, path: str = "slo") -> None:
        for name, v in (("ttft_s", self.ttft_s), ("tpot_s", self.tpot_s)):
            if v is not None and v <= 0:
                raise SpecError(f"{path}.{name}: must be > 0 (or null)")


@dataclass(frozen=True)
class Scenario(_SpecBase):
    """One fully-specified serving experiment (see module docstring).

    The tree is frozen: derive variants with :func:`scenario_with` (dotted
    field paths) or :class:`dataclasses.replace`, and grids with
    :class:`repro.scenario.Sweep`.
    """

    name: str = "scenario"
    workload: WorkloadSpec = field(default_factory=WorkloadSpec)
    pool: PoolSpec = field(default_factory=PoolSpec)
    routing: RoutingSpec = field(default_factory=RoutingSpec)
    autoscale: Optional[AutoscaleSpec] = None
    slo: SLOSpec = field(default_factory=SLOSpec)
    faults: Tuple[FaultSpec, ...] = ()    # chaos schedule (virtual times)
    # multi-model / multi-tenant extension (repro.fleet): when set, the
    # fleet's per-model pools replace the top-level pool/routing/autoscale
    # (which are ignored) and tenants split the open-loop workload
    fleet: Optional["FleetSpec"] = None
    seed: int = 0

    def validate(self, *, path: str = "") -> None:
        dot = f"{path}." if path else ""
        self.workload.validate(path=f"{dot}workload")
        self.pool.validate(path=f"{dot}pool")
        self.routing.validate(path=f"{dot}routing")
        self.slo.validate(path=f"{dot}slo")
        for i, f in enumerate(self.faults):
            f.validate(path=f"{dot}faults[{i}]")
            if f.kind == "spot_reclaim" and not self.pool.tiers:
                raise SpecError(f"{dot}faults[{i}].tier: spot_reclaim needs "
                                "a tiered pool (pool.tiers)")
        if self.faults:
            if self.routing.policy == "pd_pool":
                raise SpecError(f"{dot}faults: fault injection is not "
                                "supported for pd_pool routing")
            if self.workload.kind == "sessions" and any(
                    f.on_crash == "fail" for f in self.faults
                    if f.kind in ("crash", "spot_reclaim")):
                raise SpecError(
                    f"{dot}faults: on_crash='fail' cannot be combined with a "
                    "sessions workload (a failed turn would strand its "
                    "session's follow-ups and the run would never complete); "
                    "use on_crash='requeue'")
        if self.fleet is not None:
            self.fleet.validate(path=f"{dot}fleet")
            if self.workload.kind != "open":
                raise SpecError(
                    f"{dot}fleet: needs workload.kind='open' (the ingress "
                    "splits one open-loop stream across tenants; sessions "
                    "are per-pool concerns)")
            if self.workload.streaming:
                raise SpecError(f"{dot}fleet: streaming workloads are not "
                                "supported on the fleet path yet")
            if self.faults:
                raise SpecError(f"{dot}faults: fault injection composes at "
                                "pool level, not fleet level (run the pool's "
                                "scenario with faults instead)")
            if self.autoscale is not None:
                raise SpecError(
                    f"{dot}autoscale: a fleet scales per model pool "
                    "(fleet.models[i].autoscale); top-level autoscale "
                    "must be null")
        if self.autoscale is not None:
            self.autoscale.validate(path=f"{dot}autoscale")
            a = self.autoscale
            if self.pool.replicas < a.min_replicas \
                    or self.pool.replicas > a.max_replicas:
                raise SpecError(
                    f"{dot}pool.replicas: initial pool ({self.pool.replicas})"
                    f" outside autoscale bounds "
                    f"[{a.min_replicas}, {a.max_replicas}]")
            if self.routing.policy == "pd_pool":
                raise SpecError(f"{dot}autoscale: elastic membership is not "
                                "supported for pd_pool routing")

    @classmethod
    def from_dict(cls, d: dict, *, path: str = "") -> "Scenario":
        return _decode_dataclass(cls, d, path)

    def save(self, path) -> None:
        from pathlib import Path
        Path(path).write_text(self.to_json() + "\n")

    @classmethod
    def load(cls, path) -> "Scenario":
        from pathlib import Path
        return cls.from_json(Path(path).read_text())


# =========================================================================
# dotted-path derivation (Sweep axes, figure grids)
# =========================================================================

def scenario_with(scenario: Scenario, **overrides) -> Scenario:
    """A copy of ``scenario`` with dotted field paths replaced.

    Keys use ``__`` as the nesting separator when passed as kwargs, or dots
    when passed via the mapping form ``scenario_with(s, **{"pool.replicas":
    4})``.  Values pass through the same strict decoding as
    :meth:`Scenario.from_dict` (lists coerce to tuples, enums validate), so
    sweep axes stay plain JSON.

    >>> s = Scenario()
    >>> scenario_with(s, **{"pool.replicas": 4}).pool.replicas
    4
    >>> scenario_with(s, workload__qps=9.0).workload.qps
    9.0
    >>> try:
    ...     scenario_with(s, **{"pool.nope": 1})
    ... except SpecError as e:
    ...     print(str(e).split(" (")[0])
    pool.nope: unknown key
    """
    out = scenario
    for key, value in overrides.items():
        parts = key.replace("__", ".").split(".")
        out = _replace_path(out, parts, value, path=key.replace("__", "."))
    out.validate()
    return out


def _replace_path(node, parts, value, *, path: str):
    name = parts[0]
    fields_by_name = {f.name: f for f in dataclasses.fields(node)}
    if name not in fields_by_name:
        raise SpecError(f"{path}: unknown key (valid keys: "
                        f"{', '.join(fields_by_name)})")
    hints = _type_hints(type(node))
    if len(parts) == 1:
        new = _decode(hints[name], value, path)
        return dataclasses.replace(node, **{name: new})
    child = getattr(node, name)
    if child is None:                    # e.g. autoscale on a fixed pool
        raise SpecError(f"{path}: cannot set a nested field on "
                        f"{name}=null; set the whole object instead")
    if not dataclasses.is_dataclass(child):
        raise SpecError(f"{path}: {name} is not a nested spec")
    new_child = _replace_path(child, parts[1:], value, path=path)
    return dataclasses.replace(node, **{name: new_child})
