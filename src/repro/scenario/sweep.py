"""Scenario grids: a base scenario × axis values = a list of scenarios.

A :class:`Sweep` is the declarative form of a config-search grid (the
paper's §2.1 workload): a base :class:`~repro.scenario.spec.Scenario` plus
an ordered mapping of dotted field paths to candidate values.  ``expand()``
takes the cartesian product — later axes vary fastest, like nested for
loops — and names each cell after its coordinates, so a whole benchmark
figure is one JSON object instead of a nest of hand-wired kwargs.

>>> sweep = Sweep(Scenario(name="grid"), {
...     "pool.replicas": [1, 2],
...     "workload.qps": [4.0, 24.0],
... })
>>> cells = sweep.expand()
>>> len(cells)
4
>>> [(s.pool.replicas, s.workload.qps) for s in cells]
[(1, 4.0), (1, 24.0), (2, 4.0), (2, 24.0)]
>>> cells[0].name
'grid[replicas=1,qps=4.0]'
>>> Sweep.from_dict(sweep.to_dict()) == sweep
True
"""

from __future__ import annotations

import itertools
import json
from dataclasses import dataclass, field
from typing import Dict, List

from .spec import Scenario, SpecError, scenario_with

__all__ = ["Sweep"]


@dataclass(frozen=True)
class Sweep:
    """A scenario grid: ``base`` × the cartesian product of ``axes``.

    ``axes`` maps dotted field paths (``"pool.replicas"``) to lists of
    values; values go through the same strict decoding as
    :meth:`Scenario.from_dict` (lists coerce to tuples, enums validate), so
    an invalid axis value fails at expansion with its path, before anything
    runs.
    """

    base: Scenario
    axes: Dict[str, list] = field(default_factory=dict)

    def __post_init__(self):
        for path, values in self.axes.items():
            if not isinstance(values, (list, tuple)) or not values:
                raise SpecError(f"axes.{path}: need a non-empty list of "
                                f"values, got {values!r}")

    def __len__(self) -> int:
        n = 1
        for values in self.axes.values():
            n *= len(values)
        return n

    def expand(self) -> List[Scenario]:
        """Every grid cell as a validated scenario, product order (later
        axes fastest), each named ``base.name[leaf=value,...]``."""
        paths = list(self.axes)
        out = []
        for combo in itertools.product(*(self.axes[p] for p in paths)):
            overrides = dict(zip(paths, combo))
            cell = scenario_with(self.base, **overrides)
            coords = ",".join(f"{p.split('.')[-1]}={v!r}"
                              if isinstance(v, str) else
                              f"{p.split('.')[-1]}={v}"
                              for p, v in overrides.items())
            out.append(scenario_with(cell, name=f"{self.base.name}[{coords}]"))
        return out

    # ------------------------------------------------------- serialization --
    def to_dict(self) -> dict:
        return {"base": self.base.to_dict(),
                "axes": json.loads(json.dumps(self.axes))}

    @classmethod
    def from_dict(cls, d: dict) -> "Sweep":
        if not isinstance(d, dict):
            raise SpecError(f"sweep: expected an object, got {d!r}")
        unknown = set(d) - {"base", "axes"}
        if unknown:
            raise SpecError(f"sweep.{sorted(unknown)[0]}: unknown key "
                            "(valid keys: base, axes)")
        base = Scenario.from_dict(d.get("base", {}), path="sweep.base")
        axes = d.get("axes", {})
        if not isinstance(axes, dict):
            raise SpecError(f"sweep.axes: expected an object, got {axes!r}")
        return cls(base=base, axes={str(k): list(v)
                                    for k, v in axes.items()})

    def to_json(self, **kw) -> str:
        kw.setdefault("indent", 2)
        kw.setdefault("sort_keys", True)
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_json(cls, text: str) -> "Sweep":
        try:
            d = json.loads(text)
        except json.JSONDecodeError as e:
            raise SpecError(f"invalid JSON: {e}") from None
        return cls.from_dict(d)
