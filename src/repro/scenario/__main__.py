"""Scenario CLI: run / sweep / compare serving experiments from JSON specs.

::

    python -m repro.scenario list
    python -m repro.scenario show cluster_scaling            # dump JSON
    python -m repro.scenario run cluster_scaling --backend des
    python -m repro.scenario run my_scenario.json --backend process
    python -m repro.scenario sweep cluster_scaling \\
        --axis pool.replicas=1,2,4 --axis workload.qps=4.0,24.0
    python -m repro.scenario sweep my_sweep.json             # {"base","axes"}
    python -m repro.scenario sweep cluster_scaling --jobs 4 \\
        --axis workload.qps=2,4,8,16 --derive-seeds   # parallel workers
    python -m repro.scenario compare distributed_parity \\
        --backends thread,process,des --jobs 2

Positional specs are preset names or paths to scenario JSON files; sweep
also accepts a sweep JSON file (``{"base": {...}, "axes": {...}}``).
``--out`` appends result rows as JSONL.  ``compare`` exits non-zero when
the ≤1-slow-step parity bar fails — this is the CI scenario-smoke gate.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .presets import PRESETS, describe, get_preset
from .runner import ParityError, compare, run, run_sweep
from .spec import Scenario, SpecError
from .sweep import Sweep


def _load_scenario(ref: str) -> Scenario:
    if ref in PRESETS:
        return get_preset(ref)
    path = Path(ref)
    if path.exists():
        return Scenario.load(path)
    raise SystemExit(f"error: {ref!r} is neither a preset "
                     f"({', '.join(sorted(PRESETS))}) nor a JSON file")


def _print_rows(rows) -> None:
    if not rows:
        print("(no rows)")
        return
    cols = list(dict.fromkeys(k for r in rows for k in r))
    widths = {c: max(len(c), *(len(str(r.get(c, ""))) for r in rows))
              for c in cols}
    print("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        print("  ".join(str(r.get(c, "")).ljust(widths[c]) for c in cols))


def _emit(rows, out: str) -> None:
    if not out:
        return
    with open(out, "a") as f:
        for r in rows:
            f.write(json.dumps(r) + "\n")
    print(f"appended {len(rows)} rows -> {out}")


def _cmd_list(_args) -> int:
    for name in sorted(PRESETS):
        print(f"{name:22s} {describe(name)}")
    return 0


def _cmd_show(args) -> int:
    print(_load_scenario(args.spec).to_json())
    return 0


def _cmd_run(args) -> int:
    from .spec import scenario_with
    scenario = _load_scenario(args.spec)
    overrides = {}
    if args.sessions is not None:
        key = ("workload.num_sessions"
               if scenario.workload.kind == "sessions"
               else "workload.num_requests")
        overrides[key] = args.sessions
    if args.streaming:
        overrides["workload.streaming"] = True
    if getattr(args, "transport", None):
        overrides["pool.transport"] = args.transport
    if overrides:
        scenario = scenario_with(scenario, **overrides)
    res = run(scenario, backend=args.backend, timeout=args.timeout,
              audit=args.audit)
    row = res.to_row()
    _print_rows([row])
    _print_fleet(res)
    _emit([row], args.out)
    return 0


def _print_fleet(res) -> None:
    """Per-tenant rollup lines for fleet results (run/compare)."""
    if not res.tenants:
        return
    print(f"fleet: {len(res.pools or {})} pools, {len(res.tenants)} "
          f"tenants, fairness={res.fairness:.4f} "
          f"attainment={res.tenant_attainment():.4f}")
    for name, row in res.tenants.items():
        target = row["model"] + (f"+{row['adapter']}" if row["adapter"]
                                 else "")
        print(f"  tenant {name:12s} -> {target:16s} "
              f"submitted={row['submitted']} completed={row['completed']} "
              f"failed={row['failed']} attainment={row['attainment']} "
              f"goodput={row['goodput_rps']}/s")


def _cmd_sweep(args) -> int:
    if args.spec in PRESETS or not args.spec.endswith(".json"):
        sweep = Sweep(_load_scenario(args.spec), _parse_axes(args.axis))
    else:
        text = Path(args.spec).read_text()
        d = json.loads(text)
        if "axes" in d or "base" in d:
            sweep = Sweep.from_dict(d)
            if args.axis:
                sweep = Sweep(sweep.base,
                              {**sweep.axes, **_parse_axes(args.axis)})
        else:
            sweep = Sweep(Scenario.from_dict(d), _parse_axes(args.axis))
    cells = sweep.expand()
    print(f"sweep: {len(cells)} scenarios on backend={args.backend} "
          f"jobs={args.jobs}")
    results = run_sweep(cells, backend=args.backend, jobs=args.jobs,
                        timeout=args.timeout,
                        derive_seeds=args.derive_seeds)
    rows = [r.to_row() for r in results]      # cell order, jobs-independent
    _print_rows(rows)
    _emit(rows, args.out)
    return 0


def _parse_axes(axis_args) -> dict:
    axes = {}
    for a in axis_args or []:
        if "=" not in a:
            raise SystemExit(f"error: --axis needs path=v1,v2,..., got {a!r}")
        path, values = a.split("=", 1)
        parsed = []
        for v in values.split(","):
            try:
                parsed.append(json.loads(v))
            except json.JSONDecodeError:
                parsed.append(v)               # bare string (policy names)
        axes[path] = parsed
    return axes


def _cmd_compare(args) -> int:
    scenario = _load_scenario(args.spec)
    backends = tuple(args.backends.split(","))
    try:
        cres = compare(scenario, backends=backends, timeout=args.timeout,
                       jobs=args.jobs)
    except ParityError as e:
        print(f"PARITY FAILED: {e}", file=sys.stderr)
        return 1
    rows = [r.to_row() for r in cres.results.values()]
    _print_rows(rows)
    summary = cres.to_row()
    print(f"parity ok: decisions_equal={summary['decisions_equal']} "
          f"max_err={summary['max_err_steps']} slow-steps "
          f"(slow_step={cres.slow_step_s * 1e3:.0f} ms)")
    if scenario.faults:
        any_res = next(iter(cres.results.values()))
        recov = (f" mean_recovery={any_res.mean_recovery_s * 1e3:.0f} ms"
                 if any_res.recovery_times else "")
        print(f"chaos ok: faults_equal={cres.faults_equal} "
              f"injected={len(any_res.faults_injected)} "
              f"requeued={any_res.requests_requeued} "
              f"failed={any_res.requests_failed}{recov}")
    if scenario.fleet is not None:
        _print_fleet(next(iter(cres.results.values())))
    _emit(rows + [summary], args.out)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.scenario",
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="cmd", required=True)

    sub.add_parser("list", help="list presets").set_defaults(fn=_cmd_list)

    p = sub.add_parser("show", help="print a scenario as JSON")
    p.add_argument("spec")
    p.set_defaults(fn=_cmd_show)

    p = sub.add_parser("run", help="run one scenario on one backend")
    p.add_argument("spec")
    p.add_argument("--backend", default="thread",
                   choices=["thread", "process", "des",
                            "process-tcp", "process-shm"])
    p.add_argument("--transport", default="",
                   choices=["", "tcp", "shm"],
                   help="override pool.transport (the process backend's "
                        "wire: framed TCP or shared-memory rings)")
    p.add_argument("--sessions", type=int, default=None,
                   help="override workload size (num_sessions for session "
                        "workloads, num_requests for open loop)")
    p.add_argument("--audit", default="full",
                   choices=["full", "sampled", "off"],
                   help="per-request retention: full (parity/figures), "
                        "sampled (O(1)-memory sketches + SLO reservoir), "
                        "off (sketches only)")
    p.add_argument("--streaming", action="store_true",
                   help="force the lazy streaming workload form")
    p.add_argument("--timeout", type=float, default=600.0)
    p.add_argument("--out", default="", help="append rows as JSONL")
    p.set_defaults(fn=_cmd_run)

    p = sub.add_parser("sweep", help="expand a grid and run every cell")
    p.add_argument("spec", help="preset, scenario JSON, or sweep JSON")
    p.add_argument("--axis", action="append",
                   help="dotted.path=v1,v2,... (repeatable)")
    p.add_argument("--backend", default="thread",
                   choices=["thread", "process", "des",
                            "process-tcp", "process-shm"])
    p.add_argument("--jobs", type=int, default=1,
                   help="fan cells across N worker processes "
                        "(results identical to --jobs 1, same order)")
    p.add_argument("--derive-seeds", action="store_true",
                   help="derive a deterministic per-cell seed from each "
                        "cell name instead of inheriting the base seed")
    p.add_argument("--timeout", type=float, default=600.0)
    p.add_argument("--out", default="", help="append rows as JSONL")
    p.set_defaults(fn=_cmd_sweep)

    p = sub.add_parser("compare",
                       help="run one scenario on several backends + parity")
    p.add_argument("spec")
    p.add_argument("--backends", default="thread,des",
                   help="comma-separated subset of thread,process,des "
                        "(plus the process-tcp/process-shm wire aliases)")
    p.add_argument("--jobs", type=int, default=1,
                   help="run the backend legs in N parallel workers")
    p.add_argument("--timeout", type=float, default=600.0)
    p.add_argument("--out", default="", help="append rows as JSONL")
    p.set_defaults(fn=_cmd_compare)

    args = ap.parse_args(argv)
    try:
        return args.fn(args)
    except SpecError as e:
        print(f"spec error: {e}", file=sys.stderr)
        return 2


if __name__ == "__main__":
    sys.exit(main())
