"""One ``run()`` for every backend: thread emulator, process emulator, DES.

This module is the execution half of the scenario API: it turns a
:class:`~repro.scenario.spec.Scenario` into a live experiment on any
registered backend and returns one uniform :class:`ScenarioResult` schema,
so sweep/figure/CI code never touches ``build_cluster``/``DESConfig``
plumbing again (those remain the internal layer underneath).

* ``backend="thread"`` — N in-process engine replicas on one shared
  VirtualClock under a ManualWallSource: a deterministic pure-jump timeline,
  exactly reproducible from the scenario seed.
* ``backend="process"`` — each replica engine in its own OS process over the
  time-warp socket transport (host wall, by construction).
* ``backend="des"`` — the Vidur-style discrete-event baseline sharing the
  same Router/AutoscalerPolicy/TierSpec objects.

:func:`compare` runs one spec on several backends and checks the repo's
established parity bar: identical routing decisions and per-request
TTFT/TPOT within **one slow-step** (the coarsest predictor step in the
scenario), raising :class:`ParityError` otherwise — the §2.3 semantic-gap
argument as a one-call API.
"""

from __future__ import annotations

import multiprocessing
import time
import zlib
from collections import defaultdict
from concurrent.futures import ProcessPoolExecutor
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .spec import BACKENDS, Scenario, SpecError, scenario_with

__all__ = ["ScenarioResult", "CompareResult", "ParityError", "run", "compare",
           "run_sweep", "derive_cell_seed"]


class ParityError(AssertionError):
    """Cross-backend parity violated (routing divergence or a latency gap
    beyond one slow-step)."""


# =========================================================================
# uniform result schema
# =========================================================================

@dataclass
class ScenarioResult:
    """What every backend returns: one schema for metrics, cost, and the
    audit trails parity checks replay.

    ``latencies`` maps a backend-independent request key — submit index for
    open loop, ``(session_id, turn_index)`` for sessions — to
    ``(ttft, tpot, e2e)`` seconds (``tpot`` is None for 1-token outputs).

    ``audit`` records what this result retains per request: ``"full"``
    keeps every sample and audit trail (the parity/figure default),
    ``"sampled"`` keeps O(1)-memory sketches plus a seeded reservoir of
    SLO samples (``num_slo_samples`` is the true observation count the
    reservoir subsamples), ``"off"`` additionally drops the reservoir.
    Under ``sampled``/``off`` the audit trails (``latencies``,
    ``placements``, ``routing_decisions``, ``slo_samples``) are empty or
    reservoir-sized — parity checks need ``audit="full"``.
    """

    scenario: str
    backend: str
    seed: int
    # completion counts
    num_requests: int
    num_sessions: int
    # latency stats (repro.serving.benchmark.LatencyStats)
    ttft: object
    tpot: object
    e2e: object
    session_ttft: Optional[object]
    # timeline
    makespan_virtual: float
    wall_seconds: float
    throughput_tokens_per_s: float = 0.0
    # SLO / throughput
    slo_samples: List[tuple] = field(repr=False, default_factory=list)
    num_slo_samples: int = 0
    slo_ttft_s: Optional[float] = None
    slo_tpot_s: Optional[float] = None
    # retention mode (see class docstring)
    audit: str = "full"
    # cost accounting
    replica_seconds: float = 0.0
    cost_dollars: float = 0.0
    tier_seconds: Optional[Dict[Optional[str], float]] = None
    # emulation-speed accounting (events/sec, barrier pressure)
    num_steps: int = 0
    timekeeper: Optional[dict] = field(repr=False, default=None)
    # audit trails (parity)
    routing_decisions: List[int] = field(repr=False, default_factory=list)
    placements: Optional[Dict[tuple, int]] = field(repr=False, default=None)
    latencies: Dict[object, tuple] = field(repr=False, default_factory=dict)
    replica_tiers: List[Optional[str]] = field(default_factory=list)
    scaleups: List[Tuple[float, Optional[str]]] = field(default_factory=list)
    drained: List[int] = field(default_factory=list)
    # fault-injection audit (chaos scenarios): the applied fault log in
    # nominal virtual times — primitive tuples, float-exactly comparable
    # across backends (see repro.cluster.faults.FaultInjector.events)
    faults_injected: List[tuple] = field(default_factory=list)
    requests_requeued: int = 0
    requests_failed: int = 0
    # (fault_time, respawn_time) per recovered replica, virtual seconds
    recovery_times: List[Tuple[float, float]] = field(default_factory=list)
    # fleet plane (repro.fleet): per-tenant / per-pool rollups.  ``tenants``
    # maps tenant name -> metrics row (submitted/completed/failed counts,
    # attainment against the tenant's own SLO, goodput); ``pools`` maps
    # model pool name -> its sub-run summary; ``fairness`` is Jain's index
    # over per-tenant attainment.  All None outside fleet runs.
    tenants: Optional[Dict[str, dict]] = None
    pools: Optional[Dict[str, dict]] = None
    fairness: Optional[float] = None

    @property
    def mean_recovery_s(self) -> float:
        """Mean fault-to-respawn delay across recovered replicas."""
        if not self.recovery_times:
            return 0.0
        return float(np.mean([r - f for f, r in self.recovery_times]))

    @property
    def speedup(self) -> float:
        return (self.makespan_virtual / self.wall_seconds
                if self.wall_seconds else 0.0)

    @property
    def request_rate_completed(self) -> float:
        return (self.num_requests / self.makespan_virtual
                if self.makespan_virtual else 0.0)

    @property
    def tiers_added(self) -> List[Optional[str]]:
        """Tier of every autoscaler-provisioned replica, join order."""
        return [t for _, t in self.scaleups]

    def tenant_attainment(self) -> Optional[float]:
        """Submission-weighted aggregate SLO attainment across tenants
        (each tenant judged against its own SLO); None outside fleet runs."""
        if not self.tenants:
            return None
        total = sum(t["submitted"] for t in self.tenants.values())
        if not total:
            return 0.0
        return sum(t["attainment"] * t["submitted"]
                   for t in self.tenants.values()) / total

    def slo_attainment(self, slo_ttft_s: Optional[float] = None,
                       slo_tpot_s: Optional[float] = None) -> float:
        """Fraction of completions meeting the SLOs (defaults: the
        scenario's own SLOSpec; a missing bound is unconstrained)."""
        slo_ttft = slo_ttft_s if slo_ttft_s is not None else self.slo_ttft_s
        slo_tpot = slo_tpot_s if slo_tpot_s is not None else self.slo_tpot_s
        if not self.slo_samples:
            return 0.0
        good = 0
        for ttft, tpot in self.slo_samples:
            ttft_ok = slo_ttft is None or ttft is None or ttft <= slo_ttft
            tpot_ok = slo_tpot is None or tpot is None or tpot <= slo_tpot
            good += int(ttft_ok and tpot_ok)
        return good / len(self.slo_samples)

    def goodput_rps(self, **kw) -> float:
        """SLO-attaining completions per virtual second.  Under
        ``audit="sampled"`` attainment comes from the reservoir but is
        scaled by the true observation count, keeping goodput unbiased."""
        if not self.makespan_virtual:
            return 0.0
        n = self.num_slo_samples or len(self.slo_samples)
        return self.slo_attainment(**kw) * n / self.makespan_virtual

    def to_row(self) -> dict:
        """Flat dict for tables / JSONL artifacts (benchmark figures)."""
        row = {
            "scenario": self.scenario,
            "backend": self.backend,
            "requests": self.num_requests,
            "ttft_p50_ms": round(self.ttft.p50 * 1e3, 1),
            "ttft_p99_ms": round(self.ttft.p99 * 1e3, 1),
            "tpot_p50_ms": round(self.tpot.p50 * 1e3, 2),
            "completed_rps": round(self.request_rate_completed, 3),
            "replica_seconds": round(self.replica_seconds, 2),
            "virtual_s": round(self.makespan_virtual, 2),
            "wall_s": round(self.wall_seconds, 2),
            "speedup_x": round(self.speedup, 1),
        }
        if self.slo_ttft_s is not None or self.slo_tpot_s is not None:
            row["slo_attainment"] = round(self.slo_attainment(), 4)
            row["goodput_rps"] = round(self.goodput_rps(), 3)
        if self.cost_dollars:
            row["cost_dollars"] = round(self.cost_dollars, 6)
        if self.num_sessions:
            row["sessions"] = self.num_sessions
            if self.session_ttft is not None:
                row["session_ttft_p50_ms"] = round(
                    self.session_ttft.p50 * 1e3, 1)
        if self.scaleups:
            row["tiers_added"] = ",".join(t or "?" for t in self.tiers_added)
        if self.tenants:
            row["tenants"] = len(self.tenants)
            row["fleet_attainment"] = round(self.tenant_attainment(), 4)
            if self.fairness is not None:
                row["fairness"] = round(self.fairness, 4)
        if self.faults_injected:
            row["faults"] = len(self.faults_injected)
            row["requeued"] = self.requests_requeued
            row["failed"] = self.requests_failed
            if self.recovery_times:
                row["mean_recovery_s"] = round(self.mean_recovery_s, 3)
        return row


# =========================================================================
# shared wiring
# =========================================================================

def _ordered_tiers(scenario: Scenario) -> List[str]:
    """Every tier name the scenario can touch (pool + autoscale candidates),
    first-mention order — the set make_tier_specs must cover."""
    names: List[str] = []
    for t in (scenario.pool.replica_tiers() or []):
        if t is not None and t not in names:
            names.append(t)
    if scenario.autoscale is not None:
        for t in scenario.autoscale.tiers:
            if t not in names:
                names.append(t)
    return names


class _Wiring:
    """Everything run() derives from a scenario, built once per run so all
    backends share the exact same spec/predictor arithmetic."""

    def __init__(self, scenario: Scenario):
        from repro.core.predictor import StaticPredictor
        from repro.cluster.tiers import make_tier_specs

        scenario.validate()
        self.scenario = scenario
        self.model_cfg = scenario.pool.model_config()
        self.engine_cfg = scenario.pool.engine_config()
        self.predictor = (StaticPredictor(scenario.pool.step_time_s)
                          if scenario.pool.step_time_s is not None else None)
        self.tier_predictors = ({
            t: StaticPredictor(s)
            for t, s in scenario.pool.tier_step_time_s.items()
        } if scenario.pool.tier_step_time_s else None)
        tier_names = _ordered_tiers(scenario)
        self.tier_specs = (make_tier_specs(
            self.model_cfg, self.engine_cfg, tier_names,
            tier_predictors=self.tier_predictors) if tier_names else None)

    def base_predictor(self):
        """The predictor for untiered replicas (and the DES fallback)."""
        from repro.serving.stack import default_predictor
        if self.predictor is not None:
            return self.predictor
        tiers = self.scenario.pool.replica_tiers()
        if tiers and tiers[0] is not None and self.tier_predictors \
                and tiers[0] in self.tier_predictors:
            return self.tier_predictors[tiers[0]]
        return default_predictor(self.model_cfg, self.engine_cfg)

    def slow_step_s(self) -> float:
        """The coarsest predictor step in the scenario — the parity unit."""
        from repro.core.predictor import BatchSpec, SeqSpec
        pool = self.scenario.pool
        steps = list((pool.tier_step_time_s or {}).values())
        if pool.step_time_s is not None:
            steps.append(pool.step_time_s)
        if steps:
            return max(steps)
        probe = BatchSpec.make([SeqSpec(1, 256)])
        return self.base_predictor().predict_step(probe).total


def _latency_sample(ttft, tpot, e2e):
    return (ttft, tpot, e2e)


def _session_stats(groups: Dict[int, List[tuple]]):
    """Per-session mean TTFT/TPOT percentile stats from (ttft, tpot) lists."""
    from repro.serving.benchmark import LatencyStats
    mean_ttfts, mean_tpots = [], []
    for samples in groups.values():
        ts = [t for t, _ in samples if t is not None]
        ps = [p for _, p in samples if p is not None]
        if ts:
            mean_ttfts.append(float(np.mean(ts)))
        if ps:
            mean_tpots.append(float(np.mean(ps)))
    return LatencyStats.of(mean_ttfts), LatencyStats.of(mean_tpots)


# =========================================================================
# backends
# =========================================================================

def _run_emulated(scenario: Scenario, wiring: _Wiring, backend: str,
                  timeout: float, audit: str = "full",
                  transport: Optional[str] = None,
                  label: Optional[str] = None,
                  workload_override: Optional[list] = None) -> ScenarioResult:
    from repro.cluster import Autoscaler, build_cluster
    from repro.core.clock import ManualWallSource
    from repro.serving.benchmark import BenchmarkRunner

    pool, autoscale = scenario.pool, scenario.autoscale
    # respawn headroom: every fault that can recover activates one warm
    # standby per killed replica (spot_reclaim: one per tier member)
    respawn_extra = 0
    tiers_list = pool.replica_tiers() or []
    for f in scenario.faults:
        if not f.recover:
            continue
        if f.kind == "crash":
            respawn_extra += 1
        elif f.kind == "spot_reclaim":
            respawn_extra += sum(1 for t in tiers_list if t == f.tier)
    warm = None
    if backend == "process" and (autoscale is not None or respawn_extra):
        # pre-spawn the whole headroom (autoscaler max + chaos respawns) so
        # scale-ups and recoveries activate a warm child, paying only the
        # modeled provisioning/respawn delay, never process-spawn wall time
        # mid-run
        base_total = (autoscale.max_replicas if autoscale is not None
                      else pool.replicas)
        warm = base_total + respawn_extra
    cluster = build_cluster(
        wiring.model_cfg, wiring.engine_cfg, pool.replicas,
        policy=scenario.routing.policy, mode="emulate", backend=backend,
        predictor=wiring.predictor, tiers=pool.replica_tiers(),
        tier_predictors=wiring.tier_predictors, tier_specs=wiring.tier_specs,
        router_kwargs=scenario.routing.kwargs,
        wall=ManualWallSource() if backend == "thread" else None,
        warm_replicas=warm,
        transport=transport if transport is not None else pool.transport)
    autoscaler = None
    if autoscale is not None:
        autoscaler = Autoscaler(cluster, autoscale.make_policy(),
                                autoscale.make_config())
    injector = None
    if scenario.faults:
        from repro.cluster.faults import FaultInjector
        injector = FaultInjector(cluster, scenario.faults)
    # the fleet plane pre-splits one materialized stream across pools and
    # passes each pool its (tenant-tagged) slice directly
    workload = (list(workload_override) if workload_override is not None
                else scenario.workload.materialize(scenario.seed))
    closed = (scenario.workload.kind == "sessions"
              and workload_override is None)
    try:
        res = BenchmarkRunner(cluster, workload,
                              transport=cluster.transport,
                              autoscaler=autoscaler,
                              fault_injector=injector,
                              audit=audit,
                              metrics_seed=scenario.seed
                              ).run(timeout=timeout)
        if audit == "full":
            reqs = list(cluster.finished)
            if closed:
                keyed = {(r.session_id, r.turn_index): r for r in reqs}
                placements = {(s, t): idx
                              for s, t, _, idx in cluster.placements}
            else:
                ordered = sorted(reqs, key=lambda r: r.arrival_time)
                keyed = dict(enumerate(ordered))
                placements = None
            latencies = {
                k: _latency_sample(r.ttft(),
                                   r.tpot() if r.num_generated > 1 else None,
                                   r.e2e_latency())
                for k, r in keyed.items()
            }
        else:                  # sampled/off: no per-request audit trails
            placements = None
            latencies = {}
        drained = [m["replica"] for m in cluster.membership_events()
                   if m["drained"] is not None]
        # one scale-up audit: autoscaler provisions + chaos respawns, in
        # virtual-time order (both sources record absolute clock stamps)
        scaleups = list(autoscaler.scaleups) if autoscaler else []
        if injector is not None:
            scaleups = sorted(scaleups + list(injector.respawn_scaleups),
                              key=lambda e: e[0])
        cstats = cluster.stats()
        return ScenarioResult(
            scenario=scenario.name, backend=label or backend,
            seed=scenario.seed,
            num_requests=res.num_requests, num_sessions=res.num_sessions,
            ttft=res.ttft, tpot=res.tpot, e2e=res.e2e,
            session_ttft=res.session_ttft,
            makespan_virtual=res.makespan_virtual,
            wall_seconds=res.wall_seconds,
            throughput_tokens_per_s=res.throughput_tokens_per_s,
            slo_samples=list(res.slo_samples),
            num_slo_samples=res.num_slo_samples,
            slo_ttft_s=scenario.slo.ttft_s, slo_tpot_s=scenario.slo.tpot_s,
            audit=audit,
            replica_seconds=res.replica_seconds,
            cost_dollars=res.cost_dollars,
            tier_seconds=res.tier_seconds,
            num_steps=cstats.get("steps", 0),
            timekeeper=cstats.get("timekeeper"),
            routing_decisions=list(cluster.router.decisions),
            placements=placements,
            latencies=latencies,
            replica_tiers=list(cluster.replica_tiers),
            scaleups=scaleups,
            drained=drained,
            faults_injected=list(injector.events) if injector else [],
            requests_requeued=injector.requeued if injector else 0,
            requests_failed=injector.failed if injector else 0,
            recovery_times=list(injector.recoveries) if injector else [],
        )
    finally:
        cluster.shutdown()


def _run_des(scenario: Scenario, wiring: _Wiring,
             timeout: float, audit: str = "full",
             workload_override: Optional[list] = None) -> ScenarioResult:
    from repro.cluster.router import make_router
    from repro.des.simulator import DESConfig, DiscreteEventSimulator
    from repro.metrics import StreamingMetrics
    from repro.serving.benchmark import LatencyStats

    pool, autoscale = scenario.pool, scenario.autoscale
    router = make_router(scenario.routing.policy, pool.replicas,
                         **(scenario.routing.kwargs or {}))
    sim = DiscreteEventSimulator(
        wiring.base_predictor(),
        DESConfig(max_num_seqs=pool.max_num_seqs,
                  max_batched_tokens=pool.max_batched_tokens,
                  step_overhead_s=0.0),
        num_replicas=pool.replicas, router=router,
        autoscaler_policy=(autoscale.make_policy() if autoscale else None),
        autoscaler_cfg=(autoscale.make_config() if autoscale else None),
        replica_tiers=pool.replica_tiers(),
        tier_predictors=wiring.tier_predictors,
        tier_specs=wiring.tier_specs,
        faults=scenario.faults)
    workload = (list(workload_override) if workload_override is not None
                else scenario.workload.materialize(scenario.seed))
    closed = (scenario.workload.kind == "sessions"
              and workload_override is None)
    initial_replicas = pool.replicas

    if audit != "full":
        # flat-memory path: completions flow straight into O(1)-memory
        # accumulators via the sink; nothing per-request is retained
        router.record_decisions = False
        m = StreamingMetrics(
            seed=scenario.seed,
            session_turns=getattr(workload, "session_turns", None))
        wall0 = time.monotonic()
        sim.run(workload, sink=m.observe)
        wall = time.monotonic() - wall0
        m.finalize()
        makespan = m.max_finish or 0.0
        tier_s: Dict[Optional[str], float] = {}
        for rep in sim.replicas:
            end = rep.drained_at if rep.drained_at is not None else makespan
            on = max(0.0, min(end, makespan) - rep.added_at)
            tier_s[rep.tier] = tier_s.get(rep.tier, 0.0) + on
        return ScenarioResult(
            scenario=scenario.name, backend="des", seed=scenario.seed,
            num_requests=m.count, num_sessions=m.num_sessions,
            ttft=m.ttft.stats(), tpot=m.tpot.stats(), e2e=m.e2e.stats(),
            session_ttft=(m.session_ttft.stats()
                          if m.session_ttft.count else None),
            makespan_virtual=makespan, wall_seconds=wall,
            throughput_tokens_per_s=(m.total_new_tokens / makespan
                                     if makespan else 0.0),
            slo_samples=[] if audit == "off" else list(m.slo.items),
            num_slo_samples=m.num_slo_samples,
            slo_ttft_s=scenario.slo.ttft_s, slo_tpot_s=scenario.slo.tpot_s,
            audit=audit,
            replica_seconds=sim.replica_seconds(makespan),
            cost_dollars=sim.replica_cost(makespan),
            tier_seconds=tier_s,
            replica_tiers=[r.tier for r in sim.replicas],
            scaleups=[(r.added_at, r.tier)
                      for r in sim.replicas[initial_replicas:]],
            drained=[r.index for r in sim.replicas
                     if r.drained_at is not None],
            faults_injected=list(sim.fault_log),
            requests_requeued=sim.requeued_total,
            requests_failed=len(sim.failed),
            recovery_times=list(sim.recoveries),
        )

    wall0 = time.monotonic()
    sims = sim.run(workload)
    wall = time.monotonic() - wall0

    done = [s for s in sims if s.finish_time is not None]
    finishes = [s.finish_time for s in done]
    makespan = max(finishes) if finishes else 0.0
    ttft = LatencyStats.of([s.ttft() for s in done if s.ttft() is not None])
    tpot = LatencyStats.of([s.tpot() for s in done
                            if s.tpot() is not None and s.num_generated > 1])
    e2e = LatencyStats.of([s.finish_time - s.arrival_time for s in done])
    if closed:
        keyed = {(s.session_id, s.turn_index): s for s in done}
        placements = {k: s.replica for k, s in keyed.items()}
    else:
        ordered = sorted(done, key=lambda s: s.arrival_time)
        keyed = dict(enumerate(ordered))
        placements = None
    latencies = {
        k: _latency_sample(s.ttft(),
                           s.tpot() if s.num_generated > 1 else None,
                           s.finish_time - s.arrival_time)
        for k, s in keyed.items()
    }
    by_session: Dict[int, List[tuple]] = defaultdict(list)
    for s in done:
        if s.session_id is not None:
            by_session[s.session_id].append(
                (s.ttft(), s.tpot() if s.num_generated > 1 else None))
    session_ttft = None
    if by_session:
        session_ttft, _ = _session_stats(by_session)

    tier_s: Dict[Optional[str], float] = {}
    for rep in sim.replicas:
        end = rep.drained_at if rep.drained_at is not None else makespan
        on = max(0.0, min(end, makespan) - rep.added_at)
        tier_s[rep.tier] = tier_s.get(rep.tier, 0.0) + on
    return ScenarioResult(
        scenario=scenario.name, backend="des", seed=scenario.seed,
        num_requests=len(done),
        num_sessions=len(by_session),
        ttft=ttft, tpot=tpot, e2e=e2e, session_ttft=session_ttft,
        makespan_virtual=makespan, wall_seconds=wall,
        throughput_tokens_per_s=(sum(s.num_generated for s in done)
                                 / makespan if makespan else 0.0),
        slo_samples=[(s.ttft(), s.tpot() if s.num_generated > 1 else None)
                     for s in done],
        slo_ttft_s=scenario.slo.ttft_s, slo_tpot_s=scenario.slo.tpot_s,
        replica_seconds=sim.replica_seconds(makespan),
        cost_dollars=sim.replica_cost(makespan),
        tier_seconds=tier_s,
        routing_decisions=list(router.decisions),
        placements=placements,
        latencies=latencies,
        replica_tiers=[r.tier for r in sim.replicas],
        scaleups=[(r.added_at, r.tier)
                  for r in sim.replicas[initial_replicas:]],
        drained=[r.index for r in sim.replicas
                 if r.drained_at is not None],
        faults_injected=list(sim.fault_log),
        requests_requeued=sim.requeued_total,
        requests_failed=len(sim.failed),
        recovery_times=list(sim.recoveries),
    )


# =========================================================================
# public entry points
# =========================================================================

BACKEND_ALIASES = {
    # backend aliases pinning the process backend's wire transport; they
    # override the scenario's pool.transport, so one scenario can run
    # process-tcp vs process-shm side by side in a compare()
    "process-tcp": ("process", "tcp"),
    "process-shm": ("process", "shm"),
}


def run(scenario: Scenario, backend: str = "thread", *,
        timeout: float = 600.0, audit: str = "full") -> ScenarioResult:
    """Execute one scenario on one backend; all wiring included.

    ``backend`` is ``"thread"`` (in-process emulator on a deterministic
    manual wall), ``"process"`` (replicas as OS processes over the wire
    transport the scenario's ``pool.transport`` selects), or ``"des"``
    (the discrete-event baseline).  The aliases ``"process-tcp"`` and
    ``"process-shm"`` pin the wire explicitly (compare() legs).  The same
    scenario object/JSON runs unmodified on all of them.

    ``audit`` selects per-request retention (see
    :class:`ScenarioResult`): ``"full"`` (default, required for parity
    checks), ``"sampled"`` (sketches + SLO reservoir — the flat-memory
    scale mode), or ``"off"`` (sketches only).
    """
    from repro.serving.benchmark import AUDIT_MODES
    base, transport = BACKEND_ALIASES.get(backend, (backend, None))
    if base not in BACKENDS:
        raise SpecError(
            f"backend: invalid value {backend!r} (choose from "
            f"{sorted(BACKENDS) + sorted(BACKEND_ALIASES)})")
    if audit not in AUDIT_MODES:
        raise SpecError(f"audit: invalid value {audit!r} "
                        f"(choose from {sorted(AUDIT_MODES)})")
    if scenario.fleet is not None:
        from repro.fleet.runner import run_fleet
        return run_fleet(scenario, backend, timeout=timeout, audit=audit)
    wiring = _Wiring(scenario)
    if base == "des":
        if scenario.routing.policy == "pd_pool":
            raise SpecError("routing.policy: pd_pool is not supported on "
                            "the des backend (Table 1 semantic gap)")
        return _run_des(scenario, wiring, timeout, audit)
    if base == "process" and scenario.routing.policy == "pd_pool":
        raise SpecError("routing.policy: pd_pool is not supported on the "
                        "process backend")
    return _run_emulated(scenario, wiring, base, timeout, audit,
                         transport=transport,
                         label=backend if backend != base else None)


# =========================================================================
# parallel execution (sweep cells / compare legs)
# =========================================================================

def _run_cell(payload: tuple) -> ScenarioResult:
    """Executor worker: one (scenario-dict, backend, timeout) triple.

    Module-scope so ``spawn`` workers can import it; scenarios travel in
    their canonical JSON-dict form (the declarative API's serialization), so
    the worker rebuilds exactly what the parent validated.
    """
    scenario_dict, backend, timeout = payload[:3]
    audit = payload[3] if len(payload) > 3 else "full"
    return run(Scenario.from_dict(scenario_dict), backend, timeout=timeout,
               audit=audit)


def derive_cell_seed(base_seed: int, name: str) -> int:
    """Deterministic per-cell seed: the base seed folded with a stable hash
    of the cell name (crc32, never Python's per-interpreter-salted
    ``hash``), so a cell keeps its seed no matter the grid shape, the cell
    order, or which worker process runs it."""
    return (int(base_seed) + zlib.crc32(name.encode("utf-8"))) % (2**31 - 1)


def run_sweep(sweep, backend: str = "thread", *, jobs: int = 1,
              timeout: float = 600.0, audit: str = "full",
              derive_seeds: bool = False) -> List[ScenarioResult]:
    """Run every cell of a sweep (a :class:`~repro.scenario.sweep.Sweep` or
    any iterable of scenarios); returns results in cell order.

    ``jobs > 1`` fans cells across worker processes — each cell owns its
    private Timekeeper/cluster, so cells are embarrassingly parallel and the
    results are independent of ``jobs`` (same cells, same seeds, same
    order).  ``derive_seeds=True`` replaces each cell's inherited seed with
    :func:`derive_cell_seed` of its name, decorrelating the sampled
    workloads across a grid while staying fully reproducible.
    """
    cells = list(sweep.expand()) if hasattr(sweep, "expand") else list(sweep)
    if derive_seeds:
        cells = [scenario_with(c, seed=derive_cell_seed(c.seed, c.name))
                 for c in cells]
    payloads = [(c.to_dict(), backend, timeout, audit) for c in cells]
    if jobs <= 1 or len(cells) <= 1:
        return [_run_cell(p) for p in payloads]
    # spawn, never fork: cells start engine/reader threads and the process
    # backend spawns grandchildren — a forked worker would inherit parent
    # locks mid-flight.
    ctx = multiprocessing.get_context("spawn")
    with ProcessPoolExecutor(max_workers=min(int(jobs), len(cells)),
                             mp_context=ctx) as ex:
        return list(ex.map(_run_cell, payloads))


@dataclass
class CompareResult:
    """Outcome of running one scenario on several backends."""

    scenario: str
    backends: Tuple[str, ...]
    results: Dict[str, ScenarioResult]
    slow_step_s: float
    completed_equal: bool
    decisions_equal: bool
    scaleup_tiers_equal: bool
    drained_equal: bool
    max_ttft_err_s: float
    max_tpot_err_s: float
    faults_equal: bool = True

    @property
    def max_err_steps(self) -> float:
        return (max(self.max_ttft_err_s, self.max_tpot_err_s)
                / self.slow_step_s if self.slow_step_s else 0.0)

    def to_row(self) -> dict:
        return {
            "scenario": self.scenario,
            "backends": "/".join(self.backends),
            "completed": {b: r.num_requests for b, r in self.results.items()},
            "completed_equal": self.completed_equal,
            "decisions_equal": self.decisions_equal,
            "ttft_err_steps": round(self.max_ttft_err_s / self.slow_step_s, 3)
            if self.slow_step_s else 0.0,
            "tpot_err_steps": round(self.max_tpot_err_s / self.slow_step_s, 3)
            if self.slow_step_s else 0.0,
            "max_err_steps": round(self.max_err_steps, 3),
        }


def _decisions_of(res: ScenarioResult):
    """The placement audit in a backend-independent form: the decision list
    for open loop, the per-turn placement map for closed loop."""
    return res.placements if res.placements is not None \
        else res.routing_decisions


def compare(scenario: Scenario,
            backends: Sequence[str] = ("thread", "des"), *,
            timeout: float = 600.0,
            slow_step_s: Optional[float] = None,
            check: bool = True,
            jobs: int = 1) -> CompareResult:
    """Run one scenario on several backends and check parity.

    The bar (``check=True``, the default) is the repo's established one:

    * every backend completes the same request set;
    * routing decisions are identical (per-turn placements for sessions);
    * autoscaler scale-up tier sequences and drain victims agree;
    * per-request TTFT and TPOT agree within **one slow-step**
      (``slow_step_s`` defaults to the scenario's coarsest predictor step).

    Violations raise :class:`ParityError`; the returned
    :class:`CompareResult` carries the per-backend results and error
    magnitudes either way (pass ``check=False`` to inspect without
    raising).  ``jobs > 1`` runs the backend legs in parallel worker
    processes (each leg owns its world; results are jobs-independent).
    """
    backends = tuple(backends)
    if len(backends) < 2:
        raise SpecError("compare needs at least two backends")
    if scenario.fleet is not None:
        # fleet slow-step: the coarsest predictor step over *all* model
        # pools (the parity unit must bound every pool's discretization)
        scenario.validate()
        from repro.fleet.runner import fleet_slow_step_s
        step = (slow_step_s if slow_step_s is not None
                else fleet_slow_step_s(scenario))
    else:
        wiring = _Wiring(scenario)
        step = (slow_step_s if slow_step_s is not None
                else wiring.slow_step_s())

    if jobs > 1:
        ctx = multiprocessing.get_context("spawn")
        payloads = [(scenario.to_dict(), b, timeout) for b in backends]
        with ProcessPoolExecutor(max_workers=min(int(jobs), len(backends)),
                                 mp_context=ctx) as ex:
            results = dict(zip(backends, ex.map(_run_cell, payloads)))
    else:
        results = {b: run(scenario, b, timeout=timeout) for b in backends}
    base_b = backends[0]
    base = results[base_b]

    problems: List[str] = []
    completed_equal = True
    decisions_equal = True
    scaleups_equal = True
    drained_equal = True
    faults_equal = True
    max_ttft = 0.0
    max_tpot = 0.0
    for b in backends[1:]:
        other = results[b]
        if base.faults_injected != other.faults_injected:
            faults_equal = False
            problems.append(
                f"{base_b}/{b}: fault event sequences diverge "
                f"({base.faults_injected} vs {other.faults_injected})")
        if (base.requests_requeued != other.requests_requeued
                or base.requests_failed != other.requests_failed):
            faults_equal = False
            problems.append(
                f"{base_b}/{b}: requeue/fail outcomes diverge "
                f"(requeued {base.requests_requeued} vs "
                f"{other.requests_requeued}, failed {base.requests_failed} "
                f"vs {other.requests_failed})")
        if set(base.latencies) != set(other.latencies):
            completed_equal = False
            problems.append(
                f"{base_b}/{b}: completed different request sets "
                f"({base.num_requests} vs {other.num_requests})")
            continue
        if _decisions_of(base) != _decisions_of(other):
            decisions_equal = False
            problems.append(f"{base_b}/{b}: routing decisions diverge")
        if base.tiers_added != other.tiers_added:
            scaleups_equal = False
            problems.append(
                f"{base_b}/{b}: scale-up tiers diverge "
                f"({base.tiers_added} vs {other.tiers_added})")
        if base.drained != other.drained:
            drained_equal = False
            problems.append(
                f"{base_b}/{b}: drain victims diverge "
                f"({base.drained} vs {other.drained})")
        for k, (ttft_a, tpot_a, _) in base.latencies.items():
            ttft_b, tpot_b, _ = other.latencies[k]
            if ttft_a is not None and ttft_b is not None:
                max_ttft = max(max_ttft, abs(ttft_a - ttft_b))
            if tpot_a is not None and tpot_b is not None:
                max_tpot = max(max_tpot, abs(tpot_a - tpot_b))

    if max(max_ttft, max_tpot) > step + 1e-9:
        problems.append(
            f"latencies diverge by {max(max_ttft, max_tpot) / step:.3f} "
            f"slow-steps (bar: 1.0 × {step}s)")
    out = CompareResult(
        scenario=scenario.name, backends=backends, results=results,
        slow_step_s=step, completed_equal=completed_equal,
        decisions_equal=decisions_equal,
        scaleup_tiers_equal=scaleups_equal, drained_equal=drained_equal,
        max_ttft_err_s=max_ttft, max_tpot_err_s=max_tpot,
        faults_equal=faults_equal)
    if check and problems:
        raise ParityError(
            f"scenario {scenario.name!r} parity failed across "
            f"{'/'.join(backends)}: " + "; ".join(problems))
    return out
