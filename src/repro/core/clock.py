"""Virtual clock primitives (paper §4.2.1).

Revati represents global virtual time as an *offset* from wall-clock time::

    t_virtual = t_wall + offset                                   (Eq. 1)

Initially ``offset = 0`` so virtual time equals wall time.  As Actors request
time jumps the Timekeeper monotonically increases the offset, causing virtual
time to advance faster than wall time.  Observers read virtual time without
any coordination: they read the current offset and add wall time.

Two properties of this representation are load-bearing for correctness:

* **Monotonicity** — ``offset`` only ever grows, and wall time only ever
  grows, so virtual time is monotone even under concurrent reads.
* **Graceful degradation** — if no clock update arrives, virtual time still
  advances at wall rate.  A client waiting ``t_remaining`` *wall* seconds is
  therefore guaranteed that ``t_remaining`` *virtual* seconds have elapsed,
  which is exactly the timeout rule of Algorithm 1.

All times are float seconds.  (The paper quotes milliseconds; seconds are the
Python-native unit and conversion is confined to display code.)
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Optional

__all__ = [
    "WallSource",
    "MonotonicWallSource",
    "UnixWallSource",
    "ManualWallSource",
    "VirtualClock",
]


class WallSource:
    """Abstract source of wall-clock time.

    Injectable so tests can control the passage of wall time and so the
    cross-process transport can use a host-shared epoch (``time.time``)
    rather than the per-process ``time.monotonic``.
    """

    def time(self) -> float:
        raise NotImplementedError

    def sleep(self, seconds: float) -> None:  # pragma: no cover - trivial
        if seconds > 0:
            time.sleep(seconds)

    def sleep_precise(self, seconds: float, *, spin: float = 1.5e-3) -> None:
        """Hybrid sleep: coarse ``time.sleep`` for the bulk, busy-wait for the
        final ``spin`` seconds.  OS timer slop makes plain sleep() overshoot
        by 0.1–2 ms, which systematically inflates the sleep-based-emulation
        baseline; the spin tail removes that bias (µs-accurate) at the cost
        of one core — acceptable for the strawman we are comparing against."""
        if seconds <= 0:
            return
        deadline = self.time() + seconds
        bulk = seconds - spin
        if bulk > 0:
            time.sleep(bulk)
        while self.time() < deadline:
            pass


class MonotonicWallSource(WallSource):
    """Default in-process wall source (immune to NTP steps)."""

    def time(self) -> float:
        return time.monotonic()


class UnixWallSource(WallSource):
    """Host-shared wall source for the multi-process socket transport.

    ``time.monotonic`` epochs are per-process and therefore not comparable
    across processes; ``time.time`` is shared by all processes on a host.
    Cross-host deployments inherit NTP skew as a bounded additive error on
    virtual timestamps (same trade-off the paper makes for its ZeroMQ
    deployment).
    """

    def time(self) -> float:
        return time.time()


class ManualWallSource(WallSource):
    """Deterministic wall source for tests: time advances only on demand."""

    def __init__(self, start: float = 0.0):
        self._now = start
        self._lock = threading.Lock()

    def time(self) -> float:
        with self._lock:
            return self._now

    def advance(self, dt: float) -> None:
        assert dt >= 0
        with self._lock:
            self._now += dt

    def sleep(self, seconds: float) -> None:
        # Sleeping *is* advancing in manual mode.
        self.advance(max(0.0, seconds))

    def sleep_precise(self, seconds: float, **_kw) -> None:
        # Spinning on manual time would never terminate; advance directly.
        self.advance(max(0.0, seconds))


class VirtualClock:
    """Thread-safe virtual clock shared by the Timekeeper and its clients.

    The clock is a pair ``(offset, epoch)``: ``offset`` implements Eq. 1 and
    ``epoch`` counts clock *updates* (barrier resolutions).  The epoch bumps
    on every barrier resolution even when the offset is unchanged — waking
    blocked clients promptly instead of letting them ride out their
    degradation timeout.  This is a strict improvement over the literal
    Algorithm 2 (which broadcasts only when the offset grows) and preserves
    its semantics: clients re-check their target on every wake.
    """

    def __init__(self, wall: Optional[WallSource] = None):
        self.wall = wall or MonotonicWallSource()
        self._offset = 0.0
        self._epoch = 0
        self._cond = threading.Condition()

    # ------------------------------------------------------------- reads --
    def now(self) -> float:
        """Current virtual time (Observers call this freely, no coordination)."""
        with self._cond:
            return self.wall.time() + self._offset

    @property
    def offset(self) -> float:
        with self._cond:
            return self._offset

    @property
    def epoch(self) -> int:
        with self._cond:
            return self._epoch

    def snapshot(self) -> tuple[float, int]:
        """Atomic (virtual_now, epoch) pair."""
        with self._cond:
            return self.wall.time() + self._offset, self._epoch

    # ----------------------------------------------------------- updates --
    def advance_to(self, t_min: float) -> float:
        """Advance virtual time to at least ``t_min`` (Algorithm 2, l.7–10).

        ``offset = max(offset, t_min - t_wall)`` — the ``max`` makes the call
        idempotent and keeps the clock monotone when wall time has already
        overtaken ``t_min`` (the degradation path).  Returns the new offset.
        """
        with self._cond:
            t_wall = self.wall.time()
            self._offset = max(self._offset, t_min - t_wall)
            self._epoch += 1
            self._cond.notify_all()
            return self._offset

    def apply_update(self, offset: float, epoch: int) -> None:
        """Install a replicated (offset, epoch) broadcast — socket clients."""
        with self._cond:
            if offset > self._offset:
                self._offset = offset
            if epoch > self._epoch:
                self._epoch = epoch
            self._cond.notify_all()

    # ------------------------------------------------------------- waits --
    def wait_for_update(self, since_epoch: int, timeout: float,
                        target: Optional[float] = None) -> bool:
        """Block until the epoch moves past ``since_epoch`` (WAITFORCLOCKUPDATE).

        ``timeout`` is in wall seconds.  Returns True if an update arrived,
        False on timeout — the graceful-degradation path of Algorithm 1: by
        then wall time (and hence virtual time) has advanced by ``timeout``.
        ``target`` is the virtual time the caller is riding toward; a local
        condition wake is cheap so this implementation ignores it, but
        remote-clock subclasses (the shm seqlock word) use it to stay
        asleep through epoch bumps that can't matter to the caller.
        """
        if timeout <= 0:
            with self._cond:
                return self._epoch != since_epoch
        deadline = self.wall.time() + timeout
        with self._cond:
            while self._epoch == since_epoch:
                remaining = deadline - self.wall.time()
                if remaining <= 0:
                    return False
                if isinstance(self.wall, ManualWallSource):
                    # Deterministic tests drive wall time manually; a pure
                    # condition-wait keyed on real time would deadlock.
                    # Yield the GIL so the driving thread can advance time.
                    self._cond.release()
                    try:
                        time.sleep(1e-4)
                    finally:
                        self._cond.acquire()
                else:
                    self._cond.wait(timeout=remaining)
            return True
