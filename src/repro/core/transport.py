"""Multi-process transport for the virtual time protocol.

The paper's deployment runs the benchmark runner and the inference engine as
separate OS processes wired to the Timekeeper over ZeroMQ (§5), with a
dedicated I/O thread for serialization/sockets and a background thread for
barrier state.  ZeroMQ is not available offline, so this module implements the
same architecture on stdlib TCP sockets:

* **fan-in** — each client connection gets a reader thread on the server;
  jump requests are applied to the shared :class:`Timekeeper` and acked
  with the pre-resolution epoch.
* **fan-out** — every clock epoch bump (barrier resolutions, deregistration
  fallback bumps, the final bump on close) enqueues one ``(offset, epoch)``
  record; a single broadcast thread serializes it *once* and writes it to
  every connection (constant serialization cost per round, per §4.2).

Framing: 4-byte big-endian length prefix + msgpack body.

Frame ops (fan-in requests carry a ``rid``; the reply echoes it):

====================  ====================================================
``register``          join the barrier set
``deregister``        leave permanently; re-evaluates the barrier, and the
                      epoch is bumped + broadcast even if no round resolves
``park`` ``unpark``   leave/re-join the barrier while staying known to the
                      Timekeeper (idle replica engines — the cluster-scale
                      fast path); parking re-evaluates the barrier so a
                      parked remote replica can never stall a round
``jump``              Algorithm 1 fan-in; ack carries the pre-resolution
                      epoch to wait past
``time``              one-shot observer query
``clock``             fan-out broadcast (no rid): replica clock update
====================  ====================================================

Every successful ack additionally piggybacks the server's current
``(clock_offset, clock_epoch)``, which the client installs on receipt.
Broadcasts and acks are FIFO per connection, but a *cross-channel* message
(the cluster control plane runs on separate sockets) can outrun a clock
broadcast; the piggyback bounds that staleness at one RPC — an actor that
just acked an operation acts on a clock at least as fresh as the server
state the ack observed.

Clients hold a *replica* :class:`VirtualClock` driven by clock-update frames.
Server and clients must share a wall epoch, so both sides default to
:class:`UnixWallSource` (``time.time`` — host-shared; cross-host adds NTP skew
as bounded timestamp error).
"""

from __future__ import annotations

import queue
import socket
import struct
import threading
import uuid
from typing import Dict, Optional

import msgpack

from .client import TransportClosed
from .clock import UnixWallSource, VirtualClock
from .timekeeper import Timekeeper

__all__ = ["TimekeeperServer", "SocketTransport", "TransportClosed",
           "FrameWriter", "pack_frame", "handle_timekeeper_request"]

_LEN = struct.Struct(">I")


def pack_frame(body: bytes) -> bytes:
    """Length-prefix a serialized body into one wire frame."""
    return _LEN.pack(len(body)) + body


class FrameWriter:
    """Per-socket write combiner: one ``sendmsg`` per flush, many frames.

    Senders enqueue ready-to-wire frames under a cheap lock; the first
    sender in becomes the *flusher* and drains everything queued — including
    frames that arrive while it is inside the syscall — with a single
    scatter-gather ``sendmsg`` per drain.  Concurrent senders therefore pay
    one list append instead of one syscall each, which is exactly the
    process-mode hot path (clock piggybacks + completion acks per step).

    Falls back to ``sendall`` on partial writes and on sockets without
    ``sendmsg``.  Raises the underlying ``OSError`` to the flushing sender;
    frames it had drained are lost with the connection (same contract as the
    direct ``sendall`` path this replaces).

    ``send(frame, tag=...)`` marks the frame *coalescable*: if a frame with
    the same tag is still queued (the flusher is stuck inside a syscall on a
    slow socket), the new frame replaces it in place instead of appending.
    Clock broadcasts use this — replica clocks install updates with
    max(offset)/max(epoch), so only the newest queued update carries any
    information, and a burst of N epoch bumps leaves at most one pending
    clock frame per peer no matter how slow the socket drains.
    """

    def __init__(self, sock: socket.socket):
        self._sock = sock
        self._lock = threading.Lock()
        self._queue: list = []
        self._tag_pos: Dict[str, int] = {}
        self._flushing = False
        self.flushes = 0          # syscall batches issued
        self.frames = 0           # frames written (frames > flushes == win)
        self.coalesced = 0        # tagged frames superseded before hitting wire

    def pending(self) -> int:
        """Frames queued but not yet handed to a syscall (tests/metrics)."""
        with self._lock:
            return len(self._queue)

    def send(self, *frames: bytes, tag: Optional[str] = None) -> None:
        with self._lock:
            if tag is not None:
                pos = self._tag_pos.get(tag)
                if pos is not None:
                    self._queue[pos] = frames[0]
                    self.coalesced += len(frames)
                else:
                    self._tag_pos[tag] = len(self._queue)
                    self._queue.extend(frames)
            else:
                self._queue.extend(frames)
            if self._flushing:
                return            # the elected flusher will carry these out
            self._flushing = True
        try:
            while True:
                with self._lock:
                    batch, self._queue = self._queue, []
                    self._tag_pos.clear()
                    if not batch:
                        self._flushing = False
                        return
                self._write_batch(batch)
        except BaseException:
            with self._lock:
                self._flushing = False
            raise

    def _write_batch(self, batch: list) -> None:
        self.flushes += 1
        self.frames += len(batch)
        sendmsg = getattr(self._sock, "sendmsg", None)
        if sendmsg is None:
            self._sock.sendall(b"".join(batch))
            return
        total = sum(len(b) for b in batch)
        sent = sendmsg(batch)
        if sent < total:
            # Partial scatter-gather write (large batch vs. socket buffer):
            # finish the remainder with the reliable path.
            self._sock.sendall(b"".join(batch)[sent:])


def _send_frame(sock: socket.socket, obj: dict) -> None:
    body = msgpack.packb(obj, use_bin_type=True)
    sock.sendall(pack_frame(body))


def _recv_frame(sock: socket.socket) -> Optional[dict]:
    header = _recv_exact(sock, _LEN.size)
    if header is None:
        return None
    (length,) = _LEN.unpack(header)
    body = _recv_exact(sock, length)
    if body is None:
        return None
    return msgpack.unpackb(body, raw=False)


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    buf = b""
    while len(buf) < n:
        try:
            chunk = sock.recv(n - len(buf))
        except OSError:
            return None
        if not chunk:
            return None
        buf += chunk
    return buf


def handle_timekeeper_request(
    tk: Timekeeper, msg: dict, actors_here: set
) -> dict:
    """Apply one fan-in request to the Timekeeper and build the reply dict.

    This is the protocol logic shared by every server front-end — the TCP
    :class:`TimekeeperServer` and the shared-memory server in
    :mod:`repro.core.shm_transport` dispatch through the same function, so
    the wire ops (and their error/piggyback semantics) cannot drift between
    transports.  ``actors_here`` is the caller's per-peer registration set:
    it is mutated on register/deregister so the caller's cleanup path can
    deregister whatever a dead peer left behind.
    """
    op = msg["op"]
    try:
        if op == "jump":
            epoch = tk.request_jump(msg["actor"], msg["target"])
            reply = {"op": "jump_ack", "rid": msg["rid"], "epoch": epoch}
        elif op == "jump_run":
            epoch = tk.request_jump_run(
                msg["actor"],
                msg["targets"],
                unpark=bool(msg.get("unpark")),
                park_after=bool(msg.get("park_after")),
            )
            reply = {"op": "jump_ack", "rid": msg["rid"], "epoch": epoch}
        elif op == "register":
            tk.register_actor(msg["actor"])
            actors_here.add(msg["actor"])
            reply = {"op": "register_ack", "rid": msg["rid"]}
        elif op == "deregister":
            tk.deregister_actor(msg["actor"])
            actors_here.discard(msg["actor"])
            reply = {"op": "deregister_ack", "rid": msg["rid"]}
        elif op == "park":
            tk.park_actor(msg["actor"])
            reply = {"op": "park_ack", "rid": msg["rid"]}
        elif op == "unpark":
            tk.unpark_actor(msg["actor"])
            reply = {"op": "unpark_ack", "rid": msg["rid"]}
        elif op == "time":
            reply = {"op": "time_ack", "rid": msg["rid"]}
        else:
            reply = {"op": "error", "rid": msg.get("rid"),
                     "error": f"unknown op {op!r}"}
    except (KeyError, RuntimeError) as e:
        # Unregistered actor / closed Timekeeper: the *request* fails, the
        # peer (and its other actors) lives on.
        reply = {"op": "error", "rid": msg["rid"], "error": str(e)}
    if reply["op"] != "error":
        # Every ack piggybacks the current clock pair (distinct keys:
        # jump_ack's "epoch" is the *pre-resolution* value the client waits
        # past).  The reply path is FIFO with this peer's broadcasts, but a
        # *cross-channel* message (e.g. a cluster-plane submit racing the
        # fan-out) can outrun them — piggybacking bounds that staleness at
        # one RPC, so an actor acting on an ack always acts on a clock at
        # least as fresh as the state that ack observed.
        reply["clock_offset"] = tk.clock.offset
        reply["clock_epoch"] = tk.clock.epoch
    return reply


class TimekeeperServer:
    """TCP front-end for a :class:`Timekeeper` (the paper's Timekeeper service)."""

    def __init__(
        self,
        timekeeper: Optional[Timekeeper] = None,
        host: str = "127.0.0.1",
        port: int = 0,
        *,
        jitter_cooldown: float = 500e-6,
    ):
        self.timekeeper = timekeeper or Timekeeper(
            VirtualClock(UnixWallSource()), jitter_cooldown=jitter_cooldown
        )
        self._listener = socket.create_server((host, port))
        self.address = self._listener.getsockname()
        self._conns: Dict[int, socket.socket] = {}
        self._writers: Dict[int, FrameWriter] = {}
        self._conn_lock = threading.Lock()
        self._bcast_q: "queue.Queue[Optional[tuple[float, int]]]" = queue.Queue()
        self.timekeeper.add_broadcast_hook(
            lambda off, ep: self._bcast_q.put((off, ep))
        )
        self._stop = threading.Event()
        self._closed = False
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="timekeeper-accept", daemon=True
        )
        self._bcast_thread = threading.Thread(
            target=self._broadcast_loop, name="timekeeper-broadcast", daemon=True
        )
        self._accept_thread.start()
        self._bcast_thread.start()

    # ---------------------------------------------------------- fan-out ---
    def _broadcast_loop(self) -> None:
        # Runs until the None sentinel: close() enqueues the Timekeeper's
        # final epoch bump *before* the sentinel, so remote waiters always
        # see the releasing update before their connection dies.
        while True:
            item = self._bcast_q.get()
            if item is None:
                return
            # Collapse a backlog to the latest queued update: replica clocks
            # install updates with max(offset)/max(epoch), so intermediate
            # records carry no information once a newer one exists — under
            # burst resolution this turns k pending broadcasts into one
            # frame per connection.  The sentinel still terminates us, but
            # only after the final (releasing) update has gone out.
            stop = False
            while True:
                try:
                    nxt = self._bcast_q.get_nowait()
                except queue.Empty:
                    break
                if nxt is None:
                    stop = True
                    break
                item = nxt
            offset, epoch = item
            # Serialize once, write to all (constant cost per round).
            frame = pack_frame(msgpack.packb(
                {"op": "clock", "offset": offset, "epoch": epoch},
                use_bin_type=True,
            ))
            with self._conn_lock:
                writers = list(self._writers.items())
            for cid, writer in writers:
                try:
                    # Tagged: a clock frame still queued behind a slow
                    # socket's flusher is replaced, never stacked — a burst
                    # of epoch bumps leaves <=1 pending frame per peer.
                    writer.send(frame, tag="clock")
                except OSError:
                    self._drop(cid)
            if stop:
                return

    # ----------------------------------------------------------- fan-in ---
    def _accept_loop(self) -> None:
        cid = 0
        while not self._stop.is_set():
            try:
                conn, _ = self._listener.accept()
            except OSError:
                return
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            cid += 1
            with self._conn_lock:
                self._conns[cid] = conn
                self._writers[cid] = FrameWriter(conn)
            threading.Thread(
                target=self._serve_conn,
                args=(cid, conn),
                name=f"timekeeper-conn-{cid}",
                daemon=True,
            ).start()

    def _serve_conn(self, cid: int, conn: socket.socket) -> None:
        # Every actor this connection ever registered (parked ones included:
        # park keeps the actor known, so its death must still deregister it).
        actors_here: set[str] = set()
        tk = self.timekeeper
        with self._conn_lock:
            writer = self._writers.get(cid) or FrameWriter(conn)
        try:
            while True:
                msg = _recv_frame(conn)
                if msg is None:
                    break
                reply = handle_timekeeper_request(tk, msg, actors_here)
                # Reply through the shared per-connection writer so acks
                # coalesce with concurrent clock broadcasts into one
                # sendmsg flush instead of interleaved sendall syscalls.
                writer.send(pack_frame(
                    msgpack.packb(reply, use_bin_type=True)
                ))
        finally:
            # Connection death == actor death: deregister so the barrier is
            # never wedged by a crashed worker (fault tolerance).
            for actor in actors_here:
                tk.deregister_actor(actor)
            self._drop(cid)

    def _drop(self, cid: int) -> None:
        with self._conn_lock:
            conn = self._conns.pop(cid, None)
            self._writers.pop(cid, None)
        if conn is not None:
            try:
                conn.close()
            except OSError:
                pass

    def close(self) -> None:
        """Tear down: final clock broadcast first, then the sockets.

        ``Timekeeper.close`` bumps the epoch and fans it out through the
        broadcast hook, so every remote client — parked actors included —
        receives a releasing clock update *before* its connection is cut;
        nobody rides out a degradation timeout at shutdown.
        """
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        self.timekeeper.close()          # enqueues the final clock update
        self._bcast_q.put(None)          # sentinel AFTER the final update
        self._bcast_thread.join(timeout=5)
        try:
            self._listener.close()
        except OSError:
            pass
        with self._conn_lock:
            for conn in self._conns.values():
                try:
                    conn.close()
                except OSError:
                    pass
            self._conns.clear()
            self._writers.clear()


class SocketTransport:
    """Client-side transport: replica clock + request/reply over one socket.

    Satisfies the :class:`repro.core.client.ActorTransport` protocol —
    including the park/unpark surface — so :class:`TimeJumpClient` (and
    therefore the engine code built on it) is byte-identical over this
    transport and the in-process :class:`~repro.core.client.LocalTransport`.
    Thread-safe: multiple actors in one process may share a transport.

    ``rpc_timeout`` bounds every request/reply round trip: a server that
    stops answering (wedged, dead, partitioned) surfaces as
    :class:`TransportClosed` after that many wall seconds instead of
    blocking the actor forever — the caller still holds a replica clock
    that advances at wall rate, so this is the degradation path of §4.2.1,
    never a correctness loss.
    """

    def __init__(self, address: tuple[str, int], *, rpc_timeout: float = 30.0):
        self._sock = socket.create_connection(address)
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self.rpc_timeout = float(rpc_timeout)
        self.clock = VirtualClock(UnixWallSource())
        self._send_lock = threading.Lock()
        self._replies: Dict[str, "queue.Queue[dict]"] = {}
        self._replies_lock = threading.Lock()
        self._closed = False
        self._reader = threading.Thread(
            target=self._read_loop, name="timekeeper-client-reader", daemon=True
        )
        self._reader.start()

    # ------------------------------------------------------------ plumbing --
    def _read_loop(self) -> None:
        try:
            while True:
                msg = _recv_frame(self._sock)
                if msg is None:
                    break
                if msg["op"] == "clock":
                    # Fan-out path: install the broadcast into the replica.
                    self.clock.apply_update(msg["offset"], msg["epoch"])
                    continue
                rid = msg.get("rid")
                if rid is None:
                    continue
                with self._replies_lock:
                    q = self._replies.get(rid)
                if q is not None:
                    q.put(msg)
        finally:
            # Socket gone (server close / network death): fail every pending
            # RPC immediately and bump the replica clock epoch so local
            # waiters re-check instead of sleeping out their full
            # degradation timeout.  In a finally so no exception path can
            # leave the transport looking alive with a dead reader.
            self._closed = True
            with self._replies_lock:
                pending = list(self._replies.values())
            for q in pending:
                q.put({"op": "closed", "error": "transport closed"})
            self.clock.advance_to(self.clock.now())

    def _rpc(self, msg: dict, timeout: Optional[float] = None) -> dict:
        if self._closed:
            raise TransportClosed("transport closed")
        rid = uuid.uuid4().hex
        msg["rid"] = rid
        q: "queue.Queue[dict]" = queue.Queue(maxsize=1)
        with self._replies_lock:
            self._replies[rid] = q
        try:
            with self._send_lock:
                try:
                    _send_frame(self._sock, msg)
                except OSError as e:
                    raise TransportClosed(f"transport closed: {e}") from None
            try:
                reply = q.get(timeout=timeout if timeout is not None
                              else self.rpc_timeout)
            except queue.Empty:
                raise TransportClosed(
                    f"no reply to {msg['op']!r} within "
                    f"{timeout if timeout is not None else self.rpc_timeout}s"
                ) from None
        finally:
            with self._replies_lock:
                self._replies.pop(rid, None)
        if reply["op"] == "closed":
            raise TransportClosed(reply["error"])
        if reply["op"] == "error":
            raise KeyError(reply["error"])
        if "clock_offset" in reply:
            # Acks piggyback the server clock (see the server's reply path):
            # installing it here means every RPC refreshes the replica, so a
            # caller acting on an ack can never act on a clock staler than
            # the server state that ack observed.
            self.clock.apply_update(reply["clock_offset"],
                                    reply["clock_epoch"])
        return reply

    # -------------------------------------------------- ActorTransport API --
    def register_actor(self, actor_id: str) -> None:
        self._rpc({"op": "register", "actor": actor_id})

    def deregister_actor(self, actor_id: str) -> None:
        self._rpc({"op": "deregister", "actor": actor_id})

    def park_actor(self, actor_id: str) -> None:
        """Leave the barrier but stay known (idle replica fast path)."""
        self._rpc({"op": "park", "actor": actor_id})

    def unpark_actor(self, actor_id: str) -> None:
        self._rpc({"op": "unpark", "actor": actor_id})

    def send_jump_request(self, actor_id: str, t_target: float) -> int:
        return self._rpc({"op": "jump", "actor": actor_id, "target": t_target})[
            "epoch"
        ]

    def send_jump_run(
        self,
        actor_id: str,
        targets,
        *,
        unpark: bool = False,
        park_after: bool = False,
    ) -> int:
        """Batched fan-in: one frame carries a whole run of targets, plus any
        park/unpark transition folded in (saves the separate RPC per step)."""
        msg = {"op": "jump_run", "actor": actor_id,
               "targets": [float(t) for t in targets]}
        if unpark:
            msg["unpark"] = True
        if park_after:
            msg["park_after"] = True
        return self._rpc(msg)["epoch"]

    @property
    def closed(self) -> bool:
        """Liveness probe for the batched (no re-send) client loop."""
        return self._closed

    def observer_time(self) -> float:
        """One-shot observer query (also refreshes the replica)."""
        self._rpc({"op": "time"})
        return self.clock.now()

    def close(self) -> None:
        self._closed = True
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._sock.close()
