"""Multi-process transport for the virtual time protocol.

The paper's deployment runs the benchmark runner and the inference engine as
separate OS processes wired to the Timekeeper over ZeroMQ (§5), with a
dedicated I/O thread for serialization/sockets and a background thread for
barrier state.  ZeroMQ is not available offline, so this module implements the
same architecture on stdlib TCP sockets:

* **fan-in** — each client connection gets a reader thread on the server;
  jump requests are applied to the shared :class:`Timekeeper` and acked
  with the pre-resolution epoch.
* **fan-out** — barrier resolutions enqueue one ``(offset, epoch)`` record;
  a single broadcast thread serializes it *once* and writes it to every
  connection (constant serialization cost per round, per §4.2).

Framing: 4-byte big-endian length prefix + msgpack body.

Clients hold a *replica* :class:`VirtualClock` driven by clock-update frames.
Server and clients must share a wall epoch, so both sides default to
:class:`UnixWallSource` (``time.time`` — host-shared; cross-host adds NTP skew
as bounded timestamp error).
"""

from __future__ import annotations

import queue
import socket
import struct
import threading
import uuid
from typing import Dict, Optional

import msgpack

from .clock import UnixWallSource, VirtualClock
from .timekeeper import Timekeeper

__all__ = ["TimekeeperServer", "SocketTransport"]

_LEN = struct.Struct(">I")


def _send_frame(sock: socket.socket, obj: dict) -> None:
    body = msgpack.packb(obj, use_bin_type=True)
    sock.sendall(_LEN.pack(len(body)) + body)


def _recv_frame(sock: socket.socket) -> Optional[dict]:
    header = _recv_exact(sock, _LEN.size)
    if header is None:
        return None
    (length,) = _LEN.unpack(header)
    body = _recv_exact(sock, length)
    if body is None:
        return None
    return msgpack.unpackb(body, raw=False)


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    buf = b""
    while len(buf) < n:
        try:
            chunk = sock.recv(n - len(buf))
        except OSError:
            return None
        if not chunk:
            return None
        buf += chunk
    return buf


class TimekeeperServer:
    """TCP front-end for a :class:`Timekeeper` (the paper's Timekeeper service)."""

    def __init__(
        self,
        timekeeper: Optional[Timekeeper] = None,
        host: str = "127.0.0.1",
        port: int = 0,
        *,
        jitter_cooldown: float = 500e-6,
    ):
        self.timekeeper = timekeeper or Timekeeper(
            VirtualClock(UnixWallSource()), jitter_cooldown=jitter_cooldown
        )
        self._listener = socket.create_server((host, port))
        self.address = self._listener.getsockname()
        self._conns: Dict[int, socket.socket] = {}
        self._conn_lock = threading.Lock()
        self._bcast_q: "queue.Queue[Optional[tuple[float, int]]]" = queue.Queue()
        self.timekeeper.add_broadcast_hook(
            lambda off, ep: self._bcast_q.put((off, ep))
        )
        self._stop = threading.Event()
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="timekeeper-accept", daemon=True
        )
        self._bcast_thread = threading.Thread(
            target=self._broadcast_loop, name="timekeeper-broadcast", daemon=True
        )
        self._accept_thread.start()
        self._bcast_thread.start()

    # ---------------------------------------------------------- fan-out ---
    def _broadcast_loop(self) -> None:
        while not self._stop.is_set():
            item = self._bcast_q.get()
            if item is None:
                return
            offset, epoch = item
            # Serialize once, write to all (constant cost per round).
            body = msgpack.packb(
                {"op": "clock", "offset": offset, "epoch": epoch},
                use_bin_type=True,
            )
            frame = _LEN.pack(len(body)) + body
            with self._conn_lock:
                conns = list(self._conns.items())
            for cid, conn in conns:
                try:
                    conn.sendall(frame)
                except OSError:
                    self._drop(cid)

    # ----------------------------------------------------------- fan-in ---
    def _accept_loop(self) -> None:
        cid = 0
        while not self._stop.is_set():
            try:
                conn, _ = self._listener.accept()
            except OSError:
                return
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            cid += 1
            with self._conn_lock:
                self._conns[cid] = conn
            threading.Thread(
                target=self._serve_conn,
                args=(cid, conn),
                name=f"timekeeper-conn-{cid}",
                daemon=True,
            ).start()

    def _serve_conn(self, cid: int, conn: socket.socket) -> None:
        actors_here: set[str] = set()
        tk = self.timekeeper
        try:
            while True:
                msg = _recv_frame(conn)
                if msg is None:
                    break
                op = msg["op"]
                if op == "jump":
                    try:
                        epoch = tk.request_jump(msg["actor"], msg["target"])
                        reply = {"op": "jump_ack", "rid": msg["rid"], "epoch": epoch}
                    except KeyError as e:
                        reply = {"op": "error", "rid": msg["rid"], "error": str(e)}
                    _send_frame(conn, reply)
                elif op == "register":
                    tk.register_actor(msg["actor"])
                    actors_here.add(msg["actor"])
                    _send_frame(
                        conn,
                        {
                            "op": "register_ack",
                            "rid": msg["rid"],
                            "offset": tk.clock.offset,
                            "epoch": tk.clock.epoch,
                        },
                    )
                elif op == "deregister":
                    tk.deregister_actor(msg["actor"])
                    actors_here.discard(msg["actor"])
                    _send_frame(conn, {"op": "deregister_ack", "rid": msg["rid"]})
                elif op == "time":
                    _send_frame(
                        conn,
                        {
                            "op": "time_ack",
                            "rid": msg["rid"],
                            "offset": tk.clock.offset,
                            "epoch": tk.clock.epoch,
                        },
                    )
        finally:
            # Connection death == actor death: deregister so the barrier is
            # never wedged by a crashed worker (fault tolerance).
            for actor in actors_here:
                tk.deregister_actor(actor)
            self._drop(cid)

    def _drop(self, cid: int) -> None:
        with self._conn_lock:
            conn = self._conns.pop(cid, None)
        if conn is not None:
            try:
                conn.close()
            except OSError:
                pass

    def close(self) -> None:
        self._stop.set()
        self._bcast_q.put(None)
        try:
            self._listener.close()
        except OSError:
            pass
        with self._conn_lock:
            for conn in self._conns.values():
                try:
                    conn.close()
                except OSError:
                    pass
            self._conns.clear()
        self.timekeeper.close()


class SocketTransport:
    """Client-side transport: replica clock + request/reply over one socket.

    Satisfies the :class:`repro.core.client.ActorTransport` protocol, so
    :class:`TimeJumpClient` works unchanged over it.  Thread-safe: multiple
    actors in one process may share a transport.
    """

    def __init__(self, address: tuple[str, int]):
        self._sock = socket.create_connection(address)
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self.clock = VirtualClock(UnixWallSource())
        self._send_lock = threading.Lock()
        self._replies: Dict[str, "queue.Queue[dict]"] = {}
        self._replies_lock = threading.Lock()
        self._reader = threading.Thread(
            target=self._read_loop, name="timekeeper-client-reader", daemon=True
        )
        self._reader.start()

    # ------------------------------------------------------------ plumbing --
    def _read_loop(self) -> None:
        while True:
            msg = _recv_frame(self._sock)
            if msg is None:
                return
            if msg["op"] == "clock":
                # Fan-out path: install the broadcast into the replica clock.
                self.clock.apply_update(msg["offset"], msg["epoch"])
                continue
            rid = msg.get("rid")
            if rid is None:
                continue
            with self._replies_lock:
                q = self._replies.get(rid)
            if q is not None:
                q.put(msg)

    def _rpc(self, msg: dict, timeout: float = 30.0) -> dict:
        rid = uuid.uuid4().hex
        msg["rid"] = rid
        q: "queue.Queue[dict]" = queue.Queue(maxsize=1)
        with self._replies_lock:
            self._replies[rid] = q
        try:
            with self._send_lock:
                _send_frame(self._sock, msg)
            reply = q.get(timeout=timeout)
        finally:
            with self._replies_lock:
                self._replies.pop(rid, None)
        if reply["op"] == "error":
            raise KeyError(reply["error"])
        return reply

    # -------------------------------------------------- ActorTransport API --
    def register_actor(self, actor_id: str) -> None:
        reply = self._rpc({"op": "register", "actor": actor_id})
        self.clock.apply_update(reply["offset"], reply["epoch"])

    def deregister_actor(self, actor_id: str) -> None:
        self._rpc({"op": "deregister", "actor": actor_id})

    def send_jump_request(self, actor_id: str, t_target: float) -> int:
        return self._rpc({"op": "jump", "actor": actor_id, "target": t_target})[
            "epoch"
        ]

    def observer_time(self) -> float:
        """One-shot observer query (also refreshes the replica)."""
        reply = self._rpc({"op": "time"})
        self.clock.apply_update(reply["offset"], reply["epoch"])
        return self.clock.now()

    def close(self) -> None:
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._sock.close()
