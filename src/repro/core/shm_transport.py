"""Shared-memory transport for the virtual time protocol (zero-syscall clock).

The framed-TCP transport (:mod:`repro.core.transport`) pays a pickle/msgpack +
socket syscall round trip for every clock read that misses the replica cache,
every jump-run submission, and — worst of all — one write *per replica* for
every epoch broadcast.  This module replaces all of that with shared memory,
keeping the protocol logic byte-identical (both servers dispatch through
:func:`repro.core.transport.handle_timekeeper_request`; the client satisfies
the same :class:`~repro.core.client.ActorTransport` surface).

Three pieces:

* **Seqlock clock word** (:class:`ShmClockWord`) — a single 32-byte
  ``(seq, offset, epoch, flags)`` record in its own
  :mod:`multiprocessing.shared_memory` segment.  The Timekeeper's broadcast
  hook performs ONE word write per epoch bump — no per-replica fan-out at
  all — and every child's ``clock.now()`` / epoch watch becomes a lock-free
  read with zero syscalls (``time.time`` is vDSO).  Writes bracket the
  payload with an odd/even sequence counter; readers retry on a torn or
  in-flight read.  Single writer (the parent, under the Timekeeper lock).

* **SPSC rings** (:class:`ShmRing`) — one segment per child carries four
  single-producer/single-consumer byte rings (timekeeper request/reply +
  control-plane command/reply) of length-prefixed frames.  Waiting is
  adaptive: a brief spin (skipped entirely on 1–2 CPU hosts, where a spinner
  starves the only core the producer could run on), then escalating
  ``time.sleep`` naps capped at 1 ms.  Deliberately NO blocking primitives
  are shared between processes: a ``multiprocessing.Event``/``Lock`` whose
  holder is SIGKILLed mid-operation leaves its internal semaphore locked
  forever, deadlocking every later acquire — exactly the crash the fault
  layer injects.  Pure polling shares only bytes, which a dead peer cannot
  poison, and the 1 ms cap bounds idle wake latency far below any slow-step.

* **Endpoints** (:class:`ShmEndpoint`) — segment lifecycle.  The parent
  creates (and ultimately unlinks) every segment; children only ever attach.
  A SIGKILLed child therefore cannot leak names: the parent-side handle
  reclaims the segment after the ledger snapshot, which is the crash
  semantics the fault layer relies on.

Memory ordering: CPython executes the seqlock/ring stores as distinct
bytecodes under the GIL's sequentially-consistent handoff on x86-64 (TSO);
the 8-byte cursors are single aligned stores.  On architectures with weaker
ordering and a free-threaded interpreter this would need real fences — the
rings are parameterized narrowly enough that such a port is local to this
file.

Python 3.10 wart: attaching to an existing segment registers it with the
``resource_tracker``, which would unlink it when the *child* exits and spam
leak warnings.  ``_untrack`` undoes that registration right after attach
(3.13 grew ``track=False`` for exactly this).
"""

from __future__ import annotations

import itertools
import os
import select
import socket as _socket
import struct
import threading
import time
from dataclasses import dataclass
from multiprocessing import shared_memory
from typing import Callable, Optional, Tuple

import msgpack

from .client import TransportClosed
from .clock import UnixWallSource, VirtualClock
from .timekeeper import Timekeeper
from .transport import handle_timekeeper_request

__all__ = [
    "ShmClockWord", "ShmReplicaClock", "ShmRing", "ShmChannel",
    "ShmEndpoint", "ShmEndpointSpec", "ShmTransport", "ShmTimekeeperServer",
]

# A 1-CPU box (common in CI containers) cannot afford busy-waiting: a
# spinning consumer occupies the only core its producer needs.  With real
# parallelism a short spin wins the common sub-millisecond handoff.
_CPUS = os.cpu_count() or 1
_SPINS = 0 if _CPUS <= 2 else 200
_YIELDS = 8           # sched_yield passes before sleeping: on a saturated
                      # host the peer is usually runnable, and donating the
                      # timeslice resolves the wait in one context switch
_PAUSE_MIN = 50e-6
_PAUSE_MAX = 1e-3     # idle loops: passive listeners between bursts
_PAUSE_RPC = 200e-6   # latency-critical waits: RPC replies + epoch watch
                      # sit in the barrier's serial path, so their wake
                      # quantum directly bounds round throughput
_WAIT_QUANTUM = 0.05  # wake-socket safety net: a blocked consumer re-checks
                      # the ring at this period even if every wake byte were
                      # lost — a liveness bound, not a latency budget


class _WakeSock:
    """One-byte doorbell over a connected AF_UNIX stream socket.

    Producers ``kick()`` (non-blocking one-byte send; a full buffer means a
    wake is already pending, which is exactly as good) and consumers
    ``wait()`` in ``select`` — a *blocking* kernel wait, so an idle process
    burns zero CPU between barrier rounds.  Crash safety comes from fd
    semantics rather than shared state: SIGKILL closes the peer's end, the
    waiter's select wakes with EOF, and the channel flips to ``dead`` —
    callers then fall back to the bounded-poll path and its ``peer_alive``
    drain-then-None handling.  Nothing a dead process held can wedge us.
    """

    def __init__(self, sock: "_socket.socket"):
        sock.setblocking(False)
        self._sock = sock
        self.dead = False

    def fileno(self) -> int:
        return self._sock.fileno()

    def kick(self) -> None:
        if self.dead:
            return
        try:
            self._sock.send(b"\0")
        except (BlockingIOError, InterruptedError):
            pass                      # wake already pending
        except OSError:
            self.dead = True

    def drain(self) -> None:
        """Consume pending wake bytes (EOF marks the channel dead)."""
        try:
            while True:
                data = self._sock.recv(4096)
                if not data:
                    self.dead = True
                    return
                if len(data) < 4096:
                    return
        except (BlockingIOError, InterruptedError):
            return
        except OSError:
            self.dead = True

    def wait(self, timeout: float) -> bool:
        """Block until kicked (or EOF) or ``timeout`` seconds; True if woken.

        Uses stateless ``select.select`` so concurrent waiters on the same
        doorbell (an RPC reply wait racing an epoch watch) are merely
        inefficient — one steals the byte, the other times out at the
        quantum and re-checks — never incorrect.
        """
        if self.dead:
            return True
        try:
            ready, _, _ = select.select([self._sock], [], [], max(0.0, timeout))
        except (OSError, ValueError):
            self.dead = True
            return True
        if not ready:
            return False
        self.drain()
        return True

    def close(self) -> None:
        self.dead = True
        try:
            self._sock.close()
        except OSError:
            pass


def _attach_untracked(name: str) -> shared_memory.SharedMemory:
    """Attach to an existing segment WITHOUT registering it for cleanup.

    On 3.10, ``SharedMemory(name=...)`` registers the segment with the
    resource tracker — which children share with the parent — so a child
    attach would (a) let the tracker unlink a segment the parent still
    owns and (b) clobber the parent's own registration, breaking the
    parent's unlink.  Suppressing registration for the attach call is the
    standard workaround (3.13 grew ``track=False`` for exactly this).
    Attaches in this module happen serially at process/endpoint startup,
    so the brief monkeypatch window is single-threaded in practice.
    """
    from multiprocessing import resource_tracker
    original = resource_tracker.register
    resource_tracker.register = lambda *a, **kw: None
    try:
        return shared_memory.SharedMemory(name=name)
    finally:
        resource_tracker.register = original


# ---------------------------------------------------------------------------
# Seqlock clock word
# ---------------------------------------------------------------------------

_CLOCK = struct.Struct("<QdQQ")     # seq, offset, epoch, flags
_U64 = struct.Struct("<Q")
_FLAG_CLOSED = 1


class ShmClockWord:
    """One seqlock-protected ``(offset, epoch)`` record in shared memory.

    Single writer (the Timekeeper owner); any number of lock-free readers.
    ``flags`` bit 0 is the *closed* marker, published on server shutdown so
    replica waiters wake immediately instead of riding out a degradation
    timeout.
    """

    SIZE = _CLOCK.size

    def __init__(self, shm: shared_memory.SharedMemory, *, owner: bool):
        self._shm = shm
        self._owner = owner
        self._released = False
        # (seq, offset, epoch, closed) of the last validated read: clock
        # reads dominate the replica hot loop, and the word changes only at
        # epoch bumps, so a matching seq skips the full unpack + validation.
        # Stored as one tuple so the GIL makes the cache swap atomic.
        self._cache: Tuple[int, float, int, bool] = (-1, 0.0, 0, False)
        if owner:
            _CLOCK.pack_into(shm.buf, 0, 0, 0.0, 0, 0)

    @classmethod
    def create(cls) -> "ShmClockWord":
        return cls(shared_memory.SharedMemory(create=True, size=cls.SIZE),
                   owner=True)

    @classmethod
    def attach(cls, name: str) -> "ShmClockWord":
        return cls(_attach_untracked(name), owner=False)

    @property
    def name(self) -> str:
        return self._shm.name

    def publish(self, offset: float, epoch: int, *, closed: bool = False) -> None:
        """Seqlock write: odd seq while the payload is torn, even when done."""
        buf = self._shm.buf
        (seq,) = _U64.unpack_from(buf, 0)
        (flags,) = _U64.unpack_from(buf, 24)
        if closed:
            flags |= _FLAG_CLOSED
        _U64.pack_into(buf, 0, seq + 1)                       # odd: in flight
        struct.pack_into("<dQQ", buf, 8, float(offset), int(epoch), flags)
        _U64.pack_into(buf, 0, seq + 2)                       # even: stable

    def read(self) -> Tuple[float, int, bool]:
        """Lock-free read, retrying across in-flight writes."""
        buf = self._shm.buf
        (s,) = _U64.unpack_from(buf, 0)
        cache = self._cache
        if s == cache[0]:
            return cache[1], cache[2], cache[3]
        spins = 0
        while True:
            s1, offset, epoch, flags = _CLOCK.unpack_from(buf, 0)
            if not (s1 & 1):
                (s2,) = _U64.unpack_from(buf, 0)
                if s1 == s2:
                    closed = bool(flags & _FLAG_CLOSED)
                    self._cache = (s1, offset, epoch, closed)
                    return offset, epoch, closed
            spins += 1
            if spins > 16:
                time.sleep(0)     # yield: writer may hold the only core

    def close(self) -> None:
        if self._released:
            return
        self._released = True
        try:
            self._shm.close()
        except (OSError, BufferError):
            pass

    def unlink(self) -> None:
        if self._owner:
            try:
                self._shm.unlink()
            except FileNotFoundError:
                pass


class ShmReplicaClock(VirtualClock):
    """Replica-side clock view over the seqlock word.

    Every read (``now``/``offset``/``epoch``/``snapshot``) is a lock-free
    shared-memory load — no socket, no broadcast frame, no syscall.  The
    mutation surface of :class:`VirtualClock` is neutered: the word is
    authoritative, so piggybacked ack updates and the transport-death
    fallback bump have nothing to install.
    """

    def __init__(self, word: ShmClockWord):
        super().__init__(UnixWallSource())
        self._word = word
        self._wake_ring: Optional["ShmRing"] = None

    def bind_wake(self, ring: "ShmRing") -> None:
        """Route epoch watches through ``ring``'s doorbell (the timekeeper
        reply ring): the server's broadcast hook kicks every advertised
        sleeper after publishing the word, so degradation waits block
        instead of polling.  Reply-frame kicks on the same doorbell are
        harmless spurious wakes — the watch re-checks the word and goes
        back to sleep."""
        self._wake_ring = ring

    def now(self) -> float:
        return self.wall.time() + self._word.read()[0]

    @property
    def offset(self) -> float:
        return self._word.read()[0]

    @property
    def epoch(self) -> int:
        return self._word.read()[1]

    @property
    def closed(self) -> bool:
        return self._word.read()[2]

    def snapshot(self) -> Tuple[float, int]:
        offset, epoch, _ = self._word.read()
        return self.wall.time() + offset, epoch

    def advance_to(self, t_min: float) -> float:
        return self.now()

    def apply_update(self, offset: float, epoch: int) -> None:
        pass

    def wait_for_update(self, since_epoch: int, timeout: float,
                        target: Optional[float] = None) -> bool:
        """Adaptive epoch watch: block on the doorbell, poll as fallback.

        Same contract as the Condition-based base class: True iff the epoch
        moved past ``since_epoch`` (a closed word also returns True so
        waiters re-check liveness instead of sleeping out the degradation
        timeout).  ``target`` — the virtual time the caller is jumping to —
        lets the server's broadcast skip this sleeper for rounds that don't
        reach it: per round only the actors whose turn arrived wake, not
        the whole fleet (the shm-only cure for the thundering herd; late
        epoch observation is fine because the caller re-checks ``now``
        against its own target anyway).  The quantum backstop still bounds
        how stale the returned epoch view can get.
        """
        offset, epoch, closed = self._word.read()
        if epoch != since_epoch or closed:
            return True
        if timeout <= 0:
            return False
        deadline = self.wall.time() + timeout
        ring = self._wake_ring
        wake = ring.wake if ring is not None else None
        if wake is not None:
            want = _NEG_INF if target is None else float(target)
            # Event-driven watch: advertise on the reply ring's flag, then
            # re-read the word (publish either preceded the flag — we see
            # it — or followed it — the hook kicks us), then block.
            while not wake.dead:
                remaining = deadline - self.wall.time()
                if remaining <= 0:
                    return False
                ring.advertise(True, want)
                offset, epoch, closed = self._word.read()
                if epoch != since_epoch or closed:
                    ring.advertise(False)
                    return True
                wake.wait(min(_WAIT_QUANTUM, remaining))
                ring.advertise(False)
                offset, epoch, closed = self._word.read()
                if epoch != since_epoch or closed:
                    return True
        spins = _SPINS
        yields = _YIELDS
        pause = _PAUSE_MIN
        while True:
            offset, epoch, closed = self._word.read()
            if epoch != since_epoch or closed:
                return True
            if spins > 0:
                spins -= 1
                continue
            if yields > 0:
                yields -= 1
                time.sleep(0)       # donate the slice to whoever resolves
                continue
            remaining = deadline - self.wall.time()
            if remaining <= 0:
                return False
            time.sleep(min(pause, remaining))
            pause = min(pause * 2, _PAUSE_RPC)


# ---------------------------------------------------------------------------
# SPSC ring
# ---------------------------------------------------------------------------

_RING_HDR = 32        # head u64 | tail u64 | eof u8 | waiting u8 | pad | target f64
_OFF_HEAD = 0
_OFF_TAIL = 8
_OFF_EOF = 16
_OFF_WAIT = 17        # consumer-asleep flag: producers doorbell only when set
_OFF_TARGET = 24      # wake-target virtual time (f64) qualifying the flag:
                      # epoch broadcasts kick the sleeper only once virtual
                      # now has reached it (-inf = kick on any event)
_FRAME_LEN = struct.Struct("<I")
_F64 = struct.Struct("<d")
_NEG_INF = float("-inf")


class ShmRing:
    """Single-producer/single-consumer byte ring of length-prefixed frames.

    Cursors are free-running u64s (``used = tail - head``); payloads wrap
    with a two-part copy, no skip markers.  Either side may raise the ``eof``
    flag: producers set it on graceful close (the TCP-EOF equivalent — the
    consumer drains queued frames first, preserving "completions already on
    the wire" ledger exactness), and a consumer may force it locally after
    the peer is known dead so its own reader unblocks.

    Waiting is event-driven when a :class:`_WakeSock` doorbell is attached
    (``wake``): the consumer advertises sleep via a flag byte in the ring
    header, re-checks the ring to close the lost-wake window, then *blocks*
    in ``select`` — zero CPU while idle, which is what makes the shm path
    cheaper than TCP on saturated hosts, not just lower-latency.  Producers
    pay one non-blocking one-byte send only when the flag is up (syscall
    elision).  Without a doorbell — or after the peer's death closes it —
    waiting degrades to polling with escalating sleeps capped at 1 ms.

    No cross-process locks or events anywhere, so a SIGKILLed peer can
    never leave a blocking primitive wedged: the doorbell is a plain fd the
    kernel closes on crash (waking the select with EOF), and ``peer_alive``
    turns a dead peer — which can never set eof — into a drained-then-None
    stream instead of a hang.
    """

    def __init__(self, shm: shared_memory.SharedMemory, base: int,
                 capacity: int, *, zero: bool = False):
        self._shm = shm
        self._base = base
        self._cap = capacity
        if zero:
            shm.buf[base:base + _RING_HDR] = bytes(_RING_HDR)
        self.frames_in = 0        # frames this side consumed
        self.frames_out = 0       # frames this side produced
        self.wake: Optional[_WakeSock] = None   # doorbell (both roles)

    # -- cursor plumbing ---------------------------------------------------
    def _load(self, off: int) -> int:
        (v,) = _U64.unpack_from(self._shm.buf, self._base + off)
        return v

    def _store(self, off: int, value: int) -> None:
        _U64.pack_into(self._shm.buf, self._base + off, value)

    @property
    def eof(self) -> bool:
        return bool(self._shm.buf[self._base + _OFF_EOF])

    def set_eof(self) -> None:
        """Graceful close marker (producer side) — consumer drains first."""
        try:
            self._shm.buf[self._base + _OFF_EOF] = 1
        except (ValueError, TypeError):
            return            # segment already torn down
        if self.wake is not None:
            self.wake.kick()  # unconditional: teardown must wake a sleeper

    force_eof = set_eof   # consumer-side unblock after peer death: same flag

    def ready(self) -> bool:
        """Cheap non-consuming check: committed data or EOF visible."""
        return (self._load(_OFF_TAIL) != self._load(_OFF_HEAD)) or self.eof

    def advertise(self, on: bool, target: float = _NEG_INF) -> None:
        """Raise/lower the consumer-asleep flag (doorbell elision).

        ``target`` (virtual seconds) qualifies *broadcast* kicks: a sleeper
        waiting out a time jump only cares about the round that carries
        virtual now past its own target, so intermediate epoch bumps leave
        it asleep instead of thundering every replica awake per round.
        Data-frame kicks ignore the target — a frame is always worth a wake.
        The target is written before the flag so a producer that sees the
        flag always reads a current target.
        """
        buf = self._shm.buf
        try:
            if on:
                _F64.pack_into(buf, self._base + _OFF_TARGET, target)
                buf[self._base + _OFF_WAIT] = 1
            else:
                buf[self._base + _OFF_WAIT] = 0
        except (ValueError, TypeError):
            pass

    def _kick(self) -> None:
        """Producer-side doorbell: ring only if the consumer advertised."""
        w = self.wake
        if w is None or w.dead:
            return
        off = self._base + _OFF_WAIT
        try:
            buf = self._shm.buf
            if buf[off]:
                buf[off] = 0      # claim this wake; sleeper re-advertises
                w.kick()
        except (ValueError, TypeError):
            pass

    def kick_if_due(self, now: float) -> None:
        """Broadcast-side doorbell: wake the sleeper only if virtual ``now``
        has reached its advertised target (or it advertised no target)."""
        w = self.wake
        if w is None or w.dead:
            return
        off = self._base + _OFF_WAIT
        try:
            buf = self._shm.buf
            if buf[off]:
                (target,) = _F64.unpack_from(buf, self._base + _OFF_TARGET)
                if now >= target:
                    buf[off] = 0
                    w.kick()
        except (ValueError, TypeError):
            pass

    # -- producer ----------------------------------------------------------
    def send_bytes(self, payload: bytes,
                   peer_alive: Optional[Callable[[], bool]] = None) -> None:
        need = _FRAME_LEN.size + len(payload)
        if need > self._cap - 8:
            raise ValueError(
                f"frame of {need} bytes exceeds ring capacity {self._cap}"
            )
        data = _FRAME_LEN.pack(len(payload)) + payload
        pause = _PAUSE_MIN
        while True:
            if self.eof:
                raise TransportClosed("shm ring closed")
            head = self._load(_OFF_HEAD)
            tail = self._load(_OFF_TAIL)
            if self._cap - (tail - head) >= need:
                break
            # Ring full: the consumer is behind.  Back off; on the slow path
            # make sure it still exists.
            time.sleep(pause)
            pause = min(pause * 2, _PAUSE_MAX)
            if pause >= _PAUSE_MAX and peer_alive is not None \
                    and not peer_alive():
                raise TransportClosed("shm ring peer died (ring full)")
        pos = tail % self._cap
        first = min(len(data), self._cap - pos)
        dst = self._base + _RING_HDR
        buf = self._shm.buf
        buf[dst + pos:dst + pos + first] = data[:first]
        if first < len(data):
            rest = data[first:]
            buf[dst:dst + len(rest)] = rest
        self._store(_OFF_TAIL, tail + len(data))    # commit AFTER the copy
        self.frames_out += 1
        self._kick()              # wake the consumer iff it advertised sleep

    # -- consumer ----------------------------------------------------------
    _EMPTY = object()     # poll(): "no frame yet" (distinct from EOF None)

    def poll(self):
        """Non-blocking receive: a frame, ``None`` at EOF-and-drained, or
        :attr:`ShmRing._EMPTY` when the ring is open but has nothing yet.
        The fan-in multiplexer scans many rings with this."""
        buf = self._shm.buf
        head = self._load(_OFF_HEAD)
        tail = self._load(_OFF_TAIL)
        if tail - head >= _FRAME_LEN.size:
            # tail commits only whole frames, so the full frame is here.
            pos = head % self._cap
            src = self._base + _RING_HDR
            first = min(_FRAME_LEN.size, self._cap - pos)
            raw = bytes(buf[src + pos:src + pos + first])
            if first < _FRAME_LEN.size:
                raw += bytes(buf[src:src + _FRAME_LEN.size - first])
            (length,) = _FRAME_LEN.unpack(raw)
            start = head + _FRAME_LEN.size
            pos = start % self._cap
            first = min(length, self._cap - pos)
            payload = bytes(buf[src + pos:src + pos + first])
            if first < length:
                payload += bytes(buf[src:src + length - first])
            self._store(_OFF_HEAD, start + length)
            self.frames_in += 1
            return payload
        if self.eof:
            return None
        return ShmRing._EMPTY

    def recv_bytes(self, timeout: Optional[float] = None,
                   peer_alive: Optional[Callable[[], bool]] = None,
                   max_pause: float = _PAUSE_MAX) -> Optional[bytes]:
        """Next frame; None once the ring is drained AND (eof | peer dead).

        Raises :class:`TransportClosed` if ``timeout`` (wall seconds)
        elapses with the ring still open and empty.  With a live doorbell
        the wait *blocks* in select (zero CPU); ``max_pause`` only shapes
        the poll fallback: latency-critical callers (RPC replies) pass
        :data:`_PAUSE_RPC`; passive listeners keep the idle default.
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        spins = _SPINS
        yields = _YIELDS
        pause = _PAUSE_MIN
        while True:
            got = self.poll()
            if got is None or got is not ShmRing._EMPTY:
                return got
            if deadline is None:
                remaining = None
            else:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TransportClosed(
                        f"no frame within {timeout}s on shm ring"
                    )
            wake = self.wake
            if wake is not None and not wake.dead:
                # Event-driven wait: advertise sleep, re-check (the producer
                # either sees the flag or we see its commit — no lost wake),
                # then block.  The quantum is a liveness backstop only.
                self.advertise(True)
                got = self.poll()
                if got is None or got is not ShmRing._EMPTY:
                    self.advertise(False)
                    return got
                q = _WAIT_QUANTUM if remaining is None \
                    else min(_WAIT_QUANTUM, remaining)
                wake.wait(q)
                self.advertise(False)
                if wake.dead and peer_alive is not None and not peer_alive():
                    got = self.poll()
                    return None if got is ShmRing._EMPTY else got
                continue
            # Poll fallback: no doorbell (bare rings, non-Linux) or the
            # peer's death closed it.
            if spins > 0:
                spins -= 1
                continue
            if yields > 0:
                yields -= 1
                time.sleep(0)     # donate the slice to the producer
                continue
            time.sleep(pause if remaining is None else min(pause, remaining))
            if pause >= max_pause and peer_alive is not None \
                    and not peer_alive():
                # Dead peer cannot set eof: drain whatever it committed,
                # then surface EOF ourselves.
                got = self.poll()
                return None if got is ShmRing._EMPTY else got
            pause = min(pause * 2, max_pause)


# ---------------------------------------------------------------------------
# Endpoint: segment layout + lifecycle
# ---------------------------------------------------------------------------

_TK_CAP = 64 * 1024
_CTRL_CAP = 512 * 1024


@dataclass(frozen=True)
class ShmEndpointSpec:
    """Picklable child-side descriptor (crosses the spawn boundary).

    Plain strings and ints only — no multiprocessing synchronization
    objects, so nothing here can be wedged by a SIGKILLed holder.  ``wake``
    is the abstract-namespace AF_UNIX address of the parent's doorbell
    listener ('' when unavailable — waits then degrade to bounded polls).
    """
    segment: str
    clock: str
    caps: Tuple[int, int, int, int]        # tk_c2p, tk_p2c, ctrl_p2c, ctrl_c2p
    wake: str = ""


class ShmEndpoint:
    """One child's shared-memory attachment: four rings in one segment.

    Ring roles (direction is child-relative):

    ====================  ==================================================
    ``tk_c2p``            timekeeper requests (child produces)
    ``tk_p2c``            timekeeper replies (parent produces)
    ``ctrl_p2c``          control commands: submit/probe/stats/retire/...
    ``ctrl_c2p``          control replies + unsolicited ``complete`` frames
    ====================  ==================================================

    The parent creates the segment (``create``) and unlinks it (``unlink``)
    once the child is gone — crash reclaim included.  Children ``attach``
    and never own anything.
    """

    def __init__(self, shm: shared_memory.SharedMemory,
                 spec: ShmEndpointSpec, *, owner: bool):
        self._shm = shm
        self.spec = spec
        self._owner = owner
        base = 0
        rings = []
        for cap in spec.caps:
            rings.append(ShmRing(shm, base, cap, zero=owner))
            base += _RING_HDR + cap
        self.tk_c2p, self.tk_p2c, self.ctrl_p2c, self.ctrl_c2p = rings
        self._clock_word: Optional[ShmClockWord] = None
        self._listener: Optional["_socket.socket"] = None
        self._wake_tk: Optional[_WakeSock] = None
        self._wake_ctrl: Optional[_WakeSock] = None

    @classmethod
    def create(cls, clock_name: str, *, tk_cap: int = _TK_CAP,
               ctrl_cap: int = _CTRL_CAP) -> "ShmEndpoint":
        caps = (tk_cap, tk_cap, ctrl_cap, ctrl_cap)
        total = sum(_RING_HDR + c for c in caps)
        shm = shared_memory.SharedMemory(create=True, size=total)
        # Doorbell listener in the Linux abstract socket namespace: the
        # address is a plain string (crosses spawn inside the spec), needs
        # no filesystem cleanup, and is unique because the segment name is.
        listener = None
        wake = ""
        if hasattr(_socket, "AF_UNIX"):
            addr = "\0repro-wake-" + shm.name.strip("/")
            try:
                listener = _socket.socket(_socket.AF_UNIX,
                                          _socket.SOCK_STREAM)
                listener.bind(addr)
                listener.listen(2)
                wake = addr
            except OSError:
                if listener is not None:
                    listener.close()
                listener = None
                wake = ""
        spec = ShmEndpointSpec(shm.name, clock_name, caps, wake)
        ep = cls(shm, spec, owner=True)
        ep._listener = listener
        return ep

    @classmethod
    def attach(cls, spec: ShmEndpointSpec) -> "ShmEndpoint":
        ep = cls(_attach_untracked(spec.segment), spec, owner=False)
        if spec.wake:
            try:
                ep._bind_wakes(_WakeSock(_dial_wake(spec.wake, b"T")),
                               _WakeSock(_dial_wake(spec.wake, b"C")))
            except OSError:
                pass              # no doorbell: polling fallback, still correct
        return ep

    def _bind_wakes(self, tk: _WakeSock, ctrl: _WakeSock) -> None:
        self._wake_tk, self._wake_ctrl = tk, ctrl
        self.tk_c2p.wake = self.tk_p2c.wake = tk
        self.ctrl_p2c.wake = self.ctrl_c2p.wake = ctrl

    def accept_wakes(self, timeout: float = 5.0) -> bool:
        """Parent side: accept the child's two doorbell connections.

        Call once after spawning the child (which dials during ``attach``).
        Returns False — leaving every wait on its polling fallback — if the
        listener was never created or the child failed to dial in time;
        the transport stays correct either way.
        """
        listener, self._listener = self._listener, None
        if listener is None:
            return False
        conns = {}
        try:
            listener.settimeout(timeout)
            for _ in range(2):
                conn, _ = listener.accept()
                conn.settimeout(timeout)
                conns[conn.recv(1)] = conn
            tk, ctrl = conns.get(b"T"), conns.get(b"C")
            if tk is None or ctrl is None:
                raise OSError("doorbell handshake: missing ident")
            self._bind_wakes(_WakeSock(tk), _WakeSock(ctrl))
            return True
        except OSError:
            for conn in conns.values():
                conn.close()
            return False
        finally:
            listener.close()

    def close_wakes(self) -> None:
        if self._listener is not None:
            self._listener.close()
            self._listener = None
        for w in (self._wake_tk, self._wake_ctrl):
            if w is not None:
                w.close()

    # -- child-side views --------------------------------------------------
    def child_channel(self) -> "ShmChannel":
        parent = _parent_alive_probe()
        return ShmChannel(send=self.ctrl_c2p, recv=self.ctrl_p2c,
                          peer_alive=parent)

    def child_transport(self, *, rpc_timeout: float = 30.0) -> "ShmTransport":
        if self._clock_word is None:
            self._clock_word = ShmClockWord.attach(self.spec.clock)
        transport = ShmTransport(
            send=self.tk_c2p, recv=self.tk_p2c,
            word=self._clock_word, rpc_timeout=rpc_timeout,
            peer_alive=_parent_alive_probe())
        # Epoch watches share the reply ring's doorbell: the server's
        # broadcast hook kicks advertised sleepers after each word publish.
        transport.clock.bind_wake(self.tk_p2c)
        return transport

    # -- parent-side views -------------------------------------------------
    def parent_channel(self, peer_alive=None) -> "ShmChannel":
        return ShmChannel(send=self.ctrl_p2c, recv=self.ctrl_c2p,
                          peer_alive=peer_alive)

    def unlink(self) -> None:
        """Reclaim the segment name (owner only; mappings stay valid).

        Also releases the doorbell fds — by reclaim time the child is gone
        and every parent-side reader has drained, so nothing waits on them.
        """
        if self._owner:
            self.close_wakes()
            try:
                self._shm.unlink()
            except FileNotFoundError:
                pass


def _dial_wake(addr: str, ident: bytes) -> "_socket.socket":
    """Child side: connect one doorbell and identify it (``T``/``C``)."""
    sock = _socket.socket(_socket.AF_UNIX, _socket.SOCK_STREAM)
    try:
        sock.settimeout(5.0)
        sock.connect(addr)
        sock.sendall(ident)
    except OSError:
        sock.close()
        raise
    return sock


def _parent_alive_probe() -> Callable[[], bool]:
    import multiprocessing
    parent = multiprocessing.parent_process()
    if parent is None:
        return lambda: True
    return parent.is_alive


# ---------------------------------------------------------------------------
# Control-plane channel (pickle objects, duplex over one ring pair)
# ---------------------------------------------------------------------------


class ShmChannel:
    """Duplex framed-object channel over an SPSC ring pair.

    Drop-in for the process backend's socket control channel: ``send_obj``
    raises :class:`OSError` once closed (matching the socket contract the
    RPC layer maps to handle-death), ``recv_obj`` returns None at EOF.
    """

    def __init__(self, send: ShmRing, recv: ShmRing, *, peer_alive=None):
        import pickle
        self._pickle = pickle
        self._send = send
        self._recv = recv
        self._peer_alive = peer_alive
        self._send_lock = threading.Lock()
        self._closed = False

    def send_obj(self, obj) -> None:
        body = self._pickle.dumps(obj, protocol=self._pickle.HIGHEST_PROTOCOL)
        with self._send_lock:
            if self._closed:
                raise OSError("shm channel closed")
            try:
                self._send.send_bytes(body, peer_alive=self._peer_alive)
            except TransportClosed as e:
                raise OSError(str(e)) from None

    def recv_obj(self, timeout: Optional[float] = None):
        body = self._recv.recv_bytes(timeout=timeout,
                                     peer_alive=self._peer_alive)
        if body is None:
            return None
        return self._pickle.loads(body)

    def mark_peer_dead(self) -> None:
        """Unblock the local reader after a SIGKILL (drains, then EOF)."""
        self._recv.force_eof()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._send.set_eof()      # peer drains queued frames, then sees EOF
        self._recv.force_eof()    # our own reader unblocks likewise


# ---------------------------------------------------------------------------
# Timekeeper plane
# ---------------------------------------------------------------------------


class ShmTransport:
    """Child-side :class:`~repro.core.client.ActorTransport` over shm rings.

    Same wire ops as :class:`SocketTransport`, but the clock is a
    :class:`ShmReplicaClock` — reads never touch the rings — and the
    hot-path jump ops are *one-way*: the seqlock word supplies the epoch a
    jump ack would have carried, so the per-event ack round trip (a full
    child<->server ping-pong) disappears (see :meth:`_send_oneway`).  Real
    RPCs (register/park/...) are serialized under one lock: the ring pair
    is SPSC and the server answers strictly in order, so request/reply
    matching is positional (rids are kept for protocol identity and error
    messages).
    """

    def __init__(self, send: ShmRing, recv: ShmRing, word: ShmClockWord, *,
                 rpc_timeout: float = 30.0, peer_alive=None):
        self._send = send
        self._recv = recv
        self.clock = ShmReplicaClock(word)
        self.rpc_timeout = float(rpc_timeout)
        self._peer_alive = peer_alive
        self._lock = threading.Lock()
        self._rid = itertools.count()
        self._closed = False

    def _rpc(self, msg: dict) -> dict:
        if self.closed:
            raise TransportClosed("transport closed")
        with self._lock:
            rid = next(self._rid)
            msg["rid"] = rid
            try:
                self._send.send_bytes(
                    msgpack.packb(msg, use_bin_type=True),
                    peer_alive=self._peer_alive,
                )
                body = self._recv.recv_bytes(timeout=self.rpc_timeout,
                                             peer_alive=self._peer_alive,
                                             max_pause=_PAUSE_RPC)
            except TransportClosed:
                self._closed = True
                raise
            if body is None:
                self._closed = True
                raise TransportClosed("transport closed (server gone)")
            reply = msgpack.unpackb(body, raw=False)
        if reply.get("rid") != rid:
            self._closed = True
            raise TransportClosed(
                f"shm reply out of order (got rid {reply.get('rid')}, "
                f"expected {rid})"
            )
        if reply["op"] == "error":
            raise KeyError(reply["error"])
        return reply

    # -------------------------------------------------- ActorTransport API --
    def register_actor(self, actor_id: str) -> None:
        self._rpc({"op": "register", "actor": actor_id})

    def deregister_actor(self, actor_id: str) -> None:
        self._rpc({"op": "deregister", "actor": actor_id})

    def park_actor(self, actor_id: str) -> None:
        self._rpc({"op": "park", "actor": actor_id})

    def unpark_actor(self, actor_id: str) -> None:
        self._rpc({"op": "unpark", "actor": actor_id})

    def _send_oneway(self, msg: dict) -> int:
        """Fire-and-forget fan-in frame; returns the pre-send clock epoch.

        A jump ack's only payload is the epoch to wait past, and the
        seqlock word hands us that for free: read it *before* the frame is
        committed — any round that later consumes the target must bump
        past it — then send without waiting for a reply.  That halves the
        context switches on the per-event critical path (the ack round
        trip was one full child<->mux ping-pong per jump).  A staler-than
        -ack epoch only means the waiter may wake one round early and
        re-check its target, which the wait loop does anyway.  Server-side
        errors (a jump for an unregistered actor) cannot surface here; the
        waiter's degradation timeout keeps that bug path slow-but-correct,
        and a *closed* server still raises promptly — the word's closed
        flag wakes the waiter and the client's liveness check fires.
        """
        if self.closed:
            raise TransportClosed("transport closed")
        msg["oneway"] = True
        with self._lock:
            msg["rid"] = next(self._rid)
            epoch = self.clock.epoch
            try:
                self._send.send_bytes(
                    msgpack.packb(msg, use_bin_type=True),
                    peer_alive=self._peer_alive,
                )
            except TransportClosed:
                self._closed = True
                raise
        return epoch

    def send_jump_request(self, actor_id: str, t_target: float) -> int:
        return self._send_oneway(
            {"op": "jump", "actor": actor_id, "target": t_target}
        )

    def send_jump_run(self, actor_id: str, targets, *, unpark: bool = False,
                      park_after: bool = False) -> int:
        msg = {"op": "jump_run", "actor": actor_id,
               "targets": [float(t) for t in targets]}
        if unpark:
            msg["unpark"] = True
        if park_after:
            msg["park_after"] = True
        return self._send_oneway(msg)

    @property
    def closed(self) -> bool:
        return self._closed or self.clock.closed

    def observer_time(self) -> float:
        self._rpc({"op": "time"})
        return self.clock.now()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        # EOF on the request ring: the server's service loop drains, then
        # deregisters whatever this peer left behind (same as TCP conn death).
        self._send.set_eof()
        self._recv.force_eof()


class ShmTimekeeperServer:
    """Shared-memory front-end for a :class:`Timekeeper`.

    Drop-in for :class:`TimekeeperServer` where the cluster builder is
    concerned (``.timekeeper``, ``.close()``), but fan-out is ONE seqlock
    word write per epoch bump — the broadcast hook publishes straight into
    the clock segment, with no per-peer queue, no serialization, and no
    syscalls.  That is the "single latest-epoch write" collapse by
    construction: N bumps leave exactly one visible record.

    Fan-in is ONE multiplexer thread scanning every child's request ring:
    barrier traffic arrives in waves (a round's worth of jump requests
    lands nearly simultaneously), so a single wake services the whole wave
    — no per-child thread wakeups, no per-child scheduling jitter in the
    round's serial path.  Requests dispatch through the same
    :func:`handle_timekeeper_request` as the TCP server; a ring EOF or a
    dead child deregisters its actors so the barrier is never wedged by a
    crashed worker.
    """

    def __init__(self, timekeeper: Optional[Timekeeper] = None, *,
                 jitter_cooldown: float = 500e-6):
        self.timekeeper = timekeeper or Timekeeper(
            VirtualClock(UnixWallSource()), jitter_cooldown=jitter_cooldown
        )
        self.clock_word = ShmClockWord.create()
        self.address = ("shm", self.clock_word.name)
        self._peers: list = []        # [recv, send, peer_alive, actors_here]
        self._peers_lock = threading.Lock()
        self._mux: Optional[threading.Thread] = None
        self._closed = False
        tk = self.timekeeper
        self.clock_word.publish(tk.clock.offset, tk.clock.epoch)
        tk.add_broadcast_hook(self._broadcast)

    def _broadcast(self, offset: float, epoch: int) -> None:
        """Epoch bump: one seqlock word write, then doorbell only sleepers
        whose advertised wake target this round reached — the rest stay
        asleep through rounds that aren't theirs (no thundering herd), and
        a child that advertised nothing costs zero syscalls."""
        self.clock_word.publish(offset, epoch)
        now = time.time() + offset
        for peer in tuple(self._peers):
            peer[1].kick_if_due(now)

    def serve(self, recv: ShmRing, send: ShmRing, *, peer_alive=None,
              name: str = "shm-tk") -> threading.Thread:
        """Register one child's timekeeper ring pair with the multiplexer
        (started lazily on the first peer; ``name`` names that thread)."""
        with self._peers_lock:
            self._peers.append([recv, send, peer_alive, set()])
            if self._mux is None:
                self._mux = threading.Thread(
                    target=self._mux_loop, name=name, daemon=True)
                self._mux.start()
            return self._mux

    def _retire_peer(self, peer) -> None:
        # Peer death == actor death: deregister so the barrier is never
        # wedged by a crashed worker (fault tolerance, as on TCP).
        tk = self.timekeeper
        for actor in peer[3]:
            try:
                tk.deregister_actor(actor)
            except (KeyError, RuntimeError):
                pass
        with self._peers_lock:
            if peer in self._peers:
                self._peers.remove(peer)

    def _mux_loop(self) -> None:
        tk = self.timekeeper
        empty = ShmRing._EMPTY
        poller = select.poll()
        registered: dict = {}          # fd -> _WakeSock
        pause = _PAUSE_MIN
        while not self._closed:
            with self._peers_lock:
                peers = list(self._peers)
            progressed = False
            for peer in peers:
                recv, send, peer_alive, actors_here = peer
                while True:                        # drain the whole wave
                    got = recv.poll()
                    if got is empty:
                        break
                    if got is None:                # graceful EOF
                        self._retire_peer(peer)
                        progressed = True
                        break
                    progressed = True
                    msg = msgpack.unpackb(got, raw=False)
                    reply = handle_timekeeper_request(tk, msg, actors_here)
                    if msg.get("oneway"):
                        # Jump fan-in is fire-and-forget over shm: the child
                        # pre-read its wait epoch from the clock word, so it
                        # is not reading a reply — sending one would desync
                        # the positional request/reply pairing of real RPCs.
                        # (An error reply for a one-way op is dropped; the
                        # waiter degrades to riding wall time, never wrong.)
                        continue
                    try:
                        send.send_bytes(
                            msgpack.packb(reply, use_bin_type=True),
                            peer_alive=peer_alive,
                        )
                    except TransportClosed:
                        self._retire_peer(peer)
                        break
            if progressed or self._closed:
                pause = _PAUSE_MIN
                continue
            # Idle: arm every doorbell, re-scan (closing the lost-wake
            # window), then block until any child kicks.  Children without
            # a live doorbell cap the block at the poll quantum instead.
            blocking = True
            armed = []
            ready = False
            for peer in peers:
                recv, _, peer_alive, _ = peer
                wake = recv.wake
                if wake is not None and not wake.dead:
                    fd = wake.fileno()
                    if fd not in registered:
                        poller.register(fd, select.POLLIN)
                        registered[fd] = wake
                    recv.advertise(True)
                    armed.append(recv)
                else:
                    blocking = False
                if recv.ready():
                    ready = True
                if peer_alive is not None and not peer_alive() \
                        and not recv.ready():
                    self._retire_peer(peer)        # dead AND drained
            if ready:
                for recv in armed:
                    recv.advertise(False)
                continue
            if blocking and armed:
                timeout_ms = int(_WAIT_QUANTUM * 1000)
            else:
                timeout_ms = max(1, int(pause * 1000))
                pause = min(pause * 2, _PAUSE_RPC)
            try:
                events = poller.poll(timeout_ms) if registered else None
            except OSError:
                events = None
            if events is None and not registered:
                time.sleep(timeout_ms / 1000.0)
            for fd, _ev in (events or ()):
                wake = registered.get(fd)
                if wake is None:
                    continue
                wake.drain()
                if wake.dead:
                    try:
                        poller.unregister(fd)
                    except (KeyError, OSError):
                        pass
                    registered.pop(fd, None)
            for recv in armed:
                recv.advertise(False)

    def close(self) -> None:
        """Final clock publish (with the closed flag) first, then teardown."""
        if self._closed:
            return
        self.timekeeper.close()       # final epoch bump -> hook -> word write
        tk = self.timekeeper
        self.clock_word.publish(tk.clock.offset, tk.clock.epoch, closed=True)
        self._closed = True
        with self._peers_lock:
            peers = list(self._peers)
        for recv, send, _, _ in peers:
            recv.force_eof()
            send.set_eof()
        if self._mux is not None:
            self._mux.join(timeout=5)
        self.clock_word.unlink()
        self.clock_word.close()
