"""Actor/Observer client API for the virtual time protocol (Algorithm 1).

An *Actor* performs operations with predictable durations (a GPU worker about
to "execute" a batch, a benchmark dispatcher waiting until the next arrival).
Instead of sleeping, it calls :meth:`TimeJumpClient.time_jump`, which advances
*virtual* time by ``Δt`` while consuming as little *wall* time as the barrier
protocol allows::

    t_target <- GetVirtualTime() + Δt          # compute absolute target once
    while GetVirtualTime() < t_target:
        SendTimeJumpRequest(t_target); WaitForAck()
        t_remaining <- t_target - GetVirtualTime()
        if t_remaining > 0:
            WaitForClockUpdate(timeout=t_remaining)   # degradation timeout

A single call may span several barrier rounds (the Timekeeper advances to the
*minimum* target each round); the loop re-requests the unchanged absolute
target until reached.  The timeout makes the protocol degrade to sleep-based
emulation rather than deadlock or mis-order: after ``t_remaining`` wall
seconds, virtual time has advanced by the same amount (Eq. 1) and the loop
condition releases the caller.

*Observers* never block time; they read :meth:`now` (and may timestamp events
they consume).
"""

from __future__ import annotations

import threading
from typing import Optional, Protocol

from .clock import VirtualClock

__all__ = ["ActorTransport", "TimeJumpClient", "Observer", "LocalTransport"]


class ActorTransport(Protocol):
    """Minimal surface an actor needs: a clock view + the fan-in request path.

    ``clock`` is the authoritative shared clock for the in-process transport
    and a broadcast-driven *replica* clock for the socket transport; the
    :class:`TimeJumpClient` protocol loop is written against this protocol
    only, which is what makes engine code byte-identical across the
    in-process (thread) and socket (process) deployments.
    """

    clock: VirtualClock

    def send_jump_request(self, actor_id: str, t_target: float) -> int:
        """Submit a jump request; returns the epoch to wait past (the ack)."""
        ...

    def register_actor(self, actor_id: str) -> None: ...

    def deregister_actor(self, actor_id: str) -> None: ...


class LocalTransport:
    """In-process transport: direct function calls into the Timekeeper.

    The request path is a method call (fan-in), the update path is the shared
    clock's condition broadcast (fan-out) — the same asymmetry as the paper's
    ZeroMQ deployment, collapsed to zero serialization cost.
    """

    def __init__(self, timekeeper):
        self._tk = timekeeper
        self.clock: VirtualClock = timekeeper.clock

    def send_jump_request(self, actor_id: str, t_target: float) -> int:
        return self._tk.request_jump(actor_id, t_target)

    def register_actor(self, actor_id: str) -> None:
        self._tk.register_actor(actor_id)

    def deregister_actor(self, actor_id: str) -> None:
        self._tk.deregister_actor(actor_id)

    def park_actor(self, actor_id: str) -> None:
        self._tk.park_actor(actor_id)

    def unpark_actor(self, actor_id: str) -> None:
        self._tk.unpark_actor(actor_id)


class TimeJumpClient:
    """Actor-side implementation of TIMEJUMP(Δt) (Algorithm 1)."""

    def __init__(self, transport: ActorTransport, actor_id: str, *, auto_register: bool = True):
        self._transport = transport
        self.actor_id = actor_id
        self._registered = False
        self._parked = False
        if auto_register:
            self.register()

    # ---------------------------------------------------------- lifecycle --
    def register(self) -> None:
        if not self._registered:
            self._transport.register_actor(self.actor_id)
            self._registered = True
        elif self._parked:
            self.unpark()

    def deregister(self) -> None:
        if self._registered:
            self._transport.deregister_actor(self.actor_id)
            self._registered = False
            self._parked = False

    def park(self) -> None:
        """Leave the barrier but stay known to the Timekeeper (idle replica).

        Both built-in transports (:class:`LocalTransport` and the socket
        transport's ``park``/``unpark`` frames) expose the park surface, so
        engine code behaves identically in-process and cross-process.  A
        custom transport without one falls back to full deregistration —
        semantically equivalent, just without the cheap-re-entry
        bookkeeping."""
        if not self._registered or self._parked:
            return
        park = getattr(self._transport, "park_actor", None)
        if park is not None:
            park(self.actor_id)
            self._parked = True
        else:
            self.deregister()

    def unpark(self) -> None:
        if not self._registered:
            self.register()
            return
        if self._parked:
            self._transport.unpark_actor(self.actor_id)
            self._parked = False

    def __enter__(self) -> "TimeJumpClient":
        self.register()
        return self

    def __exit__(self, *exc) -> None:
        self.deregister()

    # ----------------------------------------------------------- protocol --
    def now(self) -> float:
        return self._transport.clock.now()

    def time_jump(self, dt: float) -> float:
        """Advance virtual time by ``dt`` seconds; returns the new virtual time.

        ``dt <= 0`` is a no-op returning the current time (a zero-duration
        operation needs no coordination — wall time already flowed while the
        caller computed).
        """
        clock = self._transport.clock
        if dt <= 0:
            return clock.now()
        t_target = clock.now() + dt  # compute absolute target once (l.1)
        while True:
            now, _ = clock.snapshot()
            if now >= t_target:  # loop guard (l.2)
                return now
            # Fan-in request + ack: the epoch to wait past.  If the barrier
            # resolved inside this call, the epoch has already moved on and
            # wait_for_update returns immediately.
            epoch = self._transport.send_jump_request(self.actor_id, t_target)
            t_remaining = t_target - clock.now()
            if t_remaining > 0:
                # Degradation timeout: worst case we ride wall time to the
                # target (sleep-based emulation) — slow, never incorrect.
                clock.wait_for_update(epoch, timeout=t_remaining)

    def jump_to(self, t_target: float) -> float:
        """Advance virtual time to an absolute target (dispatcher convenience)."""
        return self.time_jump(t_target - self.now())


class Observer:
    """Reactive client: reads virtual time, never blocks its progression."""

    def __init__(self, clock: VirtualClock, name: str = "observer"):
        self._clock = clock
        self.name = name

    def now(self) -> float:
        return self._clock.now()

    def timestamp(self) -> float:
        return self._clock.now()
