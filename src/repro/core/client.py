"""Actor/Observer client API for the virtual time protocol (Algorithm 1).

An *Actor* performs operations with predictable durations (a GPU worker about
to "execute" a batch, a benchmark dispatcher waiting until the next arrival).
Instead of sleeping, it calls :meth:`TimeJumpClient.time_jump`, which advances
*virtual* time by ``Δt`` while consuming as little *wall* time as the barrier
protocol allows::

    t_target <- GetVirtualTime() + Δt          # compute absolute target once
    while GetVirtualTime() < t_target:
        SendTimeJumpRequest(t_target); WaitForAck()
        t_remaining <- t_target - GetVirtualTime()
        if t_remaining > 0:
            WaitForClockUpdate(timeout=t_remaining)   # degradation timeout

A single call may span several barrier rounds (the Timekeeper advances to the
*minimum* target each round); the loop re-requests the unchanged absolute
target until reached.  The timeout makes the protocol degrade to sleep-based
emulation rather than deadlock or mis-order: after ``t_remaining`` wall
seconds, virtual time has advanced by the same amount (Eq. 1) and the loop
condition releases the caller.

**Batched coordination** (the emulation fast path): the Timekeeper keeps each
actor's submitted target queued *across* rounds, so the legacy
re-send-per-wake step above is redundant — the client can submit once and
then only watch the clock.  ``REPRO_CLOCK_BATCHING`` (default on) selects
that path; set it to ``0`` to force the per-wake re-send loop (the two are
trajectory-identical, the toggle exists for A/B benchmarks and bisection).
:meth:`TimeJumpClient.jump_run` goes further and submits a whole run of
pre-committed consecutive targets in one request, letting the Timekeeper
resolve multi-step rounds in one burst.

*Observers* never block time; they read :meth:`now` (and may timestamp events
they consume).
"""

from __future__ import annotations

import os
import threading
from typing import Optional, Protocol, Sequence

from .clock import VirtualClock

__all__ = [
    "ActorTransport",
    "TimeJumpClient",
    "Observer",
    "LocalTransport",
    "TransportClosed",
    "batching_enabled",
]


class TransportClosed(ConnectionError):
    """The transport's far end is gone (server close / peer death).

    Defined here (not in ``repro.core.transport``) so the dependency-free
    in-process stack can raise and catch it without importing the socket
    layer; the socket transport re-exports it for compatibility.
    """


def batching_enabled(default: bool = True) -> bool:
    """Resolve the ``REPRO_CLOCK_BATCHING`` toggle (default: batched on)."""
    raw = os.environ.get("REPRO_CLOCK_BATCHING")
    if raw is None:
        return default
    return raw.strip().lower() not in ("0", "off", "false", "no")


class ActorTransport(Protocol):
    """Minimal surface an actor needs: a clock view + the fan-in request path.

    ``clock`` is the authoritative shared clock for the in-process transport
    and a broadcast-driven *replica* clock for the socket transport; the
    :class:`TimeJumpClient` protocol loop is written against this protocol
    only, which is what makes engine code byte-identical across the
    in-process (thread) and socket (process) deployments.
    """

    clock: VirtualClock

    def send_jump_request(self, actor_id: str, t_target: float) -> int:
        """Submit a jump request; returns the epoch to wait past (the ack)."""
        ...

    def register_actor(self, actor_id: str) -> None: ...

    def deregister_actor(self, actor_id: str) -> None: ...


class LocalTransport:
    """In-process transport: direct function calls into the Timekeeper.

    The request path is a method call (fan-in), the update path is the shared
    clock's condition broadcast (fan-out) — the same asymmetry as the paper's
    ZeroMQ deployment, collapsed to zero serialization cost.
    """

    def __init__(self, timekeeper):
        self._tk = timekeeper
        self.clock: VirtualClock = timekeeper.clock

    def send_jump_request(self, actor_id: str, t_target: float) -> int:
        return self._tk.request_jump(actor_id, t_target)

    def send_jump_run(
        self,
        actor_id: str,
        targets: Sequence[float],
        *,
        unpark: bool = False,
        park_after: bool = False,
    ) -> int:
        return self._tk.request_jump_run(
            actor_id, targets, unpark=unpark, park_after=park_after
        )

    @property
    def closed(self) -> bool:
        return getattr(self._tk, "_closed", False)

    def register_actor(self, actor_id: str) -> None:
        self._tk.register_actor(actor_id)

    def deregister_actor(self, actor_id: str) -> None:
        self._tk.deregister_actor(actor_id)

    def park_actor(self, actor_id: str) -> None:
        self._tk.park_actor(actor_id)

    def unpark_actor(self, actor_id: str) -> None:
        self._tk.unpark_actor(actor_id)


class TimeJumpClient:
    """Actor-side implementation of TIMEJUMP(Δt) (Algorithm 1).

    ``batched=None`` resolves the mode from ``REPRO_CLOCK_BATCHING`` (default
    on).  Batched mode submits each target once and then watches the clock —
    the Timekeeper keeps the target queued across rounds, eliminating the
    per-round re-send wakeup churn of the legacy loop.  Both modes produce
    the identical virtual-time trajectory.
    """

    def __init__(
        self,
        transport: ActorTransport,
        actor_id: str,
        *,
        auto_register: bool = True,
        batched: Optional[bool] = None,
    ):
        self._transport = transport
        self.actor_id = actor_id
        self._registered = False
        self._parked = False
        self._batched = batching_enabled() if batched is None else bool(batched)
        if auto_register:
            self.register()

    # ---------------------------------------------------------- lifecycle --
    def register(self) -> None:
        if not self._registered:
            self._transport.register_actor(self.actor_id)
            self._registered = True
        elif self._parked:
            self.unpark()

    def deregister(self) -> None:
        if self._registered:
            self._transport.deregister_actor(self.actor_id)
            self._registered = False
            self._parked = False

    def park(self) -> None:
        """Leave the barrier but stay known to the Timekeeper (idle replica).

        Both built-in transports (:class:`LocalTransport` and the socket
        transport's ``park``/``unpark`` frames) expose the park surface, so
        engine code behaves identically in-process and cross-process.  A
        custom transport without one falls back to full deregistration —
        semantically equivalent, just without the cheap-re-entry
        bookkeeping."""
        if not self._registered or self._parked:
            return
        park = getattr(self._transport, "park_actor", None)
        if park is not None:
            park(self.actor_id)
            self._parked = True
        else:
            self.deregister()

    def unpark(self) -> None:
        if not self._registered:
            self.register()
            return
        if self._parked:
            self._transport.unpark_actor(self.actor_id)
            self._parked = False

    def __enter__(self) -> "TimeJumpClient":
        self.register()
        return self

    def __exit__(self, *exc) -> None:
        self.deregister()

    # ----------------------------------------------------------- protocol --
    def now(self) -> float:
        return self._transport.clock.now()

    def time_jump(self, dt: float) -> float:
        """Advance virtual time by ``dt`` seconds; returns the new virtual time.

        ``dt <= 0`` is a no-op returning the current time (a zero-duration
        operation needs no coordination — wall time already flowed while the
        caller computed).
        """
        clock = self._transport.clock
        if dt <= 0:
            return clock.now()
        t_target = clock.now() + dt  # compute absolute target once (l.1)
        if self._batched:
            return self._await_batched(t_target, (t_target,), park_after=False)
        while True:
            now, _ = clock.snapshot()
            if now >= t_target:  # loop guard (l.2)
                return now
            # Fan-in request + ack: the epoch to wait past.  If the barrier
            # resolved inside this call, the epoch has already moved on and
            # wait_for_update returns immediately.
            epoch = self._transport.send_jump_request(self.actor_id, t_target)
            t_remaining = t_target - clock.now()
            if t_remaining > 0:
                # Degradation timeout: worst case we ride wall time to the
                # target (sleep-based emulation) — slow, never incorrect.
                clock.wait_for_update(epoch, timeout=t_remaining,
                                      target=t_target)

    def jump_run(
        self, targets: Sequence[float], *, park_after: bool = False
    ) -> float:
        """Pre-commit a *run* of absolute ascending jump targets in ONE
        request; returns the virtual time once the final target is reached.

        The caller promises it makes no decisions between the targets that
        depend on intermediate clock reads (e.g. a replica stepping through a
        decode schedule it already committed to) — that promise is what lets
        the Timekeeper merge multiple barrier rounds into a burst with a
        single collapsed clock advance.  ``park_after=True`` additionally
        folds the end-of-run idle transition in: the Timekeeper parks this
        actor the instant the run is consumed, with no separate park RPC.

        With batching disabled (or a transport without ``send_jump_run``)
        this degrades to the exact sequential single-target protocol — same
        trajectory, one request per target.
        """
        clock = self._transport.clock
        run = sorted(float(t) for t in targets)
        if not run:
            return clock.now()
        send_run = getattr(self._transport, "send_jump_run", None)
        if not self._batched or send_run is None:
            t = clock.now()
            for t_target in run:
                t = self.time_jump(t_target - clock.now())
            if park_after:
                self.park()
            return t
        now = clock.now()
        future = [t for t in run if t > now]
        if not future:
            # Every target already reached (wall flowed past the run): only
            # the park transition remains.
            if park_after:
                self.park()
            return clock.now()
        t = self._await_batched(future[-1], future, park_after=park_after)
        if park_after:
            # The Timekeeper parked us when the run was consumed (or will,
            # the next time our leftover queue drains — see the degradation
            # note in _await_batched); mirror it locally so unpark() knows.
            self._parked = True
        return t

    def _await_batched(
        self, t_target: float, targets: Sequence[float], *, park_after: bool
    ) -> float:
        """Submit once, then watch the clock until ``t_target`` is reached.

        No per-wake re-send: the Timekeeper holds our queued run across
        rounds.  Each wake re-checks liveness instead — the legacy loop's
        re-send was also its implicit health probe (a closed transport or a
        deregistration surfaced as the re-send failing), so the batched path
        must keep raising the same errors or a shutdown mid-jump would ride
        out its full degradation timeout (forever, under a manual wall).
        """
        clock = self._transport.clock
        sent = False
        while True:
            now, epoch = clock.snapshot()
            if now >= t_target:
                return now
            if not sent:
                send_run = getattr(self._transport, "send_jump_run", None)
                if send_run is not None:
                    unpark = self._parked
                    epoch = send_run(
                        self.actor_id,
                        targets,
                        unpark=unpark,
                        park_after=park_after,
                    )
                    if unpark:
                        self._parked = False
                else:
                    epoch = self._transport.send_jump_request(
                        self.actor_id, t_target
                    )
                sent = True
            else:
                if getattr(self._transport, "closed", False):
                    raise TransportClosed(
                        f"transport closed while {self.actor_id!r} awaited "
                        f"t={t_target}"
                    )
                if not self._registered or (self._parked and not park_after):
                    raise KeyError(
                        f"actor {self.actor_id!r} left the barrier mid-jump"
                    )
            t_remaining = t_target - clock.now()
            if t_remaining > 0:
                # Degradation timeout: worst case we ride wall time to the
                # target (sleep-based emulation) — slow, never incorrect.
                # The target lets a remote clock sleep through rounds that
                # don't reach it (see ShmReplicaClock.wait_for_update).
                clock.wait_for_update(epoch, timeout=t_remaining,
                                      target=t_target)

    def jump_to(self, t_target: float) -> float:
        """Advance virtual time to an absolute target (dispatcher convenience)."""
        return self.time_jump(t_target - self.now())


class Observer:
    """Reactive client: reads virtual time, never blocks its progression."""

    def __init__(self, clock: VirtualClock, name: str = "observer"):
        self._clock = clock
        self.name = name

    def now(self) -> float:
        return self._clock.now()

    def timestamp(self) -> float:
        return self._clock.now()
