"""Device Emulation Layer (paper §4.3), adapted from CUDA/LD_PRELOAD to JAX.

The paper intercepts CUDA driver calls so unmodified framework code "believes
it has access to target hardware".  JAX has no interceptable driver API, but
it has something better suited: the trace/compile path is *already* separated
from execution.  This module provides the pieces the serving substrate uses to
run GPU-free:

* :class:`VirtualDeviceContext` — a registry of virtual devices with HBM
  accounting, implementing the paper's **split-state memory model**:

  - *metadata buffers* (small, < 4 MB by default, potentially read by the
    control plane) are backed by real host memory and behave faithfully;
  - *compute buffers* (weights, KV cache) get virtual handles with **no
    physical backing**; any CPU read raises :class:`PhantomReadError` — a
    successful emulation run therefore *proves* the control plane never
    operated on phantom data (the paper's invariant, verbatim).

* :class:`EmulatedCollective` — NCCL-collective-as-barrier (paper:
  "We convert NCCL collectives into barrier synchronization points across
  participating workers, preserving temporal ordering without data
  transfer.").  Participants exchange virtual timestamps; everyone leaves at
  ``max(entry times) + predicted collective duration``.

* :class:`EmulatedChannel` — point-to-point send/recv with virtual
  timestamps, used for pipeline-parallel stage handoff and PD-disaggregation
  KV transfer.  A receiver can never observe a message "before" it was sent
  in virtual time.
"""

from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .hardware import ChipSpec, TPU_V5E

__all__ = [
    "PhantomReadError",
    "VirtualOOMError",
    "Buffer",
    "MetadataBuffer",
    "ComputeBuffer",
    "VirtualDevice",
    "VirtualDeviceContext",
    "EmulatedCollective",
    "EmulatedChannel",
]

METADATA_THRESHOLD_BYTES = 4 * 1024 * 1024  # paper §4.3: 4 MB default


class PhantomReadError(RuntimeError):
    """The control plane attempted to read a compute buffer with no backing.

    Raised as a *fatal* fault rather than returning garbage (paper §4.3) —
    the alternative silently corrupts control decisions.
    """


class VirtualOOMError(RuntimeError):
    """Virtual HBM capacity exceeded — the configuration would OOM on the
    target hardware.  This is a *prediction*, and a feature: capacity planning
    without owning the cluster."""


class Buffer:
    __slots__ = ("nbytes", "device_id", "tag", "freed")

    def __init__(self, nbytes: int, device_id: int, tag: str):
        self.nbytes = int(nbytes)
        self.device_id = device_id
        self.tag = tag
        self.freed = False


class MetadataBuffer(Buffer):
    """Small allocation, really backed by host memory; reads/writes faithful."""

    __slots__ = ("data",)

    def __init__(self, nbytes: int, device_id: int, tag: str):
        super().__init__(nbytes, device_id, tag)
        self.data = np.zeros(nbytes, dtype=np.uint8)

    def write(self, payload: np.ndarray, offset: int = 0) -> None:
        raw = payload.view(np.uint8).reshape(-1)
        self.data[offset : offset + raw.size] = raw

    def read(self, nbytes: Optional[int] = None, offset: int = 0) -> np.ndarray:
        n = self.nbytes - offset if nbytes is None else nbytes
        return self.data[offset : offset + n]


class ComputeBuffer(Buffer):
    """Large allocation with a virtual pointer and no physical backing.

    Writes are accounted no-ops; reads fault.  ``shape``/``dtype`` are kept
    for introspection (the emulated runner hands out matching
    ``jax.ShapeDtypeStruct`` stand-ins).
    """

    __slots__ = ("shape", "dtype", "writes")

    def __init__(self, nbytes, device_id, tag, shape=None, dtype=None):
        super().__init__(nbytes, device_id, tag)
        self.shape = shape
        self.dtype = dtype
        self.writes = 0

    def write(self, *_args, **_kw) -> None:
        self.writes += 1  # accounted no-op

    def read(self, *_args, **_kw):
        raise PhantomReadError(
            f"CPU read of virtual compute buffer {self.tag!r} "
            f"({self.nbytes} B on device {self.device_id}); the control plane "
            "must never consume phantom data — classify this allocation as "
            "metadata if it is legitimately control-plane state."
        )


@dataclass
class VirtualDevice:
    device_id: int
    chip: ChipSpec
    allocated: int = 0
    peak: int = 0
    n_alloc: int = 0
    n_free: int = 0

    def alloc(self, nbytes: int, tag: str) -> None:
        if self.allocated + nbytes > self.chip.hbm_capacity:
            raise VirtualOOMError(
                f"device {self.device_id} ({self.chip.name}): allocating "
                f"{nbytes/1e9:.2f} GB on top of {self.allocated/1e9:.2f} GB "
                f"exceeds HBM capacity {self.chip.hbm_capacity/1e9:.1f} GB "
                f"(tag={tag!r})"
            )
        self.allocated += nbytes
        self.peak = max(self.peak, self.allocated)
        self.n_alloc += 1

    def free(self, nbytes: int) -> None:
        self.allocated -= nbytes
        self.n_free += 1


class VirtualDeviceContext:
    """Presents ``num_devices`` virtual chips to the serving substrate.

    The serving engine's emulated model runner allocates its weights and KV
    cache here instead of on real devices; block tables and batch metadata go
    through the metadata path so scheduler logic is *faithfully executed*,
    never modeled.
    """

    def __init__(
        self,
        num_devices: int,
        chip: ChipSpec = TPU_V5E,
        *,
        metadata_threshold: int = METADATA_THRESHOLD_BYTES,
    ):
        self.chip = chip
        self.metadata_threshold = metadata_threshold
        self.devices = [VirtualDevice(i, chip) for i in range(num_devices)]
        self._lock = threading.Lock()
        self._live: Dict[int, Buffer] = {}
        self._next_ptr = 0x10_0000_0000  # cosmetic virtual address space

    # --------------------------------------------------------------- api --
    def malloc(
        self,
        nbytes: int,
        device_id: int = 0,
        tag: str = "anon",
        *,
        shape=None,
        dtype=None,
        force_metadata: bool = False,
    ) -> Buffer:
        """Split-state allocation: metadata below threshold, virtual above."""
        with self._lock:
            dev = self.devices[device_id]
            dev.alloc(nbytes, tag)
            if force_metadata or nbytes < self.metadata_threshold:
                buf: Buffer = MetadataBuffer(nbytes, device_id, tag)
            else:
                buf = ComputeBuffer(nbytes, device_id, tag, shape=shape, dtype=dtype)
            self._next_ptr += max(256, nbytes)
            self._live[id(buf)] = buf
            return buf

    def free(self, buf: Buffer) -> None:
        with self._lock:
            if buf.freed:
                raise RuntimeError(f"double free of buffer {buf.tag!r}")
            buf.freed = True
            self._live.pop(id(buf), None)
            self.devices[buf.device_id].free(buf.nbytes)

    def memory_report(self) -> dict:
        with self._lock:
            return {
                "chip": self.chip.name,
                "num_devices": len(self.devices),
                "per_device_peak_bytes": [d.peak for d in self.devices],
                "per_device_live_bytes": [d.allocated for d in self.devices],
                "live_buffers": len(self._live),
            }


class EmulatedCollective:
    """A collective as a virtual-time barrier across ``group_size`` workers.

    Entry i arrives with its local virtual time ``t_i``; everyone leaves the
    collective at ``max_i(t_i) + duration``.  The *data* never moves — only
    the causal ordering and the time cost are preserved, exactly the paper's
    NCCL treatment.  Workers then time-jump to the exit timestamp.
    """

    def __init__(self, group_size: int, name: str = "collective"):
        self.group_size = group_size
        self.name = name
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._entries: List[float] = []
        self._generation = 0
        self._exit_time: Optional[float] = None

    def arrive(self, t_virtual: float, duration: float, timeout: float = 60.0,
               before_wait=None, after_wait=None) -> float:
        """Block (wall-clock) until all workers arrive; return exit virtual time.

        ``duration`` is the predicted collective cost; the max over the group
        is used (participants of one collective see one cost, but PP groups
        may pass stage-dependent estimates).

        ``before_wait``/``after_wait`` hooks fire only for ranks that
        actually block (not for the group-completing rank).  Worker actors
        use them to deregister from the Timekeeper while parked in the
        collective — a rank waiting on its peers must not hold the virtual
        clock hostage, while the completing rank stays registered so outside
        actors (e.g. the benchmark dispatcher) cannot race virtual time past
        the collective's exit before the group resumes.
        """
        with self._cond:
            gen = self._generation
            self._entries.append(max(t_virtual + duration, t_virtual))
            if len(self._entries) == self.group_size:
                self._exit_time = max(self._entries)
                self._entries = []
                self._generation += 1
                self._cond.notify_all()
                return self._exit_time
            if before_wait is not None:
                before_wait()
            try:
                while self._generation == gen:
                    if not self._cond.wait(timeout=timeout):
                        raise TimeoutError(
                            f"collective {self.name!r}: straggler barrier timed "
                            f"out ({self.group_size} expected)"
                        )
            finally:
                if after_wait is not None:
                    after_wait()
            assert self._exit_time is not None
            return self._exit_time


@dataclass
class _Message:
    payload: object
    t_sent: float
    nbytes: int


class EmulatedChannel:
    """P2P channel with virtual timestamps (PP stage handoff, KV transfer).

    ``recv`` returns ``(payload, t_visible)`` where ``t_visible`` is the
    virtual time at which the receiver may act on the message:
    ``t_sent + nbytes / bandwidth``.  The receiver is responsible for
    time-jumping to ``t_visible`` if its own clock is behind — this preserves
    the paper's causal dependency ("stage i+1 cannot proceed until stage i
    completes ncclSend") without moving tensor data.
    """

    def __init__(self, bandwidth: float = 50e9, name: str = "channel"):
        self.bandwidth = bandwidth
        self.name = name
        self._q: "deque[_Message]" = deque()
        self._cond = threading.Condition()

    def send(self, payload: object, t_virtual: float, nbytes: int = 0) -> float:
        """Enqueue; returns ``t_visible`` so senders can hand the deadline
        to a mover without a racy recv round-trip."""
        with self._cond:
            self._q.append(_Message(payload, t_virtual, nbytes))
            self._cond.notify_all()
        return t_virtual + (nbytes / self.bandwidth if self.bandwidth > 0
                            else 0.0)

    def recv(self, timeout: float = 60.0) -> Tuple[object, float]:
        with self._cond:
            while not self._q:
                if not self._cond.wait(timeout=timeout):
                    raise TimeoutError(f"channel {self.name!r}: recv timed out")
            msg = self._q.popleft()
        transfer = msg.nbytes / self.bandwidth if self.bandwidth > 0 else 0.0
        return msg.payload, msg.t_sent + transfer

    def poll(self) -> bool:
        with self._cond:
            return bool(self._q)
