"""The Timekeeper: barrier-based virtual time coordination (paper §4.2).

The Timekeeper manages virtual time across connected clients.  Clients are
*Actors* (active drivers with predictable operation durations — GPU workers,
the benchmark dispatcher) or *Observers* (reactive components that only
timestamp events).  Only Actors participate in barrier rounds, which is what
keeps coordination overhead minimal.

Protocol (Algorithm 2)::

    pending <- {}, offset <- 0
    loop:
        (c, t_target) <- ReceiveRequest()
        pending[c] <- t_target
        if |pending| == numActors:          # all actors at the barrier
            t_min <- min(pending.values())  # minimum-target rule => causality
            offset <- max(offset, t_min - t_wall)
            BroadcastClockUpdate(offset)
            pending <- {}

Design constraints honoured (paper §4.2.1):

* **No rollback** — virtual time only moves forward; the minimum-target rule
  guarantees no Actor's clock jumps past an event another Actor still has to
  produce.
* **No event-scheduling control** — the Timekeeper never tells a process what
  to do; it only answers jump requests.  CPU work between jumps consumes
  virtual time at wall rate automatically (Eq. 1).
* **Graceful degradation** — if a barrier never resolves (straggler, lost
  message), clients time out after their remaining *wall* delta, by which
  point virtual time has advanced by the same amount.  Worst case is
  sleep-based emulation: slow, never wrong.

Elasticity: actors may register/deregister between rounds (engine scale-up /
drain).  Deregistration re-evaluates the barrier so a departing actor cannot
wedge the clock.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Set

from .clock import VirtualClock, WallSource

__all__ = ["Timekeeper", "TimekeeperStats"]


@dataclass
class TimekeeperStats:
    """Counters exposed for benchmarks (barrier pressure, acceleration)."""

    rounds: int = 0                 # barrier resolutions
    requests: int = 0               # jump requests received
    virtual_advanced: float = 0.0   # seconds of offset added (time skipped)
    cooldown_waits: int = 0         # jitter cooldowns applied
    registered_peak: int = 0
    parks: int = 0                  # park transitions (idle replicas)
    unparks: int = 0

    def as_dict(self) -> dict:
        return {
            "rounds": self.rounds,
            "requests": self.requests,
            "virtual_advanced_s": self.virtual_advanced,
            "cooldown_waits": self.cooldown_waits,
            "registered_peak": self.registered_peak,
            "parks": self.parks,
            "unparks": self.unparks,
        }


class Timekeeper:
    """Central coordinator for virtual time jumps.

    Thread-safe; with the in-process transport, barrier resolution executes in
    the thread of the last-arriving request (there is no dedicated server
    thread to context-switch through — the fan-in path *is* the caller).  The
    socket transport (``repro.core.transport``) wraps this same object with an
    I/O thread per connection plus a broadcast path, mirroring the paper's
    split between the I/O thread and the barrier thread.

    Parameters
    ----------
    clock:
        Shared :class:`VirtualClock`.  In-process clients read it directly;
        socket clients hold replicas updated by broadcasts.
    jitter_cooldown:
        The bounded-jitter model of §4.2.1: a ``J``-duration wall-clock
        cooldown between consecutive clock advances so Observers never read a
        virtual time "from the future" of an in-flight message.  The paper
        finds J ≈ 500 µs sufficient; tests set 0 for speed.
    """

    def __init__(
        self,
        clock: Optional[VirtualClock] = None,
        *,
        jitter_cooldown: float = 500e-6,
    ):
        self.clock = clock or VirtualClock()
        self.jitter_cooldown = float(jitter_cooldown)
        self._lock = threading.Lock()
        self._actors: Set[str] = set()
        self._parked: Set[str] = set()
        self._pending: Dict[str, float] = {}
        self._last_advance_wall = -float("inf")
        self._broadcast_hooks: list[Callable[[float, int], None]] = []
        self.stats = TimekeeperStats()
        self._closed = False

    # --------------------------------------------------------- lifecycle --
    def register_actor(self, actor_id: str) -> None:
        with self._lock:
            if self._closed:
                raise RuntimeError("Timekeeper is closed")
            self._actors.add(actor_id)
            self._parked.discard(actor_id)
            self.stats.registered_peak = max(
                self.stats.registered_peak, len(self._actors)
            )

    def deregister_actor(self, actor_id: str) -> None:
        """Remove an actor; re-evaluate the barrier so departure never wedges
        the remaining actors (elastic scale-down / clean shutdown)."""
        with self._lock:
            self._actors.discard(actor_id)
            self._parked.discard(actor_id)
            self._pending.pop(actor_id, None)
            rounds_before = self.stats.rounds
            self._maybe_resolve_locked()
            if self.stats.rounds == rounds_before:
                # No round resolved: still bump the clock epoch so a client
                # being deregistered *from another thread* (autoscaler stop,
                # drain teardown) re-checks instead of riding out its
                # degradation timeout — with a manual wall source that
                # timeout would never elapse and the thread would wedge.
                # Fan the bump out to broadcast hooks too: a *remote* client
                # holds a replica clock and only learns of epoch movement
                # through broadcast frames (the in-process condition variable
                # it cannot see).
                self.clock.advance_to(self.clock.now())
                self._fanout_locked()

    # -------------------------------------------------------- park/unpark --
    # Cluster-scale support: N replica engines share one Timekeeper and most
    # of them are idle at any instant.  A *parked* actor stays known (its
    # identity, and its slot in ``registered_peak``, survive) but is excluded
    # from the barrier, so one busy replica plus the dispatcher can advance
    # the single shared offset without waiting on the other N-1.  Park/unpark
    # are the high-frequency path (every engine idle transition), so they
    # must be cheap and never wedge the barrier — parking re-evaluates it
    # exactly like deregistration does.
    def park_actor(self, actor_id: str) -> None:
        with self._lock:
            if actor_id in self._actors:
                self._actors.discard(actor_id)
                self._parked.add(actor_id)
                self._pending.pop(actor_id, None)
                self.stats.parks += 1
                self._maybe_resolve_locked()

    def unpark_actor(self, actor_id: str) -> None:
        with self._lock:
            if self._closed:
                raise RuntimeError("Timekeeper is closed")
            self._parked.discard(actor_id)
            self._actors.add(actor_id)
            self.stats.unparks += 1
            self.stats.registered_peak = max(
                self.stats.registered_peak, len(self._actors)
            )

    def close(self) -> None:
        with self._lock:
            self._closed = True
            self._actors.clear()
            self._parked.clear()
            self._pending.clear()
            # Final epoch bump releases any straggling waiters immediately —
            # broadcast it so *remote* waiters (replica clocks on the socket
            # transport, possibly parked) release too instead of riding out
            # their degradation timeouts.
            self.clock.advance_to(-float("inf"))
            self._fanout_locked()

    @property
    def num_actors(self) -> int:
        with self._lock:
            return len(self._actors)

    @property
    def num_parked(self) -> int:
        with self._lock:
            return len(self._parked)

    def add_broadcast_hook(self, hook: Callable[[float, int], None]) -> None:
        """Fan-out path: called as hook(offset, epoch) after *every* clock
        epoch bump — barrier resolutions, the deregistration fallback bump,
        and the final bump in :meth:`close`.

        The socket transport uses this to push updates to remote replica
        clocks; in-process clients share ``self.clock`` and need no hook.
        Hooks run with the Timekeeper lock held and must not block (the
        socket transport's hook is a queue append).
        """
        with self._lock:
            self._broadcast_hooks.append(hook)

    def _fanout_locked(self) -> None:
        """Push the clock's current (offset, epoch) to every broadcast hook.
        Caller holds ``self._lock``."""
        offset, epoch = self.clock.offset, self.clock.epoch
        for hook in self._broadcast_hooks:
            hook(offset, epoch)

    # ---------------------------------------------------------- protocol --
    def request_jump(self, actor_id: str, t_target: float) -> int:
        """Fan-in path: store the request; resolve the barrier if complete.

        Returns the clock epoch observed *before* any resolution triggered by
        this request — the client waits for the epoch to move past this value
        (this closes the ack/broadcast race in Algorithm 1 lines 3–4: if the
        barrier resolves during this call, the epoch has already moved and the
        client's wait returns immediately).
        """
        with self._lock:
            if actor_id not in self._actors:
                raise KeyError(
                    f"actor {actor_id!r} is not registered with the Timekeeper"
                )
            epoch_before = self.clock.epoch
            self._pending[actor_id] = t_target
            self.stats.requests += 1
            self._maybe_resolve_locked()
            return epoch_before

    # ---------------------------------------------------------- internal --
    def _maybe_resolve_locked(self) -> None:
        """Algorithm 2 lines 5–12.  Caller holds ``self._lock``."""
        if not self._actors:
            return
        if not all(a in self._pending for a in self._actors):
            return

        # Jitter cooldown (§4.2.1 "Handling Message Jitter"): enforce >= J of
        # wall time between consecutive advances so any message produced under
        # the previous offset has been delivered before observers can read a
        # post-jump timestamp.
        if self.jitter_cooldown > 0:
            now_wall = self.clock.wall.time()
            wait = self._last_advance_wall + self.jitter_cooldown - now_wall
            if wait > 0:
                self.stats.cooldown_waits += 1
                # Brief sleep with the lock held: J is ~500 µs and incoming
                # requests would be barrier-blocked behind this round anyway.
                self.clock.wall.sleep(wait)

        t_min = min(self._pending[a] for a in self._actors)
        before = self.clock.offset
        self.clock.advance_to(t_min)  # epoch bump + notify, even if offset flat
        after, epoch = self.clock.offset, self.clock.epoch
        self.stats.virtual_advanced += after - before
        self.stats.rounds += 1
        self._last_advance_wall = self.clock.wall.time()
        self._pending.clear()
        self._fanout_locked()
