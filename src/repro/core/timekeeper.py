"""The Timekeeper: barrier-based virtual time coordination (paper §4.2).

The Timekeeper manages virtual time across connected clients.  Clients are
*Actors* (active drivers with predictable operation durations — GPU workers,
the benchmark dispatcher) or *Observers* (reactive components that only
timestamp events).  Only Actors participate in barrier rounds, which is what
keeps coordination overhead minimal.

Protocol (Algorithm 2)::

    pending <- {}, offset <- 0
    loop:
        (c, t_target) <- ReceiveRequest()
        pending[c] <- t_target
        if |pending| == numActors:          # all actors at the barrier
            t_min <- min(pending.values())  # minimum-target rule => causality
            offset <- max(offset, t_min - t_wall)
            BroadcastClockUpdate(offset)
            pending <- {}

Design constraints honoured (paper §4.2.1):

* **No rollback** — virtual time only moves forward; the minimum-target rule
  guarantees no Actor's clock jumps past an event another Actor still has to
  produce.
* **No event-scheduling control** — the Timekeeper never tells a process what
  to do; it only answers jump requests.  CPU work between jumps consumes
  virtual time at wall rate automatically (Eq. 1).
* **Graceful degradation** — if a barrier never resolves (straggler, lost
  message), clients time out after their remaining *wall* delta, by which
  point virtual time has advanced by the same amount.  Worst case is
  sleep-based emulation: slow, never wrong.

Elasticity: actors may register/deregister between rounds (engine scale-up /
drain).  Deregistration re-evaluates the barrier so a departing actor cannot
wedge the clock.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Set

from .clock import VirtualClock, WallSource

__all__ = ["Timekeeper", "TimekeeperStats"]


@dataclass
class TimekeeperStats:
    """Counters exposed for benchmarks (barrier pressure, acceleration)."""

    rounds: int = 0                 # barrier resolutions (logical rounds)
    requests: int = 0               # jump requests received
    batched_requests: int = 0       # requests that carried a multi-target run
    merged_rounds: int = 0          # rounds resolved inside a burst beyond
                                    # the first (no extra fanout was paid)
    virtual_advanced: float = 0.0   # seconds of offset added (time skipped)
    cooldown_waits: int = 0         # jitter cooldowns applied
    registered_peak: int = 0
    parks: int = 0                  # park transitions (idle replicas)
    unparks: int = 0
    coalesced_parks: int = 0        # park/unpark transitions folded into a
                                    # barrier message instead of their own RPC

    def as_dict(self) -> dict:
        return {
            "rounds": self.rounds,
            "requests": self.requests,
            "batched_requests": self.batched_requests,
            "merged_rounds": self.merged_rounds,
            "virtual_advanced_s": self.virtual_advanced,
            "cooldown_waits": self.cooldown_waits,
            "registered_peak": self.registered_peak,
            "parks": self.parks,
            "unparks": self.unparks,
            "coalesced_parks": self.coalesced_parks,
        }


class Timekeeper:
    """Central coordinator for virtual time jumps.

    Thread-safe; with the in-process transport, barrier resolution executes in
    the thread of the last-arriving request (there is no dedicated server
    thread to context-switch through — the fan-in path *is* the caller).  The
    socket transport (``repro.core.transport``) wraps this same object with an
    I/O thread per connection plus a broadcast path, mirroring the paper's
    split between the I/O thread and the barrier thread.

    Parameters
    ----------
    clock:
        Shared :class:`VirtualClock`.  In-process clients read it directly;
        socket clients hold replicas updated by broadcasts.
    jitter_cooldown:
        The bounded-jitter model of §4.2.1: a ``J``-duration wall-clock
        cooldown between consecutive clock advances so Observers never read a
        virtual time "from the future" of an in-flight message.  The paper
        finds J ≈ 500 µs sufficient; tests set 0 for speed.
    """

    def __init__(
        self,
        clock: Optional[VirtualClock] = None,
        *,
        jitter_cooldown: float = 500e-6,
    ):
        self.clock = clock or VirtualClock()
        self.jitter_cooldown = float(jitter_cooldown)
        self._lock = threading.Lock()
        self._actors: Set[str] = set()
        self._parked: Set[str] = set()
        # Per-actor queues of ascending jump targets.  A queue *persists
        # across rounds* until consumed: targets are popped once the clock
        # reaches them, so an actor whose target lies beyond the current
        # round's minimum stays pending without re-sending (the batched fast
        # path).  A new request replaces the actor's queue wholesale, which
        # keeps the legacy single-target re-send protocol exactly equivalent.
        self._pending: Dict[str, list] = {}
        # Actors to auto-park the moment their queued run is fully consumed
        # (the park transition rides the jump request instead of its own RPC).
        self._park_after: Set[str] = set()
        self._last_advance_wall = -float("inf")
        self._broadcast_hooks: list[Callable[[float, int], None]] = []
        self.stats = TimekeeperStats()
        self._closed = False

    # --------------------------------------------------------- lifecycle --
    def register_actor(self, actor_id: str) -> None:
        with self._lock:
            if self._closed:
                raise RuntimeError("Timekeeper is closed")
            self._actors.add(actor_id)
            self._parked.discard(actor_id)
            self.stats.registered_peak = max(
                self.stats.registered_peak, len(self._actors)
            )

    def deregister_actor(self, actor_id: str) -> None:
        """Remove an actor; re-evaluate the barrier so departure never wedges
        the remaining actors (elastic scale-down / clean shutdown)."""
        with self._lock:
            self._actors.discard(actor_id)
            self._parked.discard(actor_id)
            self._pending.pop(actor_id, None)
            self._park_after.discard(actor_id)
            rounds_before = self.stats.rounds
            self._maybe_resolve_locked()
            if self.stats.rounds == rounds_before:
                # No round resolved: still bump the clock epoch so a client
                # being deregistered *from another thread* (autoscaler stop,
                # drain teardown) re-checks instead of riding out its
                # degradation timeout — with a manual wall source that
                # timeout would never elapse and the thread would wedge.
                # Fan the bump out to broadcast hooks too: a *remote* client
                # holds a replica clock and only learns of epoch movement
                # through broadcast frames (the in-process condition variable
                # it cannot see).
                self.clock.advance_to(self.clock.now())
                self._fanout_locked()

    # -------------------------------------------------------- park/unpark --
    # Cluster-scale support: N replica engines share one Timekeeper and most
    # of them are idle at any instant.  A *parked* actor stays known (its
    # identity, and its slot in ``registered_peak``, survive) but is excluded
    # from the barrier, so one busy replica plus the dispatcher can advance
    # the single shared offset without waiting on the other N-1.  Park/unpark
    # are the high-frequency path (every engine idle transition), so they
    # must be cheap and never wedge the barrier — parking re-evaluates it
    # exactly like deregistration does.
    def park_actor(self, actor_id: str) -> None:
        with self._lock:
            if actor_id in self._actors:
                self._actors.discard(actor_id)
                self._parked.add(actor_id)
                self._pending.pop(actor_id, None)
                self._park_after.discard(actor_id)
                self.stats.parks += 1
                self._maybe_resolve_locked()

    def unpark_actor(self, actor_id: str) -> None:
        with self._lock:
            if self._closed:
                raise RuntimeError("Timekeeper is closed")
            self._parked.discard(actor_id)
            self._actors.add(actor_id)
            self.stats.unparks += 1
            self.stats.registered_peak = max(
                self.stats.registered_peak, len(self._actors)
            )

    def close(self) -> None:
        with self._lock:
            self._closed = True
            self._actors.clear()
            self._parked.clear()
            self._pending.clear()
            self._park_after.clear()
            # Final epoch bump releases any straggling waiters immediately —
            # broadcast it so *remote* waiters (replica clocks on the socket
            # transport, possibly parked) release too instead of riding out
            # their degradation timeouts.
            self.clock.advance_to(-float("inf"))
            self._fanout_locked()

    @property
    def num_actors(self) -> int:
        with self._lock:
            return len(self._actors)

    @property
    def num_parked(self) -> int:
        with self._lock:
            return len(self._parked)

    def add_broadcast_hook(self, hook: Callable[[float, int], None]) -> None:
        """Fan-out path: called as hook(offset, epoch) after *every* clock
        epoch bump — barrier resolutions, the deregistration fallback bump,
        and the final bump in :meth:`close`.

        The socket transport uses this to push updates to remote replica
        clocks; in-process clients share ``self.clock`` and need no hook.
        Hooks run with the Timekeeper lock held and must not block (the
        socket transport's hook is a queue append).
        """
        with self._lock:
            self._broadcast_hooks.append(hook)

    def _fanout_locked(self) -> None:
        """Push the clock's current (offset, epoch) to every broadcast hook.
        Caller holds ``self._lock``."""
        offset, epoch = self.clock.offset, self.clock.epoch
        for hook in self._broadcast_hooks:
            hook(offset, epoch)

    # ---------------------------------------------------------- protocol --
    def request_jump(self, actor_id: str, t_target: float) -> int:
        """Fan-in path: store the request; resolve the barrier if complete.

        Returns the clock epoch observed *before* any resolution triggered by
        this request — the client waits for the epoch to move past this value
        (this closes the ack/broadcast race in Algorithm 1 lines 3–4: if the
        barrier resolves during this call, the epoch has already moved and the
        client's wait returns immediately).
        """
        return self.request_jump_run(actor_id, (t_target,))

    def request_jump_run(
        self,
        actor_id: str,
        targets,
        *,
        unpark: bool = False,
        park_after: bool = False,
    ) -> int:
        """Batched fan-in: submit a *run* of ascending absolute jump targets
        in one request.

        The run replaces any queue the actor already had and persists across
        rounds until consumed — targets are popped as the clock reaches them,
        so the actor never re-sends while the barrier walks through its run.
        When every active actor holds a queued run, the barrier resolves the
        whole overlap as one burst of merged rounds (minimum-target rule per
        merged step, so causality is exactly the single-target protocol's)
        with a single collapsed clock advance and fan-out.

        ``unpark=True`` folds a park-exit into this request (a parked actor
        re-enters the barrier and submits in one message); ``park_after=True``
        folds the opposite transition in: the Timekeeper auto-parks the actor
        the moment its run is fully consumed, saving the separate park RPC an
        idle-bound replica would otherwise issue per step.
        """
        with self._lock:
            if unpark and actor_id in self._parked:
                self._parked.discard(actor_id)
                self._actors.add(actor_id)
                self.stats.unparks += 1
                self.stats.coalesced_parks += 1
                self.stats.registered_peak = max(
                    self.stats.registered_peak, len(self._actors)
                )
            if actor_id not in self._actors:
                raise KeyError(
                    f"actor {actor_id!r} is not registered with the Timekeeper"
                )
            run = sorted(float(t) for t in targets)
            if not run:
                raise ValueError("jump run must contain at least one target")
            epoch_before = self.clock.epoch
            self._pending[actor_id] = run
            self.stats.requests += 1
            if len(run) > 1:
                self.stats.batched_requests += 1
            if park_after:
                self._park_after.add(actor_id)
            else:
                self._park_after.discard(actor_id)
            self._maybe_resolve_locked()
            return epoch_before

    # ---------------------------------------------------------- internal --
    def _pop_reached_locked(self, t_min: float) -> None:
        """Consume every queued target the clock has reached; auto-park
        actors whose ``park_after`` run is now fully consumed.  Caller holds
        ``self._lock``."""
        for a in list(self._actors):
            q = self._pending.get(a)
            if not q:
                continue
            while q and q[0] <= t_min:
                q.pop(0)
            if not q:
                del self._pending[a]
                if a in self._park_after:
                    # The coalesced park transition: fold the idle-replica
                    # park into barrier resolution instead of its own RPC.
                    self._park_after.discard(a)
                    self._actors.discard(a)
                    self._parked.add(a)
                    self.stats.parks += 1
                    self.stats.coalesced_parks += 1

    def _maybe_resolve_locked(self) -> None:
        """Algorithm 2 lines 5–12, burst-generalised.  Caller holds
        ``self._lock``.

        While every active actor has a non-empty target queue, merged rounds
        resolve back-to-back: each takes the minimum head target (causality —
        never past any actor's minimum) and pops what it reached.  A burst
        only runs ahead through targets actors *pre-committed* in a run; it
        stops the moment any actor's queue empties (that actor gets control
        back before time moves further).  The whole burst collapses into ONE
        physical clock advance + fan-out, so a k-step overlap costs one epoch
        bump and one broadcast instead of k.
        """
        if not self._actors:
            return
        if not all(self._pending.get(a) for a in self._actors):
            return

        # Jitter cooldown (§4.2.1 "Handling Message Jitter"): enforce >= J of
        # wall time between consecutive advances so any message produced under
        # the previous offset has been delivered before observers can read a
        # post-jump timestamp.  One cooldown per burst: the burst is a single
        # physical advance.
        if self.jitter_cooldown > 0:
            now_wall = self.clock.wall.time()
            wait = self._last_advance_wall + self.jitter_cooldown - now_wall
            if wait > 0:
                self.stats.cooldown_waits += 1
                # Brief sleep with the lock held: J is ~500 µs and incoming
                # requests would be barrier-blocked behind this round anyway.
                self.clock.wall.sleep(wait)

        merged = 0
        final_t = None
        while self._actors and all(self._pending.get(a) for a in self._actors):
            t_min = min(self._pending[a][0] for a in self._actors)
            self._pop_reached_locked(t_min)
            final_t = t_min
            merged += 1

        before = self.clock.offset
        self.clock.advance_to(final_t)  # epoch bump + notify, even if flat
        after = self.clock.offset
        self.stats.virtual_advanced += after - before
        self.stats.rounds += merged
        self.stats.merged_rounds += merged - 1
        self._last_advance_wall = self.clock.wall.time()
        self._fanout_locked()
