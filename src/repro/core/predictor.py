"""Runtime prediction (paper §4.4).

Revati's emulated workers ask "how long would this batch take on the target
hardware?" and jump virtual time by the answer.  The interface is pluggable;
three predictors are provided:

* :class:`AnalyticalPredictor` — the default, extending Vidur's operator-level
  decomposition with MoE routing, fused/paged attention variants, and ring
  collectives.  Per operator it computes FLOPs and HBM traffic, takes the
  roofline ``max(compute, memory)`` with calibratable efficiency ceilings,
  and adds collective and fixed dispatch overheads.  The same math feeds the
  §Roofline analysis, so predictor and dry-run agree by construction.
* :class:`TablePredictor` — profile-table lookup with bilinear interpolation
  over (prefill tokens, decode tokens, context); built by calibrating against
  real-mode execution (paper's "profiling-based" option).
* :class:`StaticPredictor` — fixed duration per step; used by the paper's
  Fig. 8/9 ablations ("static batch time predictions of varying durations").

All durations are seconds of *virtual* time.
"""

from __future__ import annotations

import bisect
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Protocol, Sequence, Tuple

from .hardware import ChipSpec, TPU_V5E

__all__ = [
    "SeqSpec",
    "BatchSpec",
    "ParallelSpec",
    "StepEstimate",
    "RuntimePredictor",
    "StaticPredictor",
    "TablePredictor",
    "AnalyticalPredictor",
    "collective_time",
]


@dataclass(frozen=True)
class SeqSpec:
    """One sequence's contribution to a step.

    ``new_tokens``  — query tokens processed this step (prefill chunk size,
                      or 1 for decode).
    ``context_len`` — total KV length *after* this step (prompt so far +
                      generated), i.e. what attention reads against.
    ``cached_prefix`` — tokens served from prefix cache (skip compute, still
                      read KV).
    """

    new_tokens: int
    context_len: int
    cached_prefix: int = 0


@dataclass(frozen=True)
class BatchSpec:
    seqs: Tuple[SeqSpec, ...]

    @staticmethod
    def make(seqs: Sequence[SeqSpec]) -> "BatchSpec":
        return BatchSpec(tuple(seqs))

    @property
    def total_new_tokens(self) -> int:
        return sum(s.new_tokens for s in self.seqs)

    @property
    def num_prefill(self) -> int:
        return sum(1 for s in self.seqs if s.new_tokens > 1)

    @property
    def num_decode(self) -> int:
        return sum(1 for s in self.seqs if s.new_tokens == 1)

    @property
    def total_context(self) -> int:
        return sum(s.context_len for s in self.seqs)


@dataclass(frozen=True)
class ParallelSpec:
    tp: int = 1
    pp: int = 1
    ep: int = 1
    dp: int = 1

    @property
    def chips(self) -> int:
        return self.tp * self.pp * max(self.ep // self.tp, 1) * self.dp


@dataclass
class StepEstimate:
    total: float
    compute: float = 0.0
    memory: float = 0.0
    collective: float = 0.0
    overhead: float = 0.0
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0

    def as_dict(self) -> dict:
        return dict(self.__dict__)


class RuntimePredictor(Protocol):
    def predict_step(self, batch: BatchSpec) -> StepEstimate: ...


# --------------------------------------------------------------------------
class StaticPredictor:
    """Fixed step duration (paper Fig. 8/9: 5–40 ms static batch times)."""

    def __init__(self, duration_s: float):
        self.duration_s = float(duration_s)

    def predict_step(self, batch: BatchSpec) -> StepEstimate:
        return StepEstimate(total=self.duration_s, compute=self.duration_s)


# --------------------------------------------------------------------------
class TablePredictor:
    """Profile-table predictor with multilinear interpolation.

    Keyed on (prefill_tokens, decode_seqs, mean_context); built from
    real-mode measurements via :meth:`fit`.  Out-of-range queries clamp to
    the table edge (conservative for tails).
    """

    def __init__(self):
        self._samples: List[Tuple[Tuple[float, float, float], float]] = []

    @staticmethod
    def _key(batch: BatchSpec) -> Tuple[float, float, float]:
        prefill_tokens = sum(s.new_tokens for s in batch.seqs if s.new_tokens > 1)
        decode_seqs = batch.num_decode
        mean_ctx = batch.total_context / max(len(batch.seqs), 1)
        return (float(prefill_tokens), float(decode_seqs), float(mean_ctx))

    def fit(self, observations: Sequence[Tuple[BatchSpec, float]]) -> None:
        for batch, seconds in observations:
            self._samples.append((self._key(batch), float(seconds)))

    def add(self, batch: BatchSpec, seconds: float) -> None:
        self._samples.append((self._key(batch), float(seconds)))

    def predict_step(self, batch: BatchSpec) -> StepEstimate:
        if not self._samples:
            raise RuntimeError("TablePredictor has no samples; call fit() first")
        q = self._key(batch)
        # Inverse-distance weighting over the k nearest samples: robust for
        # the scattered grids produced by real profiling runs.
        scored = sorted(
            self._samples,
            key=lambda kv: sum((a - b) ** 2 for a, b in zip(kv[0], q)),
        )[:4]
        num = den = 0.0
        for key, val in scored:
            d2 = sum((a - b) ** 2 for a, b in zip(key, q))
            w = 1.0 / (d2 + 1e-9)
            num += w * val
            den += w
        t = num / den
        return StepEstimate(total=t, compute=t)


# --------------------------------------------------------------------------
class LinearPredictor:
    """Least-squares step-time model over batch-composition features.

    Vidur's operator-level decomposition is linear in the batch composition
    (projection FLOPs ∝ new tokens, attention reads ∝ context, dispatch is
    constant), so a regression on
    ``[1, prefill_tokens, decode_seqs, total_context]`` recovers the same
    structure directly from profiled steps — and, unlike a lookup table,
    extrapolates to batch shapes the calibration run never saw.
    """

    def __init__(self):
        self._coef = None

    @staticmethod
    def _features(batch: BatchSpec):
        prefill_tokens = sum(s.new_tokens for s in batch.seqs if s.new_tokens > 1)
        return [1.0, float(prefill_tokens), float(batch.num_decode),
                float(batch.total_context)]

    def fit(self, observations: Sequence[Tuple[BatchSpec, float]]) -> None:
        import numpy as np
        X = np.asarray([self._features(b) for b, _ in observations])
        y = np.asarray([t for _, t in observations])

        def solve(Xs, ys):
            try:
                # non-negative LS: every term has a physical cost, and
                # negative coefficients extrapolate pathologically outside
                # the calibrated envelope
                from scipy.optimize import nnls
                coef, _ = nnls(Xs, ys)
                return coef
            except ImportError:  # pragma: no cover
                coef, *_ = np.linalg.lstsq(Xs, ys, rcond=None)
                return coef

        self._coef = solve(X, y)
        # One trimmed refit: profiling on a shared CPU carries OS-scheduler
        # spikes (a preempted step measures several× its true cost); drop
        # points whose residual exceeds 3× the median absolute residual and
        # refit so a handful of spikes cannot bias every prediction.
        if len(y) >= 8:
            resid = np.abs(X @ self._coef - y)
            keep = resid <= 3.0 * max(float(np.median(resid)), 1e-9)
            if keep.sum() >= max(4, len(y) // 2) and keep.sum() < len(y):
                self._coef = solve(X[keep], y[keep])

    def predict_step(self, batch: BatchSpec) -> StepEstimate:
        if self._coef is None:
            raise RuntimeError("LinearPredictor has no fit; call fit() first")
        t = float(sum(c * f for c, f in zip(self._coef, self._features(batch))))
        t = max(t, 1e-6)   # physical floor: a step is never free
        return StepEstimate(total=t, compute=t)


# --------------------------------------------------------------------------
def collective_time(
    nbytes: float,
    group: int,
    chip: ChipSpec,
    kind: str = "all_reduce",
    links: Optional[int] = None,
) -> float:
    """Ring-collective cost model on the ICI torus.

    all_reduce:      2·(n−1)/n · B / bw      (reduce-scatter + all-gather)
    all_gather /
    reduce_scatter:  (n−1)/n · B / bw
    all_to_all:      (n−1)/n · B / bw        (balanced personalized exchange)
    p2p:             B / bw
    """
    if group <= 1 or nbytes <= 0:
        return 0.0
    bw = chip.interconnect_bandwidth * (links or 1) * chip.collective_efficiency
    frac = (group - 1) / group
    factor = {"all_reduce": 2 * frac, "all_gather": frac,
              "reduce_scatter": frac, "all_to_all": frac, "p2p": 1.0}[kind]
    return factor * nbytes / bw


class AnalyticalPredictor:
    """Operator-level analytical model (Vidur-extended) for one engine step.

    Decomposition per transformer block: QKV proj, attention (fused
    flash/paged), output proj, MLP or MoE (router + experts + all-to-all),
    norms; plus embedding/unembedding and TP all-reduces.  Each dense op is
    ``max(flops / (peak·eff_mm), bytes / (bw·eff_hbm))``; memory-bound decode
    and compute-bound prefill both fall out of the same formulas.

    ``overlap_collectives``: when True (beyond-paper optimization, see
    EXPERIMENTS.md §Perf), TP collectives are assumed overlapped with compute
    and only their non-hidden remainder is charged.
    """

    def __init__(
        self,
        model,                      # repro.models.config.ModelConfig
        parallel: ParallelSpec = ParallelSpec(),
        chip: ChipSpec = TPU_V5E,
        *,
        step_overhead_s: float = 50e-6,     # dispatch / host sync per step
        layer_overhead_s: float = 3e-6,     # per-layer launch equivalent
        overlap_collectives: bool = False,
    ):
        self.model = model
        self.parallel = parallel
        self.chip = chip
        self.step_overhead_s = step_overhead_s
        self.layer_overhead_s = layer_overhead_s
        self.overlap_collectives = overlap_collectives

    # ------------------------------------------------------------ helpers --
    def _dense_op(self, flops: float, bytes_: float) -> Tuple[float, float, float]:
        c = flops / (self.chip.peak_flops_bf16 * self.chip.matmul_efficiency)
        m = bytes_ / (self.chip.hbm_bandwidth * self.chip.hbm_efficiency)
        return max(c, m), c, m

    # ------------------------------------------------------------ predict --
    def predict_step(self, batch: BatchSpec) -> StepEstimate:
        cfg = self.model
        par = self.parallel
        chip = self.chip
        B = cfg.dtype_bytes
        tp = par.tp
        T = batch.total_new_tokens
        if T == 0:
            return StepEstimate(total=self.step_overhead_s, overhead=self.step_overhead_s)

        est = StepEstimate(total=0.0)
        time_s = 0.0

        # --- per-kind block costs, multiplied by pattern counts ------------
        kind_counts: Dict[str, int] = {}
        for k in cfg.layer_pattern:
            kind_counts[k] = kind_counts.get(k, 0) + 1

        for kind, count in kind_counts.items():
            t_block, blk = self._block_cost(kind, batch, B, tp)
            time_s += count * t_block
            est.compute += count * blk["c"]
            est.memory += count * blk["m"]
            est.collective += count * blk["coll"]
            est.flops += count * blk["flops"]
            est.hbm_bytes += count * blk["bytes"]
            est.collective_bytes += count * blk["coll_bytes"]

        # --- encoder tower (enc-dec): encoder runs once per prefill -------
        if cfg.is_enc_dec and batch.num_prefill > 0:
            enc_tokens = cfg.encoder.max_source_positions * batch.num_prefill
            enc_batch = BatchSpec.make(
                [SeqSpec(cfg.encoder.max_source_positions,
                         cfg.encoder.max_source_positions)] * batch.num_prefill
            )
            t_block, blk = self._block_cost("attn", enc_batch, B, tp, causal=False)
            time_s += cfg.encoder.num_layers * t_block
            est.compute += cfg.encoder.num_layers * blk["c"]
            est.memory += cfg.encoder.num_layers * blk["m"]
            est.flops += cfg.encoder.num_layers * blk["flops"]
            est.hbm_bytes += cfg.encoder.num_layers * blk["bytes"]

        # --- unembedding (logits) ------------------------------------------
        logit_flops = 2.0 * T * cfg.d_model * cfg.vocab_size / tp
        logit_bytes = (cfg.d_model * cfg.vocab_size * B) / tp + T * cfg.vocab_size * B / tp
        t_op, c, m = self._dense_op(logit_flops, logit_bytes)
        time_s += t_op
        est.compute += c
        est.memory += m
        est.flops += logit_flops
        est.hbm_bytes += logit_bytes

        # --- pipeline parallel: per-stage time + inter-stage p2p -----------
        if par.pp > 1:
            # Serving PP runs one microbatch per step per stage; steady-state
            # step latency is stage time + (pp-1) hops of activation p2p.
            act_bytes = T * cfg.d_model * B
            hop = collective_time(act_bytes, 2, chip, "p2p")
            time_s = time_s / par.pp + (par.pp - 1) * hop
            est.collective += (par.pp - 1) * hop
            est.collective_bytes += (par.pp - 1) * act_bytes

        overhead = self.step_overhead_s + cfg.num_layers * self.layer_overhead_s / max(par.pp, 1)
        est.overhead = overhead
        est.total = time_s + overhead
        return est

    # ----------------------------------------------------------- internals --
    def _block_cost(
        self, kind: str, batch: BatchSpec, B: int, tp: int, *, causal: bool = True
    ) -> Tuple[float, Dict[str, float]]:
        cfg = self.model
        chip = self.chip
        T = batch.total_new_tokens
        flops = 0.0
        bytes_ = 0.0
        coll_bytes = 0.0
        comp_t = mem_t = coll_t = 0.0
        time_s = 0.0

        def add_op(f: float, by: float) -> None:
            nonlocal time_s, comp_t, mem_t, flops, bytes_
            t, c, m = self._dense_op(f, by)
            time_s += t
            comp_t += c
            mem_t += m
            flops += f
            bytes_ += by

        if kind in ("attn", "local_attn"):
            # -- projections (TP-sharded) --
            qkv_w = cfg.d_model * (cfg.q_size + 2 * cfg.kv_size)
            add_op(2.0 * T * qkv_w / tp, (qkv_w * B) / tp + 2 * T * cfg.d_model * B)
            # -- attention (fused flash/paged; window-capped context) --
            window = cfg.sliding_window if (kind == "local_attn" or cfg.sliding_window) else None
            attn_flops = 0.0
            kv_read = 0.0
            for s in batch.seqs:
                ctx = s.context_len if window is None else min(s.context_len, window)
                # causal: mean context over the chunk's query positions
                eff_ctx = ctx - (s.new_tokens - 1) / 2.0 if causal else ctx
                eff_ctx = max(eff_ctx, 1.0)
                attn_flops += 4.0 * s.new_tokens * eff_ctx * cfg.num_heads * cfg.head_dim
                kv_read += ctx * 2 * cfg.kv_size * B
            add_op(attn_flops / tp, kv_read / tp + T * 2 * cfg.kv_size * B / tp)
            # -- output proj --
            out_w = cfg.q_size * cfg.d_model
            add_op(2.0 * T * out_w / tp, out_w * B / tp + T * cfg.d_model * B)
            # -- MLP or MoE --
            n_mats = 3 if cfg.mlp_act == "swiglu" else 2
            if cfg.moe is None:
                mlp_w = n_mats * cfg.d_model * cfg.d_ff
                add_op(2.0 * T * mlp_w / tp, mlp_w * B / tp + 2 * T * cfg.d_model * B)
            else:
                moe = cfg.moe
                ep = max(self.parallel.ep, 1)
                add_op(2.0 * T * cfg.d_model * moe.num_experts,
                       cfg.d_model * moe.num_experts * B)  # router
                expert_w = n_mats * cfg.d_model * moe.d_ff_expert
                expert_tokens = T * moe.top_k
                # Experts sharded EP-ways: weights/ep resident per chip; each
                # chip computes its share of routed tokens.
                add_op(2.0 * expert_tokens * expert_w / max(tp, ep),
                       moe.num_experts * expert_w * B / max(tp, ep)
                       + 2 * expert_tokens * cfg.d_model * B / max(tp, ep))
                if ep > 1:
                    a2a = 2 * expert_tokens * cfg.d_model * B  # dispatch+combine
                    t = collective_time(a2a, ep, chip, "all_to_all")
                    time_s += t
                    coll_t += t
                    coll_bytes += a2a
            # -- TP all-reduces (attn out + mlp out) --
            if tp > 1:
                ar_bytes = 2 * T * cfg.d_model * B
                t = collective_time(ar_bytes, tp, chip, "all_reduce")
                if self.overlap_collectives:
                    t = max(0.0, t - 0.5 * time_s)  # hidden under compute
                time_s += t
                coll_t += t
                coll_bytes += ar_bytes
            # -- cross-attention for enc-dec decoder --
            if cfg.is_enc_dec and causal:
                xw = cfg.d_model * (cfg.q_size + 2 * cfg.kv_size) + cfg.q_size * cfg.d_model
                add_op(2.0 * T * xw / tp, xw * B / tp)
                x_flops = sum(
                    4.0 * s.new_tokens * cfg.encoder.max_source_positions
                    * cfg.num_heads * cfg.head_dim
                    for s in batch.seqs
                )
                x_read = len(batch.seqs) * cfg.encoder.max_source_positions * 2 * cfg.kv_size * B
                add_op(x_flops / tp, x_read / tp)

        elif kind == "ssd":
            ssm = cfg.ssm
            d_in = ssm.d_inner(cfg.d_model)
            nheads = ssm.num_heads(cfg.d_model)
            w_in = cfg.d_model * (2 * d_in + 2 * ssm.state_dim + nheads)
            add_op(2.0 * T * w_in / tp, w_in * B / tp + T * cfg.d_model * B)
            # SSD state update/scan: decode reads+writes the full state.
            state_bytes = nheads * ssm.head_dim * ssm.state_dim * 4
            scan_flops = 0.0
            state_traffic = 0.0
            for s in batch.seqs:
                if s.new_tokens == 1:
                    scan_flops += 2.0 * nheads * ssm.head_dim * ssm.state_dim * 2
                    state_traffic += 2 * state_bytes
                else:
                    L = s.new_tokens
                    c = ssm.chunk_size
                    # intra-chunk quadratic + inter-chunk recurrence
                    scan_flops += 4.0 * L * c * nheads * ssm.head_dim
                    scan_flops += 4.0 * L * nheads * ssm.head_dim * ssm.state_dim
                    state_traffic += 2 * state_bytes * max(L // c, 1)
            add_op(scan_flops / tp, state_traffic / tp)
            w_out = d_in * cfg.d_model
            add_op(2.0 * T * w_out / tp, w_out * B / tp + T * cfg.d_model * B)
            if tp > 1:
                ar_bytes = T * cfg.d_model * B
                t = collective_time(ar_bytes, tp, chip, "all_reduce")
                time_s += t
                coll_bytes += ar_bytes

        elif kind == "rglru":
            rg = cfg.rglru
            w = rg.lru_width
            w_total = 2 * cfg.d_model * w + w * cfg.d_model + 2 * w * w
            add_op(2.0 * T * w_total / tp, w_total * B / tp + 2 * T * cfg.d_model * B)
            # element-wise recurrence: state read/write per token
            state_traffic = sum(2 * w * 4 * s.new_tokens for s in batch.seqs)
            add_op(6.0 * T * w, state_traffic / tp)
            n_mats = 3 if cfg.mlp_act == "swiglu" else 2
            mlp_w = n_mats * cfg.d_model * cfg.d_ff
            add_op(2.0 * T * mlp_w / tp, mlp_w * B / tp + 2 * T * cfg.d_model * B)
            if tp > 1:
                ar_bytes = 2 * T * cfg.d_model * B
                t = collective_time(ar_bytes, tp, chip, "all_reduce")
                time_s += t
                coll_t += t
                coll_bytes += ar_bytes

        else:  # pragma: no cover
            raise ValueError(f"unknown block kind {kind!r}")

        blk = {
            "c": comp_t,
            "m": mem_t,
            "coll": coll_t,
            "flops": flops,
            "bytes": bytes_,
            "coll_bytes": coll_bytes,
        }
        return time_s, blk
