"""Hardware specifications for runtime prediction and roofline analysis.

The emulator presents *virtual devices* of a configurable target platform
(§4.3: "a researcher ... can simply configure REVATI to emulate the desired
hardware").  The same specs drive:

* the analytical runtime predictor (`repro.core.predictor`),
* the roofline terms reported by `benchmarks/roofline.py`:

    compute    = HLO_FLOPs        / (chips × peak_flops)
    memory     = HLO_bytes        / (chips × hbm_bw)
    collective = collective_bytes / (chips × link_bw)

TPU v5e is the primary target (per the assignment); the paper's H100/H200 are
included so the fidelity benchmarks can model the paper's own setup; A100 and
L4 fill out the cheaper tiers a heterogeneous pool autoscales into.

Chips double as the **hardware tiers** of the heterogeneous cluster layer
(``repro.cluster``): each replica carries a tier name, and tier-aware routing
and autoscaling weigh replicas by throughput and ``cost_per_hour``.  Short
tier aliases (``"h100"``, ``"a100"``, ``"l4"`` …) resolve through
:func:`get_chip`:

>>> get_chip("l4").name
'l4'
>>> get_chip("h100") is get_chip("h100-sxm")
True
"""

from __future__ import annotations

from dataclasses import dataclass

__all__ = ["ChipSpec", "TPU_V5E", "H100", "H200", "A100", "L4", "CHIPS",
           "CHIP_ALIASES", "get_chip"]


@dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops_bf16: float      # FLOP/s, dense
    hbm_bandwidth: float        # bytes/s
    hbm_capacity: float         # bytes
    interconnect_bandwidth: float  # bytes/s per link (ICI / NVLink per-dir)
    interconnect_links: int     # links per chip (torus degree / NVLink count)
    # Empirical efficiency ceilings used by the analytical predictor.  These
    # are calibration knobs, not physics: large aligned matmuls reach ~70–85%
    # of peak on both MXU and tensor cores; HBM streams reach ~80–90%.
    matmul_efficiency: float = 0.65
    hbm_efficiency: float = 0.80
    collective_efficiency: float = 0.85
    # Cost model for the heterogeneous-pool sweeps: representative public
    # on-demand $/chip-hour.  A calibration knob like the efficiencies — the
    # benchmarks compare *relative* tier costs, not cloud invoices.
    cost_per_hour: float = 0.0

    @property
    def flops_per_byte(self) -> float:
        """Roofline ridge point (bf16)."""
        return self.peak_flops_bf16 / self.hbm_bandwidth

    @property
    def cost_per_second(self) -> float:
        """$/chip-second (derived from :attr:`cost_per_hour`).

        >>> round(ChipSpec("x", 1, 1, 1, 1, 1, cost_per_hour=3600.0)
        ...       .cost_per_second, 6)
        1.0
        """
        return self.cost_per_hour / 3600.0


TPU_V5E = ChipSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,          # per assignment: 197 TFLOP/s bf16
    hbm_bandwidth=819e9,             # 819 GB/s
    hbm_capacity=16e9,               # 16 GB
    interconnect_bandwidth=50e9,     # ~50 GB/s per ICI link
    interconnect_links=4,            # 2D torus
    cost_per_hour=1.2,
)

H100 = ChipSpec(
    name="h100-sxm",
    peak_flops_bf16=989e12,
    hbm_bandwidth=3.35e12,
    hbm_capacity=80e9,
    interconnect_bandwidth=450e9,    # NVLink4 per direction
    interconnect_links=1,
    cost_per_hour=5.5,
)

H200 = ChipSpec(
    name="h200-sxm",
    peak_flops_bf16=989e12,
    hbm_bandwidth=4.8e12,
    hbm_capacity=141e9,
    interconnect_bandwidth=450e9,
    interconnect_links=1,
    cost_per_hour=6.8,
)

A100 = ChipSpec(
    name="a100-sxm",
    peak_flops_bf16=312e12,
    hbm_bandwidth=2.0e12,
    hbm_capacity=80e9,
    interconnect_bandwidth=300e9,
    interconnect_links=1,
    cost_per_hour=3.0,
)

L4 = ChipSpec(
    name="l4",
    peak_flops_bf16=121e12,          # dense bf16 tensor
    hbm_bandwidth=300e9,             # GDDR6
    hbm_capacity=24e9,
    interconnect_bandwidth=32e9,     # PCIe gen4 x16 (no NVLink)
    interconnect_links=1,
    cost_per_hour=0.8,
)

CHIPS = {c.name: c for c in (TPU_V5E, H100, H200, A100, L4)}

# Short tier names used by the heterogeneous cluster layer (EngineConfig.chip
# and the canonical names keep working everywhere).
CHIP_ALIASES = {
    "h100": "h100-sxm",
    "h200": "h200-sxm",
    "a100": "a100-sxm",
    "v5e": "tpu-v5e",
}


def get_chip(name: str) -> ChipSpec:
    """Resolve a chip/tier name (canonical or alias) to its spec.

    >>> get_chip("a100").cost_per_hour < get_chip("h100").cost_per_hour
    True
    >>> get_chip("warp-drive")
    Traceback (most recent call last):
        ...
    KeyError: "unknown chip 'warp-drive'; known: ['a100', 'a100-sxm', \
'h100', 'h100-sxm', 'h200', 'h200-sxm', 'l4', 'tpu-v5e', 'v5e']"
    """
    key = CHIP_ALIASES.get(name, name)
    try:
        return CHIPS[key]
    except KeyError:
        known = sorted(set(CHIPS) | set(CHIP_ALIASES))
        raise KeyError(f"unknown chip {name!r}; known: {known}") from None
