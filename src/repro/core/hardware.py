"""Hardware specifications for runtime prediction and roofline analysis.

The emulator presents *virtual devices* of a configurable target platform
(§4.3: "a researcher ... can simply configure REVATI to emulate the desired
hardware").  The same specs drive:

* the analytical runtime predictor (`repro.core.predictor`),
* the roofline terms reported by `benchmarks/roofline.py`:

    compute    = HLO_FLOPs        / (chips × peak_flops)
    memory     = HLO_bytes        / (chips × hbm_bw)
    collective = collective_bytes / (chips × link_bw)

TPU v5e is the primary target (per the assignment); the paper's H100/H200 are
included so the fidelity benchmarks can model the paper's own setup.
"""

from __future__ import annotations

from dataclasses import dataclass

__all__ = ["ChipSpec", "TPU_V5E", "H100", "H200", "A100", "CHIPS", "get_chip"]


@dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops_bf16: float      # FLOP/s, dense
    hbm_bandwidth: float        # bytes/s
    hbm_capacity: float         # bytes
    interconnect_bandwidth: float  # bytes/s per link (ICI / NVLink per-dir)
    interconnect_links: int     # links per chip (torus degree / NVLink count)
    # Empirical efficiency ceilings used by the analytical predictor.  These
    # are calibration knobs, not physics: large aligned matmuls reach ~70–85%
    # of peak on both MXU and tensor cores; HBM streams reach ~80–90%.
    matmul_efficiency: float = 0.65
    hbm_efficiency: float = 0.80
    collective_efficiency: float = 0.85

    @property
    def flops_per_byte(self) -> float:
        """Roofline ridge point (bf16)."""
        return self.peak_flops_bf16 / self.hbm_bandwidth


TPU_V5E = ChipSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,          # per assignment: 197 TFLOP/s bf16
    hbm_bandwidth=819e9,             # 819 GB/s
    hbm_capacity=16e9,               # 16 GB
    interconnect_bandwidth=50e9,     # ~50 GB/s per ICI link
    interconnect_links=4,            # 2D torus
)

H100 = ChipSpec(
    name="h100-sxm",
    peak_flops_bf16=989e12,
    hbm_bandwidth=3.35e12,
    hbm_capacity=80e9,
    interconnect_bandwidth=450e9,    # NVLink4 per direction
    interconnect_links=1,
)

H200 = ChipSpec(
    name="h200-sxm",
    peak_flops_bf16=989e12,
    hbm_bandwidth=4.8e12,
    hbm_capacity=141e9,
    interconnect_bandwidth=450e9,
    interconnect_links=1,
)

A100 = ChipSpec(
    name="a100-sxm",
    peak_flops_bf16=312e12,
    hbm_bandwidth=2.0e12,
    hbm_capacity=80e9,
    interconnect_bandwidth=300e9,
    interconnect_links=1,
)

CHIPS = {c.name: c for c in (TPU_V5E, H100, H200, A100)}


def get_chip(name: str) -> ChipSpec:
    try:
        return CHIPS[name]
    except KeyError:
        raise KeyError(f"unknown chip {name!r}; known: {sorted(CHIPS)}") from None
