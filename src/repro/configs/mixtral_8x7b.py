"""Mixtral-8x7B [moe] — 8 experts top-2, sliding-window attention
[arXiv:2401.04088; hf].

32L, d_model=4096, 32H (GQA kv=8), d_ff=14336 (per expert), vocab=32000,
MoE 8e top-2, SWA window 4096.  SWA bounds per-step KV reads, so the
long_500k decode cell RUNS for this arch (see DESIGN.md).
"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="mixtral_8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    mlp_act="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    sliding_window=4096,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=14336),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        arch_id="mixtral_8x7b_reduced",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, sliding_window=64,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128),
        layer_pattern=None,
    )
