"""Granite-8B-Code [dense] — llama-architecture code model [arXiv:2405.04324; hf].

36L, d_model=4096, 32 heads (GQA kv=8), d_ff=14336, vocab=49152.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite_8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=49152,
    mlp_act="swiglu",
    norm="rmsnorm",
    rope_theta=10_000_000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        arch_id="granite_8b_reduced",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, layer_pattern=None,
    )
