"""OLMo-1B [dense] — non-parametric LayerNorm [arXiv:2402.00838; hf].

16L, d_model=2048, 16 heads (kv=16, i.e. MHA), d_ff=8192, vocab=50304.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="olmo_1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    mlp_act="swiglu",
    norm="nonparametric_ln",
    rope_theta=10_000.0,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        arch_id="olmo_1b_reduced",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512, layer_pattern=None,
    )
