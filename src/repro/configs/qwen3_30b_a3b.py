"""Qwen3-30B-A3B — paper evaluation model (§6.1), sparse MoE, EP degree 2."""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="qwen3_30b_a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=768,
    vocab_size=151936,
    head_dim=128,
    mlp_act="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=768),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        arch_id="qwen3_30b_a3b_reduced",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64),
        layer_pattern=None,
    )
