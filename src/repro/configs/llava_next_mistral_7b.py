"""LLaVA-NeXT-Mistral-7B [vlm] — anyres tiling
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].

Backbone only per assignment: 32L, d_model=4096, 32H (GQA kv=8), d_ff=14336,
vocab=32000.  The vision frontend (CLIP tower + anyres tiling) is a STUB:
``input_specs()`` provides precomputed patch embeddings of shape
(batch, frontend_tokens, d_model); 2880 patch tokens models a 2x2 anyres grid
plus base tile (5 tiles x 576 patches).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="llava_next_mistral_7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    mlp_act="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    frontend="vision_patches",
    frontend_tokens=2880,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        arch_id="llava_next_mistral_7b_reduced",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, frontend_tokens=16, layer_pattern=None,
    )
