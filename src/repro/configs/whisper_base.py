"""Whisper-base [audio] — encoder-decoder with conv frontend (stubbed)
[arXiv:2212.04356; unverified].

6L decoder, d_model=512, 8H (kv=8), d_ff=2048, vocab=51865; 6L encoder over
1500 audio-frame positions.  The conv frontend is a STUB per assignment:
``input_specs()`` provides precomputed frame embeddings
(batch, 1500, d_model).  Decode shapes exercise the decoder (self-attn KV
cache + cross-attn to encoder states).
"""

from repro.models.config import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper_base",
    family="audio",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    mlp_act="gelu",
    norm="layernorm",
    encoder=EncoderConfig(num_layers=6, num_heads=8, max_source_positions=1500),
    frontend="audio_frames",
    frontend_tokens=1500,
    tie_embeddings=True,
    # Published whisper-base caps target positions at 448; the assigned shape
    # cells (train_4k => 2596 decoder tokens, prefill/decode_32k => 31268)
    # require a longer learned-position table, so it is extended to cover the
    # largest assigned decoder context (deviation noted in DESIGN.md).
    max_seq_len=32_768,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        arch_id="whisper_base_reduced",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512,
        encoder=EncoderConfig(num_layers=2, num_heads=4, max_source_positions=32),
        frontend_tokens=32, layer_pattern=None,
    )
