"""Qwen2.5-3B [dense] — GQA with QKV bias [hf:Qwen/Qwen2.5-0.5B family; hf].

36L, d_model=2048, 16 heads (GQA kv=2), d_ff=11008, vocab=151936.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2_5_3b",
    family="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    d_ff=11008,
    vocab_size=151936,
    qkv_bias=True,
    mlp_act="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        arch_id="qwen2_5_3b_reduced",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        layer_pattern=None,
    )
