"""Granite-3.0-8B [dense] — GQA [hf:ibm-granite/granite-3.0-2b-base family; hf].

40L, d_model=4096, 32 heads (GQA kv=8), d_ff=12800, vocab=49155.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite_3_8b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=12800,
    vocab_size=49155,
    mlp_act="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        arch_id="granite_3_8b_reduced",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, layer_pattern=None,
    )
