"""Mamba2-370M [ssm] — SSD (state-space duality), attention-free
[arXiv:2405.21060; unverified].

48L, d_model=1024, d_ff=0 (no MLP stack; SSD blocks only), vocab=50280,
ssm_state=128.  O(1)-state decode => long_500k cell runs.
"""

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="mamba2_370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=1,          # unused by SSD blocks (attention-free)
    num_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    head_dim=64,
    norm="rmsnorm",
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk_size=128),
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        arch_id="mamba2_370m_reduced",
        num_layers=2, d_model=64, vocab_size=512,
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, chunk_size=16),
        layer_pattern=None,
    )
