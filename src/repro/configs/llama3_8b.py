"""Llama-3.1-8B — paper evaluation model (§6.1), TP degree 1."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama3_8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    mlp_act="swiglu",
    norm="rmsnorm",
    rope_theta=500_000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        arch_id="llama3_8b_reduced",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, layer_pattern=None,
    )
