"""Llama-3.1-70B — paper evaluation model (§6.1), TP degree 4."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama3_70b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    mlp_act="swiglu",
    norm="rmsnorm",
    rope_theta=500_000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        arch_id="llama3_70b_reduced",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, layer_pattern=None,
    )
