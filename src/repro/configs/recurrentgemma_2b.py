"""RecurrentGemma-2B [hybrid] — RG-LRU + local attention, 1:2 ratio
[arXiv:2402.19427; hf].

26L, d_model=2560, 10H (MQA kv=1), d_ff=7680, vocab=256000.  Layer pattern:
(rglru, rglru, local_attn) repeating; local attention window 2048.
Sub-quadratic decode => long_500k cell runs.
"""

from repro.models.config import ModelConfig, RGLRUConfig

_PATTERN = (["rglru", "rglru", "local_attn"] * 9)[:26]

CONFIG = ModelConfig(
    arch_id="recurrentgemma_2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    mlp_act="swiglu",
    norm="rmsnorm",
    sliding_window=2048,
    layer_pattern=_PATTERN,
    rglru=RGLRUConfig(lru_width=2560, conv_width=4),
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        arch_id="recurrentgemma_2b_reduced",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=512, sliding_window=64,
        layer_pattern=["rglru", "rglru", "local_attn"],
        rglru=RGLRUConfig(lru_width=64, conv_width=4),
    )
