"""DBRX-132B [moe] — 16 experts top-4, fine-grained [hf:databricks/dbrx-base;
unverified].

40L, d_model=6144, 48H (GQA kv=8), d_ff=10752 (per expert), vocab=100352,
MoE 16e top-4.
"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="dbrx_132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    mlp_act="swiglu",
    norm="layernorm",
    rope_theta=500_000.0,
    moe=MoEConfig(num_experts=16, top_k=4, d_ff_expert=10752),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        arch_id="dbrx_132b_reduced",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128),
        layer_pattern=None,
    )
