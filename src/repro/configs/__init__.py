"""Architecture registry: the 10 assigned architectures plus the paper's own
evaluation models (llama-3.1-8b, llama-3.1-70b, qwen3-30b-a3b).

Every module in this package exports ``CONFIG`` (the full published config)
and ``reduced()`` (a tiny same-family config for CPU smoke tests).  Select
with ``--arch <id>`` in the launchers.

Shape cells (assigned): each architecture is paired with all four shapes;
``decode_*``/``long_*`` lower ``serve_step`` (one token against a KV cache of
``seq_len``), ``prefill_32k`` lowers the chunked-prefill step, ``train_4k``
lowers ``train_step``.  ``long_500k`` requires sub-quadratic decode and is
skipped for pure full-attention architectures (see DESIGN.md
§Arch-applicability); the skip is explicit in :func:`applicable_shapes`.
"""

from __future__ import annotations

import importlib
from dataclasses import dataclass
from typing import Dict, List

from repro.models.config import ModelConfig

__all__ = [
    "ShapeSpec",
    "SHAPES",
    "ARCH_IDS",
    "PAPER_ARCH_IDS",
    "get_config",
    "get_reduced_config",
    "applicable_shapes",
    "all_cells",
]


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

ARCH_IDS: List[str] = [
    "qwen2_5_3b",
    "granite_3_8b",
    "granite_8b",
    "olmo_1b",
    "llava_next_mistral_7b",
    "dbrx_132b",
    "mixtral_8x7b",
    "recurrentgemma_2b",
    "whisper_base",
    "mamba2_370m",
]

# The paper's §6.1 evaluation models (used by the fidelity benchmarks).
PAPER_ARCH_IDS: List[str] = ["llama3_8b", "llama3_70b", "qwen3_30b_a3b"]

_ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS + PAPER_ARCH_IDS}


def _module(arch_id: str):
    arch_id = _ALIASES.get(arch_id, arch_id)
    return importlib.import_module(f"repro.configs.{arch_id}")


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).CONFIG


def get_reduced_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).reduced()


def applicable_shapes(cfg: ModelConfig) -> List[ShapeSpec]:
    """The assigned shape cells this architecture participates in."""
    out = []
    for shape in SHAPES.values():
        if shape.name == "long_500k" and not cfg.supports_long_context():
            continue  # quadratic full attention — skip per assignment
        out.append(shape)
    return out


def all_cells() -> List[tuple[str, str]]:
    """Every (arch, shape) dry-run cell, including assignment-mandated skips
    (a skipped cell is simply absent)."""
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in applicable_shapes(cfg):
            cells.append((arch, shape.name))
    return cells
