"""Vidur-style discrete-event simulator — the baseline Revati replaces.

This is a deliberate, faithful instance of the approach the paper critiques
(§2.2–2.3): the serving system's control logic is *re-implemented* inside an
event loop.  It models continuous batching with chunked prefill (the ~150
lines Vidur needed for the original vLLM scheduler) and shares Revati's
runtime predictor, so any output divergence from the emulator is purely the
**semantic gap** of re-implementation — not a cost-model difference.

Multi-replica mode: ``num_replicas > 1`` runs N independent replica engines
inside one merged event loop, with request placement delegated to the same
pluggable :class:`~repro.cluster.router.Router` policies that route the
emulator's real engines.  Using identically-constructed policy objects
(routers are stateful — build a fresh one per run) pins routing behaviour
equal by construction, so emulator-vs-DES divergence at cluster scale is
attributable purely to engine-semantics re-implementation — extending the
paper's semantic-gap argument to N replicas.

Intentionally (and realistically) missing, mirroring Table 1's "VD" column:
prefix caching (so ``prefix_affinity`` routing degrades to its sticky-map
fallback — a DES replica can never report a cache hit), hierarchical cache
tiers, preemption-by-recompute, per-framework batching quirks, and the
``pd_pool`` policy's KV migration (re-implementing it here would be exactly
the re-implementation burden the paper critiques, so it raises instead).
``benchmarks/table1_features`` quantifies the resulting error on workloads
that exercise those features.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.predictor import BatchSpec, RuntimePredictor, SeqSpec


@dataclass
class DESConfig:
    max_num_seqs: int = 64
    max_batched_tokens: int = 512
    step_overhead_s: float = 20e-6     # modelled CPU overhead per step


@dataclass
class SimRequest:
    request_id: int
    prompt_len: int
    max_new_tokens: int
    arrival_time: float
    num_prefilled: int = 0
    num_generated: int = 0
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    replica: int = -1                              # placement decision
    prompt_tokens: Optional[Tuple[int, ...]] = None  # routing key only

    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    def tpot(self) -> Optional[float]:
        if self.finish_time is None or self.first_token_time is None:
            return None
        n = self.num_generated - 1
        return (self.finish_time - self.first_token_time) / n if n > 0 else 0.0


class _ReplicaState:
    """One simulated engine replica: queues + in-flight step bookkeeping.

    Also the replica's :class:`~repro.cluster.router.ReplicaView`: routing
    probes answer from event-loop state.  ``prefix_match_len`` is always 0 —
    the DES models no radix cache (Table 1), which is itself part of the
    semantic gap the multi-replica comparison measures.
    """

    def __init__(self, index: int):
        self.index = index
        self.waiting: List[SimRequest] = []
        self.running: List[SimRequest] = []
        self.step_in_flight = False
        self.in_flight_batch: List[Tuple[SimRequest, int]] = []

    # ------------------------------------------------------- ReplicaView --
    def outstanding_tokens(self) -> int:
        total = 0
        for s in self.waiting + self.running:
            total += max(s.prompt_len - s.num_prefilled, 0)
            total += max(s.max_new_tokens - s.num_generated, 0)
        return total

    def prefix_match_len(self, tokens) -> int:
        return 0


class DiscreteEventSimulator:
    """Event-driven re-implementation of a vLLM-like engine (1..N replicas)."""

    ARRIVAL, STEP_DONE = 0, 1

    def __init__(
        self,
        predictor: RuntimePredictor,
        cfg: DESConfig = DESConfig(),
        *,
        num_replicas: int = 1,
        router=None,                 # repro.cluster.router.Router
    ):
        self.predictor = predictor
        self.cfg = cfg
        self.num_replicas = num_replicas
        if router is not None and getattr(router, "policy", None) == "pd_pool":
            raise ValueError(
                "the DES baseline does not model PD disaggregation "
                "(KV migration would need re-implementation — the exact "
                "burden the paper critiques); use the cluster emulator")
        if router is not None and router.num_replicas != num_replicas:
            raise ValueError(
                f"router sized for {router.num_replicas} replicas, "
                f"simulator has {num_replicas}")
        self.router = router
        self.replicas: List[_ReplicaState] = []

    def run(self, requests) -> List[SimRequest]:
        """``requests``: iterable of objects with prompt_tokens/prompt_len,
        max_new_tokens, arrival_time (repro Request or SimRequest)."""
        from repro.cluster.router import RoundRobinRouter

        router = self.router or RoundRobinRouter(self.num_replicas)
        sims: List[SimRequest] = []
        for i, r in enumerate(requests):
            toks = getattr(r, "prompt_tokens", None)
            plen = getattr(r, "prompt_len", None) or len(toks)
            sims.append(SimRequest(
                request_id=i, prompt_len=plen,
                max_new_tokens=r.max_new_tokens,
                arrival_time=r.arrival_time,
                prompt_tokens=tuple(toks) if toks is not None else None))

        self.replicas = [_ReplicaState(i) for i in range(self.num_replicas)]
        counter = itertools.count()
        # event payload: SimRequest for ARRIVAL, replica index for STEP_DONE
        events: List[Tuple[float, int, int, object]] = []
        for s in sims:
            heapq.heappush(events, (s.arrival_time, next(counter), self.ARRIVAL, s))

        now = 0.0

        def schedule_step(rep: _ReplicaState):
            if rep.step_in_flight:
                return
            batch: List[Tuple[SimRequest, int]] = []
            budget = self.cfg.max_batched_tokens
            # decodes first (mixed batching)
            for s in rep.running:
                if s.num_prefilled >= s.prompt_len:
                    batch.append((s, 1))
            # chunked prefill continuation + FCFS admission
            for s in rep.running:
                if budget <= 0:
                    break
                if s.num_prefilled < s.prompt_len:
                    chunk = min(budget, s.prompt_len - s.num_prefilled)
                    batch.append((s, chunk))
                    budget -= chunk
            while (budget > 0 and rep.waiting
                   and len(rep.running) < self.cfg.max_num_seqs):
                s = rep.waiting.pop(0)
                rep.running.append(s)
                chunk = min(budget, s.prompt_len)
                batch.append((s, chunk))
                budget -= chunk
            if not batch:
                return
            spec = BatchSpec.make([
                SeqSpec(n, s.num_prefilled + s.num_generated + n)
                for s, n in batch
            ])
            dur = self.predictor.predict_step(spec).total + self.cfg.step_overhead_s
            rep.in_flight_batch = batch
            rep.step_in_flight = True
            heapq.heappush(
                events, (now + dur, next(counter), self.STEP_DONE, rep.index))

        while events:
            now, _, kind, payload = heapq.heappop(events)
            if kind == self.ARRIVAL:
                idx = router.route(payload, self.replicas)
                payload.replica = idx
                rep = self.replicas[idx]
                rep.waiting.append(payload)
                schedule_step(rep)
            else:  # STEP_DONE
                rep = self.replicas[payload]
                rep.step_in_flight = False
                for s, n in rep.in_flight_batch:
                    if s.num_prefilled < s.prompt_len:
                        s.num_prefilled += n
                        if s.num_prefilled >= s.prompt_len:
                            s.num_generated += 1
                            if s.first_token_time is None:
                                s.first_token_time = now
                    else:
                        s.num_generated += 1
                    if (s.num_prefilled >= s.prompt_len
                            and s.num_generated >= s.max_new_tokens
                            and s.finish_time is None):
                        s.finish_time = now
                        rep.running.remove(s)
                rep.in_flight_batch = []
                schedule_step(rep)

        return sims
